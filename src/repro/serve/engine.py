"""Batched serving engine: prefill + autoregressive decode.

``build_serve_step`` produces the jitted single-token step that the dry-run
lowers for the decode_* shape cells: one new token against a KV cache (or SSM
state) of the cell's seq_len.  The engine wraps it with greedy/temperature
sampling and a fixed-slot batch (continuous batching would swap finished
slots; we keep slot management host-side and simple).

``params`` may be a dense tree OR a compressed SparseParams tree (pruned
projections stored as :class:`~repro.sparsity.params.NMCompressed` buffers,
e.g. from ``prune_transformer(..., emit="compressed")``): the model layers
dispatch per-leaf, so prefill and decode stream the compressed weights
through the nm_spmm kernel and no dense W is ever materialized in HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


def build_serve_step(cfg: ModelConfig, in_shardings=None, donate: bool = True):
    """Jitted decode step: (params, token (B,), caches, index) -> (logits, caches)."""

    def step(params, token, caches, index):
        return lm.decode_step(params, cfg, token, caches, index)

    return jax.jit(
        step,
        donate_argnums=(2,) if donate else (),
        in_shardings=in_shardings,
    )


def build_prefill(cfg: ModelConfig, in_shardings=None):
    def pre(params, caches, tokens=None, embeds=None):
        return lm.prefill(params, cfg, caches, tokens=tokens, embeds=embeds)

    return jax.jit(pre, static_argnames=(), in_shardings=in_shardings)


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    params: Any
    max_len: int
    temperature: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._decode = build_serve_step(self.cfg, donate=True)
        self._prefill = build_prefill(self.cfg)

    def generate(
        self,
        prompts: jnp.ndarray,  # (B, S_prompt) int32
        max_new_tokens: int,
        embeds: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Greedy/temperature generation; returns (B, max_new_tokens)."""
        b = prompts.shape[0] if prompts is not None else embeds.shape[0]
        s0 = prompts.shape[1] if prompts is not None else embeds.shape[1]
        if max_new_tokens <= 0:  # nothing to generate: no prefill, no sample
            return jnp.zeros((b, 0), jnp.int32)
        caches = lm.init_cache(self.cfg, b, self.max_len)
        logits, caches = self._prefill(
            self.params, caches,
            tokens=None if embeds is not None else prompts,
            embeds=embeds,
        )
        key = jax.random.PRNGKey(self.seed)
        out = []
        tok = self._sample(logits, key)
        out.append(tok)
        index = jnp.asarray(s0, jnp.int32)
        for i in range(max_new_tokens - 1):
            logits, caches = self._decode(self.params, tok, caches, index + i)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            out.append(tok)
        return jnp.stack(out, axis=1)

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature).astype(jnp.int32)
