"""qwen2-vl-7b [vlm]: 28L d3584 28H (GQA kv=4) ff18944 vocab=152064.
M-RoPE (sections 16/24/24 over head_dim/2=64), dynamic-resolution vision
frontend STUBBED (input_specs feeds patch embeddings).  [arXiv:2409.12191; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        d_ff=18944, vocab_size=152064, head_dim=128,
        rope_theta=1e6, mrope_sections=(16, 24, 24), frontend="vision",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        mrope_sections=(2, 3, 3), frontend="vision", remat="none",
        dtype="float32",
    )
