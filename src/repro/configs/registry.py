"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# The ten assigned architectures (+ the paper's own eval model).
ARCH_IDS = [
    "qwen2_vl_7b",
    "zamba2_7b",
    "qwen3_moe_235b",
    "mixtral_8x22b",
    "llama32_3b",
    "command_r_plus_104b",
    "phi3_medium_14b",
    "granite_8b",
    "mamba2_370m",
    "musicgen_large",
]
EXTRA_IDS = ["llama32_1b"]

# long_500k requires sub-quadratic decode; pure full-attention archs skip it
# (DESIGN.md §4).  SSM/hybrid/SWA archs run it.
LONG_CONTEXT_ARCHS = {"mamba2_370m", "zamba2_7b", "mixtral_8x22b"}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def cell_supported(arch: str, shape_name: str) -> tuple[bool, str]:
    """Whether (arch x shape) is a live dry-run cell, with a reason if not."""
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "full-attention arch: 500k dense KV outside scope (DESIGN.md §4)"
    return True, ""


def all_cells(shapes: list[str]) -> list[tuple[str, str, bool, str]]:
    out = []
    for arch in ARCH_IDS:
        for s in shapes:
            ok, why = cell_supported(arch, s)
            out.append((arch, s, ok, why))
    return out
