"""granite-8b [dense]: 36L d4096 32H (GQA kv=8) ff14336 vocab=49152.
llama-arch code model.  [arXiv:2405.04324; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=49152, head_dim=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, remat="none", dtype="float32",
    )
