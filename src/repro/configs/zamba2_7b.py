"""zamba2-7b [hybrid]: 81L d3584 Mamba2 blocks (ssm_state=64) with a
shared-weight attention+MLP block (32H MHA, ff14336) applied every 6 layers.
vocab=32000.  [arXiv:2411.15242; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000, head_dim=112,
        ssm_state=64, ssm_head_dim=64, hybrid_attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, hybrid_attn_every=2,
        remat="none", dtype="float32",
    )
