"""phi3-medium-14b [dense]: 40L d5120 40H (GQA kv=10) ff17920 vocab=100352.
RoPE + SwiGLU + GQA.  [arXiv:2404.14219; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
        d_ff=17920, vocab_size=100352, head_dim=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-smoke", family="dense",
        num_layers=2, d_model=80, num_heads=5, num_kv_heads=5,
        d_ff=160, vocab_size=512, head_dim=16, remat="none", dtype="float32",
    )
