"""llama3.2-1b [dense] — the paper\'s own eval family (Tab. 5): 16L d2048
32H (GQA kv=8) ff8192 vocab=128256.  Used by the pruning benchmarks.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
        d_ff=8192, vocab_size=128256, head_dim=64, rope_theta=5e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, remat="none", dtype="float32",
    )
