"""command-r-plus-104b [dense]: 64L d12288 96H (GQA kv=8) ff33792
vocab=256000, no biases.  256k vocab exercises the vocab-sharded loss path.
[hf:CohereForAI/c4ai-command-r-plus; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=33792, vocab_size=256000, head_dim=128, rope_theta=75e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-smoke", family="dense",
        num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=192, vocab_size=512, head_dim=16, remat="none", dtype="float32",
    )
