"""mixtral-8x22b [moe]: 56L d6144 48H (GQA kv=8) ff16384 vocab=32768,
8 experts top-2, sliding-window attention.  SWA ring cache makes the
long_500k decode cell run (DESIGN.md §4).  [arXiv:2401.04088; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        num_experts=8, top_k=2, sliding_window=4096, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512, head_dim=16,
        num_experts=4, top_k=2, sliding_window=16, moe_group=64,
        remat="none", dtype="float32",
    )
