"""qwen3-moe-235b-a22b [moe]: 94L d4096 64H (GQA kv=4, head_dim 128)
per-expert ff1536, vocab=151936, 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B scaled per assignment; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
        d_ff=1536, vocab_size=151936, head_dim=128,
        num_experts=128, top_k=8, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512, head_dim=16,
        num_experts=8, top_k=2, moe_group=64, remat="none", dtype="float32",
    )
