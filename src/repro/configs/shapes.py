"""Assigned input-shape cells (same four for every LM-family arch)."""
from repro.models.config import ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}
