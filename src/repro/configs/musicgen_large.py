"""musicgen-large [audio]: 48L d2048 32H (MHA kv=32) ff8192 vocab=2048,
decoder-only over EnCodec tokens; the EnCodec frontend is a STUB
(input_specs provides frame embeddings).  [arXiv:2306.05284; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, head_dim=64, frontend="audio",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16, frontend="audio",
        remat="none", dtype="float32",
    )
