"""Distribution substrate: meshes, sharding rules, gradient compression."""
from repro.distributed.sharding import (
    MeshRules,
    current_mesh,
    set_mesh,
    shard,
    named_sharding,
    logical_to_spec,
)

__all__ = [
    "MeshRules",
    "current_mesh",
    "set_mesh",
    "shard",
    "named_sharding",
    "logical_to_spec",
]
