"""Int8 gradient compression with error feedback for cross-pod reduction.

On a multi-pod mesh the "pod" axis rides the slowest links (DCN / inter-pod
ICI), so the cross-pod gradient all-reduce dominates collective time for pure
data parallelism across pods.  We shard_map the train step with *manual*
"pod" axis (data/model stay auto/GSPMD) and replace the pod all-reduce with:

    1. pmax of the per-tensor scale        (scalar — free)
    2. all_gather of int8 quantized grads  (1 byte/elem vs 4)
    3. local f32 sum + dequantize

Error feedback [Seide'14/Karimireddy'19]: the quantization residual is added
to the next step's gradient, keeping the compressed SGD unbiased in the long
run — the residual buffer lives in the train state and inherits param
sharding.  Traffic drops 4x vs f32 all-reduce (per-link accounting in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def compressed_psum(grads, ef, axis_name: str):
    """Quantized all-reduce over ``axis_name`` with error feedback.

    grads/ef: pytrees (ef may be None -> no feedback).  Returns
    (reduced grads in f32-of-param-dtype, new ef residuals).
    """
    n = axis_size(axis_name)

    def one(g, e):
        if g.size == 0:  # non-diff placeholder (compressed N:M indices)
            return g, jnp.zeros_like(g)
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e.astype(jnp.float32)
        scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-30
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        resid = g32 - q.astype(jnp.float32) * scale
        gathered = jax.lax.all_gather(q, axis_name)  # (n, ...) int8 payload
        total = jnp.sum(gathered.astype(jnp.float32), axis=0) * scale
        return (total / n).astype(g.dtype), resid.astype(g.dtype)

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef) if ef is not None else [None] * len(flat_g)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tree, [o[0] for o in outs]),
        jax.tree.unflatten(tree, [o[1] for o in outs]),
    )
