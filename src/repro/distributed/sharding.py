"""Logical-axis sharding rules (MaxText-style) and mesh plumbing.

Parameters and activations are annotated with *logical* axis names; a
``MeshRules`` table maps them to physical mesh axes.  The defaults implement
FSDP("data") x TP("model") with an optional outer "pod" data axis:

  * weight matrices  (in=embed, out=mlp/heads/vocab) -> ("data", "model")
  * expert tensors   (experts, embed, ff)            -> ("model", "data", None)
  * activations      (batch, seq, embed)             -> (("pod","data"), None, None)

``shard(x, *logical)`` applies a sharding constraint when a mesh is active
and is a no-op otherwise, so model code is identical on 1 CPU device and on a
512-chip mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES = {
    # Parameter logical axes.
    "embed": "data",       # FSDP shard of the model dimension
    "mlp": "model",        # TP shard of hidden/ff
    "heads": "model",      # TP shard of attention heads
    "kv_heads": "model",
    "vocab": "model",      # TP shard of embedding/unembedding vocab
    "experts": "model",    # expert parallelism
    "expert_in": "data",   # FSDP of per-expert matrices
    "layers": None,        # scan-stacked layer axis is replicated
    "conv": None,
    "stats": None,
    # Activation logical axes.
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_vocab": "model",
    "act_exp": "model",
    "act_kv": None,
    # Sequence parallelism for attention internals when head counts don't
    # divide the TP axis (phi3: 40 heads, qwen2-vl: 28, mixtral: 48, ...).
    "act_attn_seq": "model",
}


def model_axis_size(mesh: Optional[Mesh]) -> int:
    if mesh is None:
        return 1
    sizes = dict(mesh.shape)
    return sizes.get("model", 1)


@dataclasses.dataclass(frozen=True)
class MeshRules:
    rules: dict

    def spec(self, *logical: Optional[str]) -> P:
        out = []
        for name in logical:
            if name is None:
                out.append(None)
            else:
                axis = self.rules.get(name, None)
                out.append(axis)
        return P(*out)


def default_rules(mesh: Optional[Mesh]) -> MeshRules:
    rules = dict(DEFAULT_RULES)
    if mesh is not None:
        names = set(mesh.axis_names)
        # Drop references to mesh axes that don't exist (e.g. no "pod").
        def fix(v):
            if isinstance(v, tuple):
                vv = tuple(a for a in v if a in names)
                return vv if vv else None
            return v if v in names else None

        rules = {k: fix(v) for k, v in rules.items()}
    return MeshRules(rules)


def set_mesh(mesh: Optional[Mesh], rules: Optional[MeshRules] = None):
    _state.mesh = mesh
    _state.rules = rules or default_rules(mesh)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> MeshRules:
    r = getattr(_state, "rules", None)
    return r if r is not None else default_rules(None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[MeshRules] = None):
    prev_m, prev_r = current_mesh(), getattr(_state, "rules", None)
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        _state.mesh = prev_m
        _state.rules = prev_r


def logical_to_spec(*logical: Optional[str]) -> P:
    return current_rules().spec(*logical)


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(*logical))


def _fit_spec_to_shape(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes that don't divide the corresponding dim (e.g. 40 heads
    on a 16-wide model axis).  Dropped dims become UNCONSTRAINED — a None
    would *force replication* across the axis, which measured 3-6x extra
    HBM traffic on phi3/qwen2-vl/llama3.2-3b whose head counts don't divide
    16 (EXPERIMENTS.md §Perf iteration 1)."""
    sizes = dict(mesh.shape)
    out = []
    for dim, s in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        axes = tuple(a for a in axes if a in sizes)
        total = 1
        for a in axes:
            total *= sizes[a]
        if axes and total and dim % total == 0:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(P.UNCONSTRAINED)
    return P(*out)


def shard(x, *logical: Optional[str]):
    """Apply a sharding constraint if a mesh is active; else identity."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _fit_spec_to_shape(logical_to_spec(*logical), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
