"""Unified front door for the TSENOR reproduction.

Everything a user needs for "arbitrary N:M values with pluggable layer-wise
frameworks" lives here:

* **Pattern** — :class:`PatternSpec`, the single description of an N:M
  pattern (``PatternSpec(2, 4)``, ``PatternSpec.parse("t16:32")``).
* **Solver backends** — :func:`register_backend` / :func:`get_backend` /
  :func:`available_backends` over the :class:`SolverBackend` protocol;
  ``SolverConfig(backend="pallas")`` selects one.
* **Pruning methods** — :func:`register_method` / :func:`get_method` /
  :func:`available_methods` over the :class:`PruneMethod` protocol;
  ``prune_transformer(method="wanda")`` is a registry lookup.
* **Solving** — :func:`solve_mask` for one tensor;
  :class:`MaskService` (``service.solve(w, pattern)``) for whole-model
  workloads with bucketed mega-batches, multi-device sharding, caching and
  journaled resume.
* **Compressed execution** — :class:`NMCompressed` /
  :func:`compress_params` / :func:`decompress_params`: SparseParams trees
  whose pruned projections train and serve straight from ``(values,
  indices)`` buffers through the nm_spmm kernel
  (``prune_transformer(emit="compressed")``,
  ``StepConfig(mask_mode="compressed")``).

Every pruning method routes its transposable mask solves through the
service: importance-scored methods (Wanda, magnitude) as one up-front
batch, sequential methods (SparseGPT, ALPS) through the ``solve_plan``
generator protocol driven by :func:`repro.pruning.plan.drive_solve_plans`
— so the fused backend, bucketed mega-batches, bit-packed transport and
content cache apply uniformly.

Typical use::

    from repro.api import MaskService, PatternSpec, SolverConfig

    service = MaskService(SolverConfig(iters=150), directory="runs/prune")
    mask = service.solve(w, PatternSpec(2, 4))

See ``examples/custom_backend.py`` for registering a custom solver backend
and pruning method, ``docs/architecture.md`` for the layer map and solve
request lifecycle, and ``docs/solver_math.md`` for the algorithm.
"""
from repro.patterns import PatternSpec, pattern_from_args
from repro.core.backends import (
    SolverBackend,
    available_backends,
    get_backend,
    register_backend,
    unregister_backend,
)
from repro.core.solver import (
    SolverConfig,
    is_transposable_nm,
    nm_mask,
    objective,
    relative_error,
    solve_blocks,
    solve_mask,
    transposable_nm_mask,
)
from repro.service import (
    BucketPolicy,
    MaskCache,
    MaskClient,
    MaskHandle,
    MaskServer,
    MaskService,
    ServiceStats,
    StreamStats,
    TenantConfig,
)
from repro.pruning.alps import AlpsConfig
from repro.pruning.methods import (
    PruneContext,
    PruneMethod,
    available_methods,
    get_method,
    register_method,
    unregister_method,
)
from repro.pruning.runner import prune_transformer
from repro.sparsity.masks import apply_mask, mask_sparsity, sparsify_pytree
from repro.sparsity.params import (
    NMCompressed,
    compress_params,
    decompress_params,
    is_sparse_params,
    masks_from_params,
    sparse_param_bytes,
)

__all__ = [
    # pattern
    "PatternSpec",
    "pattern_from_args",
    # solver + backends
    "SolverBackend",
    "SolverConfig",
    "available_backends",
    "get_backend",
    "register_backend",
    "unregister_backend",
    "solve_mask",
    "solve_blocks",
    "nm_mask",
    "transposable_nm_mask",
    "is_transposable_nm",
    "objective",
    "relative_error",
    # service (in-process engine + network front-end)
    "BucketPolicy",
    "MaskCache",
    "MaskClient",
    "MaskHandle",
    "MaskServer",
    "MaskService",
    "ServiceStats",
    "StreamStats",
    "TenantConfig",
    # pruning
    "AlpsConfig",
    "PruneContext",
    "PruneMethod",
    "available_methods",
    "get_method",
    "register_method",
    "unregister_method",
    "prune_transformer",
    # sparsity substrate
    "apply_mask",
    "mask_sparsity",
    "sparsify_pytree",
    # compressed execution (SparseParams)
    "NMCompressed",
    "compress_params",
    "decompress_params",
    "is_sparse_params",
    "masks_from_params",
    "sparse_param_bytes",
]
