"""Pluggable solver backends for the per-block transposable N:M problem.

A backend consumes a ``(B, M, M)`` float batch of ``|W|`` blocks and returns
``(B, M, M)`` boolean masks with <= N ones per row and column of every
block.  Backends own their own jit/compile strategy; callers select one by
name through :class:`repro.core.solver.SolverConfig.backend`.

Built-in entries:

* ``"dense-jit"``       — XLA-jitted Dykstra (Alg. 1) + rounding (Alg. 2);
                          the default, bit-identical to the pre-registry path.
* ``"pallas"``          — same pipeline with the Dykstra iterations fused in
                          a Pallas kernel (VMEM-resident).
* ``"pallas-fused"``    — the whole solve (tau scaling, Dykstra, greedy +
                          local-search rounding) in ONE Pallas kernel: a
                          single HBM read of |W| and a single bit-packed
                          mask write.  Mask-identical to ``dense-jit`` at
                          ``SolverConfig.tol = 0``; ``tol > 0`` enables the
                          adaptive early-exit fast mode.  Also exposes
                          ``solve_packed`` returning (B, M) uint32 rows
                          (``repro.sparsity.bitpack`` layout) that the
                          service cache stores verbatim.
* ``"exact"``           — per-block LP oracle (HiGHS; integral by the
                          transportation-polytope argument).  Host-side,
                          for tests/benchmarks — not a production path.
* ``"greedy-baseline"`` — greedy insertion on raw magnitudes, the Hubara et
                          al. 2021 2-approximation the paper compares against.

Third parties register their own::

    from repro.api import register_backend

    @register_backend
    class MyBackend:
        name = "my-backend"
        traceable = True  # safe to call under an enclosing jit / shard_map
        def solve(self, w_abs_blocks, pattern, config): ...

``traceable`` declares the solve is pure JAX, which lets the service
scheduler wrap it in ``shard_map`` for multi-device mega-batch dispatch;
host-side backends (like ``"exact"``) set it False and are dispatched on a
single device.  A backend may additionally expose
``solve_packed(w_abs_blocks, pattern, config) -> (B, M) uint32`` returning
bit-packed mask rows (``repro.sparsity.bitpack`` layout); the scheduler and
cache consume those verbatim, skipping the unpack/repack round-trip.

Every mask in the repo comes through here: ``solve_mask`` for one tensor,
``MaskService`` mega-batches for whole models, and — since the
``solve_plan`` routing — SparseGPT/ALPS sequential sweeps as well, so a
registered backend accelerates every pruning framework at once.  See
``docs/architecture.md`` ("which backend when") for selection guidance and
``docs/solver_math.md`` for the algorithm the built-ins implement.
"""
from __future__ import annotations

import functools
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dykstra import dykstra_log
from repro.core.rounding import greedy_round, round_blocks
from repro.patterns import PatternSpec


@runtime_checkable
class SolverBackend(Protocol):
    """Protocol every solver backend implements.

    ``name`` keys the registry (``SolverConfig.backend`` selects by it);
    ``traceable`` declares the solve safe under jit/``shard_map``.  The
    optional ``solve_packed`` method (see module docstring) returns
    bit-packed uint32 mask rows instead of bool blocks.
    """

    name: str
    traceable: bool

    def solve(
        self, w_abs_blocks: jnp.ndarray, pattern: PatternSpec, config
    ) -> jnp.ndarray:
        """(B, M, M) |W| blocks -> (B, M, M) bool masks (row/col sums <= N)."""
        ...


_REGISTRY: dict[str, SolverBackend] = {}


def register_backend(backend=None, *, name: str | None = None, overwrite: bool = False):
    """Register a backend instance (or class — it is instantiated).

    Usable directly (``register_backend(MyBackend())``) or as a decorator.
    Registering an existing name without ``overwrite=True`` is an error.
    """

    def _register(obj):
        inst = obj() if isinstance(obj, type) else obj
        key = name if name is not None else getattr(inst, "name", None)
        if not key or not isinstance(key, str):
            raise ValueError("backend needs a string 'name' attribute (or name=)")
        if key in _REGISTRY and not overwrite:
            raise ValueError(
                f"solver backend {key!r} is already registered; "
                "pass overwrite=True to replace it"
            )
        _REGISTRY[key] = inst
        return obj

    if backend is None:
        return _register
    return _register(backend)


def unregister_backend(name: str) -> None:
    """Remove a registered backend (no-op if absent); mainly for tests."""
    _REGISTRY.pop(name, None)


def get_backend(name) -> SolverBackend:
    """Look up a backend by name; backend instances pass through."""
    if not isinstance(name, str):
        if isinstance(name, SolverBackend):
            return name
        raise TypeError(f"expected a backend name or SolverBackend, got {name!r}")
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver backend {name!r}; available: "
            f"{', '.join(available_backends())}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Built-in backends.
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("n", "iters", "ls_steps", "tau_scale", "tol", "kernel"),
)
def _batched_solve(w_abs_blocks, n, iters, ls_steps, tau_scale, tol, kernel):
    """The TSENOR pipeline over a block batch; one program per static config.

    At ``tol=0`` this is the exact jitted program the pre-registry
    ``_solve_blocks_jit`` compiled, so masks (and the in-process jit cache)
    are unchanged.  ``tol>0`` swaps the fixed Dykstra ``fori_loop`` for the
    convergence-tested ``while_loop``.
    """
    w_abs_blocks = jnp.asarray(w_abs_blocks, jnp.float32)
    scale = jnp.max(w_abs_blocks, axis=(1, 2), keepdims=True)
    tau = tau_scale / jnp.maximum(scale, 1e-30)
    if kernel:
        from repro.kernels.dykstra import ops as dykstra_ops

        s_approx = dykstra_ops.dykstra(w_abs_blocks * tau, n, iters, tol=tol)
    else:
        s_approx = dykstra_log(w_abs_blocks, n, iters, tau=tau, tol=tol)
    return round_blocks(s_approx, w_abs_blocks, n, ls_steps)


class DenseJitBackend:
    """XLA path: log-domain Dykstra + greedy/local-search rounding."""

    name = "dense-jit"
    traceable = True

    def solve(self, w_abs_blocks, pattern, config):
        return _batched_solve(
            w_abs_blocks, pattern.n, config.iters, config.ls_steps,
            config.tau_scale, config.tol, False,
        )


class PallasBackend:
    """Pallas path: Dykstra iterations fused in VMEM, same rounding."""

    name = "pallas"
    traceable = True

    def solve(self, w_abs_blocks, pattern, config):
        return _batched_solve(
            w_abs_blocks, pattern.n, config.iters, config.ls_steps,
            config.tau_scale, config.tol, True,
        )


class FusedPallasBackend:
    """Single-pass path: the whole block solve in one Pallas kernel.

    One HBM read of |W|, one bit-packed mask write; the fractional plan,
    Dykstra dual and capacity counters never leave VMEM.  Masks are
    bit-identical to ``dense-jit`` at ``config.tol = 0``; ``tol > 0``
    enables the kernel's adaptive early-exit fast mode.  ``solve_packed``
    skips the unpack and returns the (B, M) uint32 row words directly —
    the scheduler and cache consume these verbatim.
    """

    name = "pallas-fused"
    traceable = True

    def solve(self, w_abs_blocks, pattern, config):
        from repro.sparsity.bitpack import unpack_rows

        words = self.solve_packed(w_abs_blocks, pattern, config)
        return unpack_rows(words, pattern.m)

    def solve_packed(self, w_abs_blocks, pattern, config):
        from repro.kernels.fused_solve import ops as fused_ops
        from repro.perf.table import fused_solve_block_b

        # Trace-time tuning-table consult: a measured block-batch tile for
        # this device kind / group size overrides the vmem_plan default.
        # Blocks are independent, so the tile never changes the masks.
        words, _ = fused_ops.fused_solve(
            jnp.asarray(w_abs_blocks, jnp.float32), pattern.n,
            iters=config.iters, ls_steps=config.ls_steps,
            tau_scale=config.tau_scale, tol=config.tol,
            block_b=fused_solve_block_b(pattern.m),
        )
        return words


class GreedyBaselineBackend:
    """Hubara et al. 2-approximation: greedy insertion on |W| directly."""

    name = "greedy-baseline"
    traceable = True

    def solve(self, w_abs_blocks, pattern, config):
        return greedy_round(jnp.asarray(w_abs_blocks, jnp.float32), pattern.n)


class ExactBackend:
    """LP oracle per block (HiGHS).  Host-side numpy; not traceable."""

    name = "exact"
    traceable = False

    def solve(self, w_abs_blocks, pattern, config):
        from repro.core.exact import lp_exact

        blocks = np.asarray(w_abs_blocks, np.float64)
        if blocks.shape[0] == 0:
            return jnp.zeros(blocks.shape, bool)
        masks = np.stack([lp_exact(b, pattern.n)[0] for b in blocks])
        return jnp.asarray(masks)


register_backend(DenseJitBackend())
register_backend(PallasBackend())
register_backend(FusedPallasBackend())
register_backend(GreedyBaselineBackend())
register_backend(ExactBackend())
