"""Baseline transposable-mask methods the paper compares against (Sec. 5.1).

* 2-Approximation [Hubara et al. 2021]: greedy insertion directly on |W|.
* Bi-NM [Zhang et al. 2023]: row-wise N:M followed by column-wise N:M.
* MaxK ("Max1000"): best of K random feasible transposable masks.

All operate on (B, M, M) block batches and return boolean masks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.rounding import _cap_counts, greedy_round


def two_approx(w_abs_blocks: jnp.ndarray, n: int) -> jnp.ndarray:
    """Greedy on raw magnitudes — provably within 2x of optimal."""
    return greedy_round(w_abs_blocks, n)


@functools.partial(jax.jit, static_argnames=("n",))
def bi_nm(w_abs_blocks: jnp.ndarray, n: int) -> jnp.ndarray:
    """Row-wise N:M on W, then column-wise N:M on the row-masked W."""
    s = jnp.asarray(w_abs_blocks, jnp.float32)
    b, m, _ = s.shape
    # Row-wise top-N (per block row).
    r_rank = jnp.argsort(jnp.argsort(-s, axis=2), axis=2)
    m1 = r_rank < n
    masked = jnp.where(m1, s, -jnp.inf)
    # Column-wise top-N of survivors.
    c_rank = jnp.argsort(jnp.argsort(-masked, axis=1), axis=1)
    m2 = c_rank < n
    both = m1 & m2
    return _cap_counts(both, s, n)


@functools.partial(jax.jit, static_argnames=("n", "k"))
def max_k_random(
    key: jax.Array, w_abs_blocks: jnp.ndarray, n: int, k: int = 1000
) -> jnp.ndarray:
    """Best of K random feasible masks (the paper's "Max1000" baseline).

    A feasible transposable mask is produced by conjugating the circulant
    base pattern C[i, j] = ((i + j) mod M < N) — which has exactly N ones per
    row and column — with independent random row and column permutations.
    """
    s = jnp.asarray(w_abs_blocks, jnp.float32)
    b, m, _ = s.shape
    ar = jnp.arange(m)
    base = ((ar[:, None] + ar[None, :]) % m) < n  # (M, M), row/col sums == N

    def one_sample(key):
        kr, kc = jax.random.split(key)
        pr = jax.random.permutation(kr, m)  # row relabeling
        pc = jax.random.permutation(kc, m)  # col relabeling
        mask = base[pr][:, pc]
        return mask

    def best_for_block(key, w):
        keys = jax.random.split(key, k)
        masks = jax.vmap(one_sample)(keys)  # (K, M, M)
        vals = jnp.einsum("kij,ij->k", masks.astype(jnp.float32), w)
        return masks[jnp.argmax(vals)]

    keys = jax.random.split(key, b)
    return jax.vmap(best_for_block)(keys, s)
