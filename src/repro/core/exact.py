"""Exact oracles for the per-block transposable N:M problem.

Used by tests and the solution-quality benchmark (paper Figs. 3 & 6 report
relative error against the optimum).  Two oracles:

* ``brute_force`` — exhaustive enumeration, only for M <= 4.
* ``lp_exact`` — the LP relaxation (Eq. 3) solved with HiGHS; by the bipartite
  matching polytope integrality (Schrijver Ch. 18) the optimal *value* of the
  relaxation equals the integral optimum, and simplex returns a vertex, which
  is integral.  We assert near-integrality and round.

These run on CPU/numpy — they are oracles, not production paths.
"""
from __future__ import annotations

import itertools

import numpy as np


def brute_force(w_abs: np.ndarray, n: int) -> tuple[np.ndarray, float]:
    """Exhaustive search over row-wise N-subsets; feasible col sums filtered.

    Complexity C(M, N)^M — practical only for M <= 4.
    """
    w = np.asarray(w_abs, np.float64)
    m = w.shape[0]
    assert w.shape == (m, m) and m <= 6, "brute force limited to tiny blocks"
    row_choices = [np.array(c) for c in itertools.combinations(range(m), n)]
    best_val, best_mask = -1.0, None
    rows_as_masks = []
    for c in row_choices:
        v = np.zeros(m, bool)
        v[c] = True
        rows_as_masks.append(v)
    for combo in itertools.product(range(len(rows_as_masks)), repeat=m):
        mask = np.stack([rows_as_masks[i] for i in combo])
        if not np.all(mask.sum(0) == n):
            continue
        val = float((w * mask).sum())
        if val > best_val:
            best_val, best_mask = val, mask
    return best_mask, best_val


def lp_exact(w_abs: np.ndarray, n: int) -> tuple[np.ndarray, float]:
    """Solve the relaxation (Eq. 3) exactly with HiGHS simplex."""
    from scipy.optimize import linprog

    w = np.asarray(w_abs, np.float64)
    m = w.shape[0]
    # Variables S_ij flattened row-major; maximize <S, w> -> minimize -w.
    a_eq = np.zeros((2 * m, m * m))
    for i in range(m):
        a_eq[i, i * m : (i + 1) * m] = 1.0  # row sums
        a_eq[m + i, i::m] = 1.0  # col sums
    b_eq = np.full(2 * m, float(n))
    res = linprog(
        -w.ravel(), A_eq=a_eq, b_eq=b_eq, bounds=(0.0, 1.0), method="highs"
    )
    assert res.status == 0, res.message
    x = res.x.reshape(m, m)
    mask = x > 0.5
    # Vertex solutions of the transportation polytope are integral.
    frac = np.abs(x - mask.astype(np.float64)).max()
    assert frac < 1e-6, f"non-integral LP vertex (max frac {frac})"
    return mask, float(-res.fun)


def exact_block_values(w_abs_blocks: np.ndarray, n: int) -> np.ndarray:
    """Optimal objective value per block (B,) via the LP oracle."""
    return np.array([lp_exact(b, n)[1] for b in np.asarray(w_abs_blocks)])
