"""Rounding of fractional transport plans to feasible binary masks.

Implements Algorithm 2 of the paper: greedy selection over sorted entries with
row/column capacity counters, followed by swap-based local search (Eq. 6).
Both phases are vectorized across the whole block batch (paper Appendix A.2):
the greedy loop has M^2 iterations of O(B) fully-parallel work, and every
local-search step performs one batched gather + argmax over all blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n",))
def greedy_round(scores: jnp.ndarray, n: int) -> jnp.ndarray:
    """Greedy selection (Algorithm 2, lines 1-6), batched over blocks.

    Args:
      scores: (B, M, M) entries to round — either the fractional Dykstra
        solution (full TSENOR) or |W| directly (the 2-approximation baseline).
      n: row/column capacity N.

    Returns:
      (B, M, M) boolean mask with row and column sums <= N (== N except for
      blocks where greedy saturates prematurely; see local_search).
    """
    scores = jnp.asarray(scores)
    b, m, _ = scores.shape
    order = jnp.argsort(-scores.reshape(b, m * m), axis=1)  # (B, M^2) desc
    bidx = jnp.arange(b)

    def body(k, carry):
        mask, rc, cc = carry
        idx = order[:, k]
        r, c = idx // m, idx % m
        can = (rc[bidx, r] < n) & (cc[bidx, c] < n)
        mask = mask.at[bidx, r, c].set(mask[bidx, r, c] | can)
        inc = can.astype(jnp.int32)
        rc = rc.at[bidx, r].add(inc)
        cc = cc.at[bidx, c].add(inc)
        return mask, rc, cc

    mask0 = jnp.zeros((b, m, m), bool)
    cnt0 = jnp.zeros((b, m), jnp.int32)
    mask, _, _ = jax.lax.fori_loop(0, m * m, body, (mask0, cnt0, cnt0))
    return mask


@functools.partial(jax.jit, static_argnames=("n", "steps"))
def local_search(
    mask: jnp.ndarray, w_abs: jnp.ndarray, n: int, steps: int = 10
) -> jnp.ndarray:
    """Swap-based local search (Algorithm 2, lines 7-13), batched over blocks.

    For each block with an unsaturated row i and column j, evaluates
    Swap(i', j') = |W[i, j']| + |W[i', j]| - |W[i', j']| over all (i', j')
    with the paper's feasibility penalties (Eq. 6), and applies the best
    positive swap: insert (i, j') and (i', j), remove (i', j').

    The three touched cells are provably distinct whenever the swap is valid,
    so the batched scatter updates below never collide.

    The loop exits once a step applies no swap in any block: such a step
    recomputes the identical state next time around, so all remaining steps
    are no-ops and skipping them leaves the mask bit-identical.
    """
    w_abs = jnp.asarray(w_abs, jnp.float32)
    b, m, _ = mask.shape
    bidx = jnp.arange(b)

    def sweep(mask):
        rdef = mask.sum(2) < n  # (B, M) unsaturated rows
        cdef = mask.sum(1) < n  # (B, M) unsaturated cols
        i = jnp.argmax(rdef, axis=1)  # first deficit row per block
        j = jnp.argmax(cdef, axis=1)  # first deficit col per block
        need = rdef.any(1) & cdef.any(1)

        w_row_i = w_abs[bidx, i, :]  # (B, M): |W[i, j']|
        w_col_j = w_abs[bidx, :, j]  # (B, M): |W[i', j]|
        score = w_row_i[:, None, :] + w_col_j[:, :, None] - w_abs
        # Eq. 6 penalties: need S[i',j']=1 (removable), S[i,j']=0, S[i',j]=0.
        s_row_i = mask[bidx, i, :]
        s_col_j = mask[bidx, :, j]
        valid = mask & ~s_row_i[:, None, :] & ~s_col_j[:, :, None]
        score = jnp.where(valid, score, -jnp.inf)

        flat = score.reshape(b, m * m)
        k = jnp.argmax(flat, axis=1)
        smax = flat[bidx, k]
        ip, jp = k // m, k % m
        do = need & (smax > 0)

        mask = mask.at[bidx, ip, jp].set(jnp.where(do, False, mask[bidx, ip, jp]))
        mask = mask.at[bidx, ip, j].set(jnp.where(do, True, mask[bidx, ip, j]))
        mask = mask.at[bidx, i, jp].set(jnp.where(do, True, mask[bidx, i, jp]))
        return mask, jnp.any(do)

    def cond(carry):
        _, it, changed = carry
        return (it < steps) & changed

    def body(carry):
        mask, it, _ = carry
        mask, changed = sweep(mask)
        return mask, it + 1, changed

    mask, _, _ = jax.lax.while_loop(cond, body, (mask, jnp.int32(0), True))
    return mask


def round_blocks(
    s_approx: jnp.ndarray,
    w_abs: jnp.ndarray,
    n: int,
    ls_steps: int = 10,
) -> jnp.ndarray:
    """Full Algorithm 2: greedy on the fractional solution + local search.

    Local search scores use the *original* magnitudes |W| (the true objective),
    not the fractional solution.
    """
    mask = greedy_round(s_approx, n)
    if ls_steps > 0:
        mask = local_search(mask, w_abs, n, ls_steps)
    return mask


@functools.partial(jax.jit, static_argnames=("n",))
def simple_round(s_approx: jnp.ndarray, n: int) -> jnp.ndarray:
    """"Simple" rounding baseline (paper §B.2.1): row-wise top-N of the
    fractional solution, then column-wise top-N of the row-masked solution.
    Feasible in the <=N sense only."""
    b, m, _ = s_approx.shape
    # Row-wise top-N.
    row_thresh = -jnp.sort(-s_approx, axis=2)[:, :, n - 1 : n]
    m1 = s_approx >= row_thresh
    masked = jnp.where(m1, s_approx, -jnp.inf)
    # Column-wise top-N of the survivors.
    col_sorted = -jnp.sort(-masked, axis=1)
    col_thresh = col_sorted[:, n - 1 : n, :]
    m2 = masked >= col_thresh
    both = m1 & m2
    # Cap: top-N per column could tie; enforce <= N exactly via cumulative count.
    return _cap_counts(both, s_approx, n)


def _cap_counts(mask: jnp.ndarray, scores: jnp.ndarray, n: int) -> jnp.ndarray:
    """Enforce row/col sums <= n by dropping lowest-score surplus entries."""
    b, m, _ = mask.shape
    order = jnp.argsort(-jnp.where(mask, scores, -jnp.inf).reshape(b, m * m), axis=1)
    bidx = jnp.arange(b)

    def body(k, carry):
        out, rc, cc = carry
        idx = order[:, k]
        r, c = idx // m, idx % m
        keep = mask[bidx, r, c] & (rc[bidx, r] < n) & (cc[bidx, c] < n)
        out = out.at[bidx, r, c].set(keep)
        inc = keep.astype(jnp.int32)
        rc = rc.at[bidx, r].add(inc)
        cc = cc.at[bidx, c].add(inc)
        return out, rc, cc

    out0 = jnp.zeros_like(mask)
    cnt0 = jnp.zeros((b, m), jnp.int32)
    out, _, _ = jax.lax.fori_loop(0, m * m, body, (out0, cnt0, cnt0))
    return out
