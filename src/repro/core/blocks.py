"""Block partitioning utilities for transposable N:M sparsity.

The transposable N:M constraint acts independently on each M x M block of a
weight matrix (paper Sec. 3.1).  All solvers in this package therefore operate
on a batched tensor of shape (B, M, M); these helpers convert between the 2-D
weight-matrix view and the block-batch view, with zero-padding for matrices
whose dimensions are not multiples of M.
"""
from __future__ import annotations

import jax.numpy as jnp


def pad_to_multiple(w: jnp.ndarray, m: int) -> tuple[jnp.ndarray, tuple[int, int]]:
    """Zero-pad a 2-D matrix so both dims are multiples of ``m``.

    Returns the padded matrix and the original (rows, cols).  Padding with
    zeros is safe for mask search: zero-magnitude entries are never preferred
    over real entries by any of the solvers, and the mask is cropped back.
    """
    r, c = w.shape
    pr = (-r) % m
    pc = (-c) % m
    if pr or pc:
        w = jnp.pad(w, ((0, pr), (0, pc)))
    return w, (r, c)


def to_blocks(w: jnp.ndarray, m: int) -> jnp.ndarray:
    """(R, C) -> (B, M, M) with B = (R/M)*(C/M).  R, C must divide by M."""
    r, c = w.shape
    assert r % m == 0 and c % m == 0, (r, c, m)
    return (
        w.reshape(r // m, m, c // m, m)
        .transpose(0, 2, 1, 3)
        .reshape(-1, m, m)
    )


def from_blocks(blocks: jnp.ndarray, shape: tuple[int, int]) -> jnp.ndarray:
    """Inverse of :func:`to_blocks`; ``shape`` is the (padded) matrix shape."""
    r, c = shape
    m = blocks.shape[-1]
    return (
        blocks.reshape(r // m, c // m, m, m)
        .transpose(0, 2, 1, 3)
        .reshape(r, c)
    )


def crop(w: jnp.ndarray, shape: tuple[int, int]) -> jnp.ndarray:
    return w[: shape[0], : shape[1]]
