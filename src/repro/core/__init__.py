"""TSENOR core: transposable N:M mask solver (paper Sections 3.1-3.3)."""
from repro.patterns import PatternSpec
from repro.core.backends import (
    SolverBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.solver import (
    SolverConfig,
    solve_mask,
    transposable_nm_mask,
    solve_blocks,
    nm_mask,
    is_transposable_nm,
    objective,
    relative_error,
)
from repro.core.dykstra import dykstra_log
from repro.core.rounding import greedy_round, local_search, round_blocks, simple_round

__all__ = [
    "PatternSpec",
    "SolverBackend",
    "SolverConfig",
    "available_backends",
    "get_backend",
    "register_backend",
    "solve_mask",
    "transposable_nm_mask",
    "solve_blocks",
    "nm_mask",
    "is_transposable_nm",
    "objective",
    "relative_error",
    "dykstra_log",
    "greedy_round",
    "local_search",
    "round_blocks",
    "simple_round",
]
