"""TSENOR core: transposable N:M mask solver (paper Sections 3.1-3.3)."""
from repro.core.solver import (
    SolverConfig,
    transposable_nm_mask,
    solve_blocks,
    nm_mask,
    is_transposable_nm,
    objective,
    relative_error,
)
from repro.core.dykstra import dykstra_log
from repro.core.rounding import greedy_round, local_search, round_blocks, simple_round

__all__ = [
    "SolverConfig",
    "transposable_nm_mask",
    "solve_blocks",
    "nm_mask",
    "is_transposable_nm",
    "objective",
    "relative_error",
    "dykstra_log",
    "greedy_round",
    "local_search",
    "round_blocks",
    "simple_round",
]
