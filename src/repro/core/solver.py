"""TSENOR core: N:M mask generation for weight matrices.

Pipeline (paper Fig. 1):  partition into M x M blocks -> entropy-regularized
OT via Dykstra (Alg. 1) -> greedy + local-search rounding (Alg. 2) ->
reassemble.  Everything is batched over blocks; the actual per-block solve
is delegated to a pluggable :mod:`repro.core.backends` entry selected by
``SolverConfig.backend`` ("dense-jit" XLA default, "pallas" fused kernel,
"exact" LP oracle, "greedy-baseline" 2-approximation).

The canonical entry points are :func:`solve_mask` (one tensor, any
:class:`repro.patterns.PatternSpec`) and — for whole-model workloads —
``repro.service.MaskService.solve``.  ``transposable_nm_mask(w, n, m)`` is
kept as a deprecated shim.  The algorithm is documented in
``docs/solver_math.md``; dispatch and batching in ``docs/architecture.md``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax.numpy as jnp

from repro.core import blocks as blk
from repro.core.backends import get_backend
from repro.patterns import PatternSpec


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Hyper-parameters of the TSENOR solver (paper defaults).

    ``backend`` names a registered :class:`repro.core.backends.SolverBackend`.
    The deprecated ``use_kernel`` bool is still accepted and maps to
    ``backend="pallas"`` / ``"dense-jit"`` with a DeprecationWarning.
    """

    iters: int = 300          # Dykstra iterations T (upper bound when tol > 0)
    ls_steps: int = 10        # local-search steps L (upper bound; both the
    #                           XLA and fused paths exit once a step swaps
    #                           nothing — remaining steps are provable no-ops)
    tau_scale: float = 200.0  # tau = tau_scale / max|W| per block
    tol: float = 0.0          # adaptive Dykstra early exit: stop once the max
    #                           relative row/col marginal violation of the
    #                           pre-clamp iterate drops to <= tol.  0 (the
    #                           default) runs the fixed T loop and keeps masks
    #                           bit-identical to the historical solver.
    backend: str = "dense-jit"  # registered solver backend name
    block_batch: int = 0      # >0: process blocks in chunks of this size
    use_kernel: dataclasses.InitVar[Optional[bool]] = None  # deprecated

    def __post_init__(self, use_kernel):
        if use_kernel is not None:
            warnings.warn(
                "SolverConfig(use_kernel=...) is deprecated; use "
                "backend='pallas' (True) or backend='dense-jit' (False)",
                DeprecationWarning,
                stacklevel=3,
            )
            object.__setattr__(
                self, "backend", "pallas" if use_kernel else "dense-jit"
            )


def solve_mask(
    w: jnp.ndarray,
    pattern,
    config: SolverConfig = SolverConfig(),
) -> jnp.ndarray:
    """Compute an N:M mask for a 2-D weight/score matrix.

    Args:
      w: (R, C) weights; the objective uses |w|.  For transposable patterns
        R, C are zero-padded to multiples of M internally and the mask is
        cropped back.
      pattern: a :class:`PatternSpec` (or canonical string like ``"t2:4"``).
        Transposable patterns run the TSENOR block solver through
        ``config.backend``; standard patterns reduce to the row-wise top-N
        mask along axis 0.
      config: solver hyper-parameters.

    Returns:
      Boolean mask of the same shape as ``w``.
    """
    spec = PatternSpec.coerce(pattern)
    w = jnp.asarray(w)
    if not spec.transposable:
        return nm_mask(w, spec.n, spec.m, axis=0)
    w_abs = jnp.abs(w).astype(jnp.float32)
    padded, orig = blk.pad_to_multiple(w_abs, spec.m)
    blocks = blk.to_blocks(padded, spec.m)
    mask_blocks = solve_blocks(blocks, spec, config)
    mask = blk.from_blocks(mask_blocks, padded.shape)
    return blk.crop(mask, orig)


def transposable_nm_mask(
    w: jnp.ndarray,
    n: int,
    m: int,
    config: SolverConfig = SolverConfig(),
) -> jnp.ndarray:
    """Deprecated: use ``solve_mask(w, PatternSpec(n, m))`` (repro.api)."""
    warnings.warn(
        "transposable_nm_mask(w, n, m) is deprecated; use "
        "solve_mask(w, PatternSpec(n, m)) or MaskService.solve(w, pattern)",
        DeprecationWarning,
        stacklevel=2,
    )
    return solve_mask(w, PatternSpec(n, m, True), config)


def solve_blocks(
    w_abs_blocks: jnp.ndarray, pattern, config: SolverConfig = SolverConfig()
) -> jnp.ndarray:
    """Solve a (B, M, M) batch of block problems; returns boolean masks.

    ``pattern`` may be a :class:`PatternSpec` (``m`` must equal the block
    side) or a bare int N — the block side already fixes M, so an int is not
    a "loose tuple" and stays supported.
    """
    m = int(w_abs_blocks.shape[-1])
    if isinstance(pattern, int) and not isinstance(pattern, bool):
        spec = PatternSpec(pattern, m, True)
    else:
        spec = PatternSpec.coerce(pattern)
    if not spec.transposable:
        raise ValueError(
            "solve_blocks solves transposable patterns; use nm_mask for "
            "standard N:M"
        )
    if spec.m != m:
        raise ValueError(f"pattern {spec} does not match block side {m}")
    backend = get_backend(config.backend)
    total = w_abs_blocks.shape[0]
    bb = config.block_batch
    if bb and total > bb:
        outs = []
        for s in range(0, total, bb):
            chunk = w_abs_blocks[s : s + bb]
            pad = bb - chunk.shape[0]
            if pad:
                # Pad the ragged final chunk to the full block_batch so it
                # reuses the already-compiled program instead of triggering
                # one extra XLA compile; sentinel zero blocks are cropped
                # after the solve (blocks are independent).
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((pad, m, m), chunk.dtype)], axis=0
                )
            solved = backend.solve(chunk, spec, config)
            outs.append(solved[: bb - pad] if pad else solved)
        return jnp.concatenate(outs, axis=0)
    return backend.solve(w_abs_blocks, spec, config)


# ---------------------------------------------------------------------------
# Standard (non-transposable) N:M masks, used by the pruning baselines.
# ---------------------------------------------------------------------------


def nm_mask(w: jnp.ndarray, n: int, m: int, axis: int = 0) -> jnp.ndarray:
    """Standard N:M mask: keep the top-N of every M consecutive entries along
    ``axis`` (the reduction/input dimension of the matmul).

    Like ``solve_mask`` does for transposable patterns, a reduction dimension
    that is not a multiple of M is zero-padded and the mask cropped back:
    zero-magnitude padding never outranks a real entry (ties break toward the
    lower index, i.e. the real rows), so real entries keep priority and the
    partial final group simply keeps its top ``min(n, group size)`` entries.
    """
    w_abs = jnp.abs(jnp.asarray(w))
    if axis == 1:
        return nm_mask(w_abs.T, n, m, axis=0).T
    r, c = w_abs.shape
    pad = (-r) % m
    if pad:
        mask = nm_mask(jnp.pad(w_abs, ((0, pad), (0, 0))), n, m, axis=0)
        return mask[:r]
    g = w_abs.reshape(r // m, m, c)
    thresh = -jnp.sort(-g, axis=1)[:, n - 1 : n, :]
    # Tie-break: rank entries within the group and keep the first n.
    rank = jnp.argsort(jnp.argsort(-g, axis=1), axis=1)
    mask = (g >= thresh) & (rank < n)
    return mask.reshape(r, c)


# ---------------------------------------------------------------------------
# Verification / metrics helpers.
# ---------------------------------------------------------------------------


def block_row_col_sums(mask: jnp.ndarray, m: int):
    padded, _ = blk.pad_to_multiple(jnp.asarray(mask, jnp.int32), m)
    b = blk.to_blocks(padded, m)
    return b.sum(axis=2), b.sum(axis=1)


def is_transposable_nm(mask: jnp.ndarray, n: int, m: int, strict: bool = False) -> bool:
    """Check the transposable N:M property.  ``strict`` demands == N sums
    (only meaningful when both dims divide by M)."""
    rs, cs = block_row_col_sums(mask, m)
    if strict:
        return bool(jnp.all(rs == n) & jnp.all(cs == n))
    return bool(jnp.all(rs <= n) & jnp.all(cs <= n))


def objective(mask: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Paper objective f(S) = sum_ij S_ij |W_ij|."""
    return jnp.sum(jnp.where(mask, jnp.abs(w), 0.0))


def relative_error(mask: jnp.ndarray, w: jnp.ndarray, opt_value: jnp.ndarray) -> jnp.ndarray:
    """(f(S*) - f(S)) / f(S*) as reported in paper Figs. 3 & 6."""
    return (opt_value - objective(mask, w)) / opt_value
