"""TSENOR public API: transposable N:M mask generation for weight matrices.

Pipeline (paper Fig. 1):  partition into M x M blocks -> entropy-regularized
OT via Dykstra (Alg. 1) -> greedy + local-search rounding (Alg. 2) ->
reassemble.  Everything is batched over blocks and jit-compiled; the Pallas
kernel path (``use_kernel=True``) fuses the Dykstra iterations in VMEM.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import blocks as blk
from repro.core.dykstra import dykstra_log
from repro.core.rounding import greedy_round, local_search, round_blocks, simple_round


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Hyper-parameters of the TSENOR solver (paper defaults)."""

    iters: int = 300          # Dykstra iterations T
    ls_steps: int = 10        # local-search steps L
    tau_scale: float = 200.0  # tau = tau_scale / max|W| per block
    use_kernel: bool = False  # route Dykstra through the Pallas kernel
    block_batch: int = 0      # >0: process blocks in chunks of this size


def transposable_nm_mask(
    w: jnp.ndarray,
    n: int,
    m: int,
    config: SolverConfig = SolverConfig(),
) -> jnp.ndarray:
    """Compute a transposable N:M mask for a 2-D weight/score matrix.

    Args:
      w: (R, C) weights; the objective uses |w|.  R, C are zero-padded to
        multiples of ``m`` internally and the mask is cropped back.
      n, m: the N:M pattern; every M x M block of the mask has <= N (== N when
        achievable) ones per row and per column, so both the mask and its
        transpose are N:M sparse.
      config: solver hyper-parameters.

    Returns:
      Boolean mask of the same shape as ``w``.
    """
    w = jnp.asarray(w)
    w_abs = jnp.abs(w).astype(jnp.float32)
    padded, orig = blk.pad_to_multiple(w_abs, m)
    blocks = blk.to_blocks(padded, m)
    mask_blocks = solve_blocks(blocks, n, config)
    mask = blk.from_blocks(mask_blocks, padded.shape)
    return blk.crop(mask, orig)


def solve_blocks(
    w_abs_blocks: jnp.ndarray, n: int, config: SolverConfig = SolverConfig()
) -> jnp.ndarray:
    """Solve a (B, M, M) batch of block problems; returns boolean masks."""
    if config.block_batch and w_abs_blocks.shape[0] > config.block_batch:
        outs = []
        for s in range(0, w_abs_blocks.shape[0], config.block_batch):
            outs.append(
                _solve_blocks_jit(
                    w_abs_blocks[s : s + config.block_batch],
                    n,
                    config.iters,
                    config.ls_steps,
                    config.tau_scale,
                    config.use_kernel,
                )
            )
        return jnp.concatenate(outs, axis=0)
    return _solve_blocks_jit(
        w_abs_blocks, n, config.iters, config.ls_steps, config.tau_scale, config.use_kernel
    )


@functools.partial(
    jax.jit, static_argnames=("n", "iters", "ls_steps", "tau_scale", "use_kernel")
)
def _solve_blocks_jit(w_abs_blocks, n, iters, ls_steps, tau_scale, use_kernel):
    w_abs_blocks = jnp.asarray(w_abs_blocks, jnp.float32)
    scale = jnp.max(w_abs_blocks, axis=(1, 2), keepdims=True)
    tau = tau_scale / jnp.maximum(scale, 1e-30)
    if use_kernel:
        from repro.kernels.dykstra import ops as dykstra_ops

        s_approx = dykstra_ops.dykstra(w_abs_blocks * tau, n, iters)
    else:
        s_approx = dykstra_log(w_abs_blocks, n, iters, tau=tau)
    return round_blocks(s_approx, w_abs_blocks, n, ls_steps)


# ---------------------------------------------------------------------------
# Standard (non-transposable) N:M masks, used by the pruning baselines.
# ---------------------------------------------------------------------------


def nm_mask(w: jnp.ndarray, n: int, m: int, axis: int = 0) -> jnp.ndarray:
    """Standard N:M mask: keep the top-N of every M consecutive entries along
    ``axis`` (the reduction/input dimension of the matmul)."""
    w_abs = jnp.abs(jnp.asarray(w))
    if axis == 1:
        return nm_mask(w_abs.T, n, m, axis=0).T
    r, c = w_abs.shape
    assert r % m == 0, (r, m)
    g = w_abs.reshape(r // m, m, c)
    thresh = -jnp.sort(-g, axis=1)[:, n - 1 : n, :]
    # Tie-break: rank entries within the group and keep the first n.
    rank = jnp.argsort(jnp.argsort(-g, axis=1), axis=1)
    mask = (g >= thresh) & (rank < n)
    return mask.reshape(r, c)


# ---------------------------------------------------------------------------
# Verification / metrics helpers.
# ---------------------------------------------------------------------------


def block_row_col_sums(mask: jnp.ndarray, m: int):
    padded, _ = blk.pad_to_multiple(jnp.asarray(mask, jnp.int32), m)
    b = blk.to_blocks(padded, m)
    return b.sum(axis=2), b.sum(axis=1)


def is_transposable_nm(mask: jnp.ndarray, n: int, m: int, strict: bool = False) -> bool:
    """Check the transposable N:M property.  ``strict`` demands == N sums
    (only meaningful when both dims divide by M)."""
    rs, cs = block_row_col_sums(mask, m)
    if strict:
        return bool(jnp.all(rs == n) & jnp.all(cs == n))
    return bool(jnp.all(rs <= n) & jnp.all(cs <= n))


def objective(mask: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Paper objective f(S) = sum_ij S_ij |W_ij|."""
    return jnp.sum(jnp.where(mask, jnp.abs(w), 0.0))


def relative_error(mask: jnp.ndarray, w: jnp.ndarray, opt_value: jnp.ndarray) -> jnp.ndarray:
    """(f(S*) - f(S)) / f(S*) as reported in paper Figs. 3 & 6."""
    return (opt_value - objective(mask, w)) / opt_value
