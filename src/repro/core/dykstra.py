"""Entropy-regularized optimal-transport solver via Dykstra's algorithm.

Implements Algorithm 1 of the paper in log-space (paper Appendix A.2) over a
batch of M x M blocks.  Each block solves

    max_S  <S, |W|> + (1/tau) H(S)
    s.t.   S 1 = N 1,  S^T 1 = N 1,  0 <= S <= 1,

which is the KL/Bregman projection of exp(tau |W|) onto the intersection of
the row-marginal, column-marginal and capacity constraint sets.  Only the dual
variable of the capacity constraint needs to be tracked (Appendix A.1.1).

All operations are element-wise or row/column logsumexp reductions, fully
vectorized over the block batch — this is the paper's core "tensor-based"
design and maps directly onto the TPU VPU.  A fused Pallas kernel with the
same semantics lives in ``repro.kernels.dykstra``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _log_normalize(log_s: jnp.ndarray, axis: int, log_n: jnp.ndarray) -> jnp.ndarray:
    """KL projection onto {sum_axis exp(log_s) = N}, in log space."""
    lse = jax.scipy.special.logsumexp(log_s, axis=axis, keepdims=True)
    return log_s - lse + log_n


def marginal_violation(s: jnp.ndarray, n: int) -> jnp.ndarray:
    """Max relative row/col marginal violation of a fractional plan batch.

    ``max(|rowsum - N|, |colsum - N|) / N`` over every row and column of every
    block.  The early exit evaluates this on the iterate *after the column
    projection and before the capacity clamp*: there the column sums equal N
    exactly, so the row deviation measures how far the row/column
    normalizations are from mutual equilibrium — it decays geometrically,
    whereas the post-clamp iterate keeps a persistent deviation of the mass
    the clamp removes each sweep and never meets a tight tolerance.
    """
    nf = jnp.float32(n)
    row_dev = jnp.abs(jnp.sum(s, axis=2) - nf)
    col_dev = jnp.abs(jnp.sum(s, axis=1) - nf)
    return jnp.maximum(jnp.max(row_dev), jnp.max(col_dev)) / nf


@functools.partial(jax.jit, static_argnames=("n", "iters", "tol", "return_iters"))
def dykstra_log(
    w_abs: jnp.ndarray,
    n: int,
    iters: int = 300,
    tau: float | jnp.ndarray = None,
    tol: float = 0.0,
    return_iters: bool = False,
) -> jnp.ndarray:
    """Run Dykstra's algorithm on a batch of blocks.

    Args:
      w_abs: (B, M, M) non-negative scores (|W| or importance scores).
      n: target row/column sum N of the transposable N:M pattern.
      iters: number of Dykstra iterations (paper default T=300).
      tau: entropy regularization strength.  Defaults to the paper's rule
        tau = 5 / (0.005-quantile scale): we use tau such that
        tau * max|W| ~= 200, i.e. tau = 200 / max|W| per block — equivalent to
        the paper's 0.005*max|W| *temperature* (their tau multiplies |W|; a
        temperature of 0.005*max means tau = 1/(0.005*max) = 200/max).
      tol: adaptive early exit: stop once :func:`marginal_violation` of the
        whole batch drops to ``<= tol``.  ``tol=0`` (default) runs the fixed
        ``fori_loop`` — bit-identical to the historical behavior.
      return_iters: also return the number of iterations actually run (an
        int32 scalar; == ``iters`` when ``tol=0``).

    Returns:
      (B, M, M) fractional solution S in [0, 1] with row/col sums ~= N,
      plus the iteration count if ``return_iters``.
    """
    w_abs = jnp.asarray(w_abs, jnp.float32)
    b, m, _ = w_abs.shape
    if tau is None:
        scale = jnp.max(w_abs, axis=(1, 2), keepdims=True)
        tau = 200.0 / jnp.maximum(scale, 1e-30)
    log_n = jnp.log(jnp.asarray(n, jnp.float32))

    log_s0 = tau * w_abs
    log_q0 = jnp.zeros_like(log_s0)

    def normalized(log_s):
        # Projection onto C1 (row sums = N) then C2 (col sums = N).
        log_s = _log_normalize(log_s, axis=2, log_n=log_n)
        return _log_normalize(log_s, axis=1, log_n=log_n)

    def capacity(log_s, log_q):
        # Projection onto C3 (S <= 1) with dual update.
        log_tmp = log_s + log_q
        log_s = jnp.minimum(log_tmp, 0.0)
        return log_s, log_tmp - log_s

    if tol <= 0.0:
        log_s, _ = jax.lax.fori_loop(
            0, iters,
            lambda _, c: capacity(normalized(c[0]), c[1]),
            (log_s0, log_q0),
        )
        it = jnp.int32(iters)
    else:

        def cond(carry):
            _, _, it, viol = carry
            return (it < iters) & (viol > tol)

        def step(carry):
            log_s, log_q, it, _ = carry
            log_s = normalized(log_s)
            # Pre-clamp iterate: col sums == N exactly, so this is the full
            # marginal violation (see marginal_violation docstring).
            viol = marginal_violation(jnp.exp(log_s), n)
            log_s, log_q = capacity(log_s, log_q)
            return log_s, log_q, it + 1, viol

        log_s, _, it, _ = jax.lax.while_loop(
            cond, step, (log_s0, log_q0, jnp.int32(0), jnp.float32(jnp.inf))
        )
    if return_iters:
        return jnp.exp(log_s), it
    return jnp.exp(log_s)


def dykstra_reference(w_abs, n, iters=300, tau=None):
    """Non-log-space textbook implementation (Algorithm 1 verbatim).

    Used only in tests to cross-check the log-space version on well-scaled
    inputs; overflows for large tau by design.
    """
    w_abs = jnp.asarray(w_abs, jnp.float32)
    if tau is None:
        scale = jnp.max(w_abs, axis=(1, 2), keepdims=True)
        tau = 200.0 / jnp.maximum(scale, 1e-30)
    s = jnp.exp(tau * w_abs)
    q = jnp.ones_like(s)
    for _ in range(iters):
        s = s * (n / jnp.sum(s, axis=2, keepdims=True))
        s = s * (n / jnp.sum(s, axis=1, keepdims=True))
        tmp = s * q
        s_new = jnp.minimum(tmp, 1.0)
        q = tmp / jnp.maximum(s_new, 1e-30)
        s = s_new
    return s
