"""MaskService: submit/future front-end of the batched mask-solver engine.

Callers enqueue whole tensors (2-D, or scan-stacked 3-D as ONE submission)
and get back :class:`MaskHandle` futures; ``flush()`` drains the queue as a
handful of shape-bucketed mega-batches (see ``scheduler``), consulting the
content-addressed cache first and journaling every completion for resume.
``MaskService.solve(w, pattern)`` is the canonical synchronous solve path of
the whole codebase.

    service = MaskService(SolverConfig(iters=150), directory="runs/prune")
    handles = [service.submit(name, w, PatternSpec(2, 4)) for name, w in tensors]
    service.flush()                       # one bucketed solve for everything
    masks = {h.name: h.result() for h in handles}

    mask = service.solve(w, "t2:4")       # canonical one-shot solve

``result()`` on an unresolved handle flushes implicitly, so laziness is a
throughput optimization, never a correctness concern.  Everything is
single-process; the "service" boundary is the submit/flush API, which is
what a multi-tenant deployment would put behind an RPC layer.  Mega-batches
shard over all local devices (``BucketPolicy.shard_devices``).

Sequential solvers (SparseGPT's column-block sweep, ALPS's ADMM loop) feed
the same queue through the ``solve_plan`` protocol — see
:mod:`repro.pruning.plan` and ``docs/architecture.md`` — using
:meth:`MaskService.submit_many`/:meth:`MaskService.results` for per-sweep
batches; ``flush`` is re-entrant, so ``io_callback``-style solves that fire
mid-drain are folded into the active flush.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import warnings
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import ContentStore
from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec, pattern_from_args
from repro.service.cache import MaskCache, content_key
from repro.service.journal import Journal
from repro.service.scheduler import (
    BucketPolicy,
    StreamStats,
    blocks_to_mask,
    solve_stream,
    tensor_to_blocks,
)
from repro.sparsity import bitpack


class MaskHandle:
    """Future for one submitted tensor's transposable N:M mask.

    Resolved handles hold the mask in the bit-packed row-word form the
    solver pipeline produces (32x smaller than bool blocks); ``result()``
    unpacks on access.
    """

    def __init__(self, service: "MaskService", name: str, pattern: PatternSpec,
                 key: str, geom: dict, journal: bool = True):
        self.service = service
        self.name = name
        self.pattern = pattern
        self.key = key
        self.journal = journal
        self._geom = geom
        self._words: Optional[np.ndarray] = None
        # Identical in-flight submissions attach here instead of enqueueing
        # their blocks a second time; the primary's solve resolves them all.
        self._dups: list["MaskHandle"] = []

    @property
    def n(self) -> int:
        return self.pattern.n

    @property
    def m(self) -> int:
        return self.pattern.m

    @property
    def done(self) -> bool:
        return self._words is not None

    def _resolve(self, words: np.ndarray) -> None:
        self._words = words

    def mask_blocks(self) -> np.ndarray:
        """The solved (B, M, M) bool block stream (unpacked on access)."""
        assert self.done, f"{self.name!r} is not resolved"
        return bitpack.unpack_rows_np(self._words, self.pattern.m)

    def words(self) -> np.ndarray:
        """The solved mask as (B, M[, W]) uint32 bit-packed row words — the
        native solver/cache/wire format (``repro.sparsity.bitpack``).  The
        network front-end ships these verbatim: 32x less traffic than the
        bool mask ``result()`` materializes."""
        assert self.done, f"{self.name!r} is not resolved"
        return self._words

    def result(self) -> jnp.ndarray:
        """The solved bool mask, shaped like the submitted tensor."""
        if not self.done:
            self.service.flush()
        assert self.done, f"flush did not resolve {self.name!r}"
        return jnp.asarray(blocks_to_mask(self.mask_blocks(), self._geom))


class FlushTicket:
    """Completion future for one :meth:`MaskService.flush_async` drain."""

    def __init__(self):
        self._event = threading.Event()
        self._error: Optional[BaseException] = None
        self.seconds: float = 0.0  # background wall-clock of the drain

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the background flush finishes (re-raising anything it
        raised); returns False only on timeout."""
        ok = self._event.wait(timeout)
        if ok and self._error is not None:
            raise self._error
        return ok


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    cache_hits: int = 0
    dedup_hits: int = 0  # identical submission already in flight (no re-solve)
    journal_skips: int = 0  # resolved via a prior run's journal + store
    cache_evictions: int = 0  # disk entries GC'd by the cache_max_bytes bound
    cache_skips: int = 0  # entries not written to disk (cheaper to re-solve)
    solve_seconds: float = 0.0  # wall time inside solve_stream dispatches
    # Client-side resilience counters (repro.service.net): zero and inert
    # for an in-process service.
    retries: int = 0  # wire requests retried after a transport failure
    failovers: int = 0  # endpoint switches after a dead/rejecting endpoint
    resubmitted: int = 0  # in-flight requests re-sent after a reconnect
    degraded: bool = False  # fell back to a local in-process solver
    stream: StreamStats = dataclasses.field(default_factory=StreamStats)

    @property
    def blocks_solved(self) -> int:
        return self.stream.blocks_solved

    @property
    def batches(self) -> int:
        return self.stream.batches

    def summary(self) -> str:
        """One-line service report: submit/cache counters + the dispatch
        aggregate delegated to :meth:`StreamStats.summary` (the single
        padding-waste formatter — emitted once per run, not per stream)."""
        evict = (
            f" cache_evictions={self.cache_evictions}"
            if self.cache_evictions else ""
        )
        dedup = f" dedup_hits={self.dedup_hits}" if self.dedup_hits else ""
        skips = f" cache_skips={self.cache_skips}" if self.cache_skips else ""
        resil = ""
        if self.retries or self.failovers or self.degraded:
            resil = (
                f" retries={self.retries} failovers={self.failovers}"
                f"{' DEGRADED' if self.degraded else ''}"
            )
        return (
            f"submitted={self.submitted} cache_hits={self.cache_hits}"
            f"{dedup}{skips}{evict}{resil} {self.stream.summary()}"
        )

    def solve_blocks_per_sec(self) -> Optional[float]:
        """Observed solve throughput, or None before any dispatch — one of
        the two rates the auto cache-admission threshold compares."""
        if self.solve_seconds <= 0 or not self.stream.blocks_solved:
            return None
        return self.stream.blocks_solved / self.solve_seconds


class MaskService:
    """Batched, cached, journaled transposable N:M mask solver."""

    def __init__(
        self,
        config: SolverConfig = SolverConfig(),
        policy: Optional[BucketPolicy] = None,
        cache: Optional[MaskCache] = None,
        journal: Optional[Journal] = None,
        directory: Optional[str] = None,
        cache_max_bytes: Optional[int] = None,
        cache_min_blocks: Optional[int] = None,
    ):
        """``directory`` is the one-argument persistent setup: it wires a
        disk-backed cache (``<dir>/store``) and a completion journal
        (``<dir>/journal.jsonl``) unless explicit ones are passed.

        ``policy=None`` (the default) derives a VMEM-aware bucket ladder per
        pattern at flush time (:meth:`BucketPolicy.for_device`), informed by
        the padding waste this service has already observed; pass an explicit
        :class:`BucketPolicy` to pin one.

        ``cache_max_bytes`` bounds the disk cache: after every flush the
        store evicts least-recently-accessed entries past the bound
        (model-scale stores otherwise grow monotonically — every distinct
        tensor content is a new immutable entry).  ``None`` keeps the
        historical unbounded behavior.

        ``cache_min_blocks`` is the size-aware disk-admission floor: solved
        entries with fewer blocks than this are *not* written to the disk
        store (they stay in the in-memory front), because re-solving them
        costs less than reading them back.  ``None`` (default) derives the
        floor from observed rates — solve blocks/sec vs the store's measured
        per-entry read time (see :meth:`cache_admission_min_blocks`); ``0``
        admits everything (the historical behavior); any positive int pins
        the floor.  Skips are counted in ``ServiceStats.cache_skips``.
        """
        self.config = config
        self.policy = policy
        if directory is not None:
            if cache is None:
                cache = MaskCache(ContentStore(os.path.join(directory, "store")))
            if journal is None:
                journal = Journal(os.path.join(directory, "journal.jsonl"))
        self.cache = cache if cache is not None else MaskCache()
        self.journal = journal
        self.cache_max_bytes = cache_max_bytes
        self.cache_min_blocks = cache_min_blocks
        if cache_max_bytes is not None:
            self.cache.track_access = True  # mem hits count for the LRU
        self.stats = ServiceStats()
        self._pending: list[tuple[MaskHandle, np.ndarray]] = []
        # Queue/dedup state shared with the background-flush thread.
        self._lock = threading.RLock()
        # Serializes whole drains: a flush that finds another thread mid-
        # drain must WAIT for it (that drain resolves this thread's handles
        # too), not return early with its submissions still pending.
        # Reentrant so io_callback-style solves that flush mid-drain fold in.
        self._drain_lock = threading.RLock()
        self._inflight: dict[str, MaskHandle] = {}  # content key -> primary
        self._bg_thread: Optional[threading.Thread] = None

    # -- submit/future API --------------------------------------------------

    def submit(self, name: Optional[str], w, pattern=None, m=None, *,
               n=None, journal: bool = True) -> MaskHandle:
        """Enqueue one tensor (2-D, or stacked (L, R, C) as one submission).

        The mask objective uses |w|, so callers pass either raw weights or an
        importance matrix.  ``pattern`` is a :class:`PatternSpec` (or
        canonical string); the deprecated ``submit(name, w, n, m)`` form
        still works.  ``name=None`` derives a content-addressed name.
        ``journal=False`` skips the per-completion journal record (one
        fsync each) while keeping the content cache: the right setting for
        high-rate ephemeral requests like solve-plan sweeps, whose resume
        path is the cache, not the name.  Returns immediately; the solve
        happens at the next ``flush()`` (or lazily at ``result()``).
        """
        spec = pattern_from_args(pattern, m, None, n=n, caller="MaskService.submit")
        if not spec.transposable:
            raise ValueError(
                "MaskService solves transposable patterns; standard N:M masks "
                "are a cheap top-N (repro.core.solver.nm_mask)"
            )
        blocks, geom = tensor_to_blocks(w, spec.m)
        key = content_key(blocks, spec, self.config)
        if name is None:
            name = f"mask:{key[:12]}"
        handle = MaskHandle(self, name, spec, key, geom, journal=journal)
        # The whole probe-or-enqueue decision is one critical section: the
        # stats counters, the cache's in-memory front, the in-flight dedup
        # table and the pending queue must move together or concurrent
        # submitters lose increments / solve the same content twice.  (The
        # expensive work — abs/blocking/sha256 — already happened above,
        # outside the lock.)
        with self._lock:
            self.stats.submitted += 1
            disk_hits_before = self.cache.disk_hits
            cached = self.cache.get_packed(key)
            if cached is not None:
                if self.cache.disk_hits > disk_hits_before \
                        and journal and self.journal is not None \
                        and self.journal.lookup(name) is not None:
                    self.stats.journal_skips += 1
                self.stats.cache_hits += 1
                handle._resolve(cached[0])
                self._record(handle)
                return handle
            # In-flight dedup: a second submit of the same content key
            # before (or during) a flush rides the first one's solve —
            # without this, both copies solve and race to populate the
            # cache.  DST refresh makes this path hot: a re-submitted
            # snapshot after resume, or two layers sharing identical
            # weights, must cost one solve.
            primary = self._inflight.get(key)
            if primary is not None and not primary.done:
                primary._dups.append(handle)
                self.stats.dedup_hits += 1
                return handle
            self._inflight[key] = handle
            self._pending.append((handle, blocks))
        return handle

    def submit_many(self, items, pattern=None, *, n=None,
                    m=None) -> list[MaskHandle]:
        """Enqueue a batch of ``(name, w)`` pairs under one pattern.

        The batched-futures twin of :meth:`submit`: returns one
        :class:`MaskHandle` per item, in input order, without flushing —
        pair with :meth:`results` (or one :meth:`flush`) so the whole batch
        solves as a single bucketed mega-batch sequence.
        """
        spec = pattern_from_args(pattern, m, None, n=n,
                                 caller="MaskService.submit_many")
        return [self.submit(name, w, spec) for name, w in items]

    def results(self, handles) -> list[jnp.ndarray]:
        """Resolve a batch of handles with at most one flush.

        Flushes only if some handle is still pending, then returns every
        handle's mask in input order.  Handles from other services are
        rejected — their pending work lives in a different queue.
        """
        handles = list(handles)
        for h in handles:
            if h.service is not self:
                raise ValueError(
                    f"handle {h.name!r} belongs to a different MaskService"
                )
        if any(not h.done for h in handles):
            self.flush()
        return [h.result() for h in handles]

    def flush(self) -> None:
        """Solve every pending submission in shape-bucketed mega-batches.

        The whole drain runs bit-packed: mega-batches come back from the
        device as uint32 row words (32x less transfer), handles hold the
        words, and the cache stores them verbatim (format v3) — the mask is
        only ever unpacked on ``result()`` access.

        Re-entrant: submissions that arrive *while* the drain is running —
        an ``io_callback`` solve escaping a jitted loop, a solve-plan
        driver, a backend that itself consults the service — are folded
        into this same ``flush`` call (the drain loops until the queue is
        quiescent), so no caller ever returns from ``flush`` with work it
        enqueued still unsolved.

        Concurrent ``flush`` calls from *other* threads serialize on the
        drain lock: the later caller blocks until the active drain finishes
        (which resolves the later caller's handles too, since the drain
        loops until quiescent), then drains whatever arrived after — so no
        thread ever returns from ``flush`` with its own work still pending.
        """
        bg = self._bg_thread
        if bg is not None and bg is not threading.current_thread():
            bg.join()  # fold into (never race) an active background drain
        with self._drain_lock:
            self._drain()

    def _drain(self) -> None:
        wrote = False
        while True:
            with self._lock:
                if not self._pending:
                    break
                pending, self._pending = self._pending, []
            # One stream per pattern: block shape and the solver's static
            # args both depend on it.  Submission order is preserved within
            # a group.
            groups: dict[PatternSpec, list[tuple[MaskHandle, np.ndarray]]] = {}
            for handle, blocks in pending:
                groups.setdefault(handle.pattern, []).append((handle, blocks))
            for spec, entries in groups.items():
                policy = self.policy if self.policy is not None else \
                    BucketPolicy.for_device(spec.m, stats=self.stats.stream)
                t0 = time.monotonic()
                solved = solve_stream(
                    [blocks for _, blocks in entries],
                    spec,
                    self.config,
                    policy,
                    self.stats.stream,
                    packed=True,
                )
                self.stats.solve_seconds += time.monotonic() - t0
                for (handle, blocks), words in zip(entries, solved):
                    # Atomic wrt submit(): resolve + cache + drain the
                    # dedup followers before dropping the in-flight entry,
                    # so a racing identical submit either attaches to the
                    # primary or hits the cache — never re-solves.
                    nblocks = blocks.shape[0]
                    admit = nblocks >= self.cache_admission_min_blocks()
                    with self._lock:
                        handle._resolve(words)
                        self.cache.put_packed(
                            handle.key, words,
                            (nblocks, spec.m, spec.m),
                            disk=admit,
                        )
                        if not admit:
                            self.stats.cache_skips += 1
                        self._record(handle)
                        for dup in handle._dups:
                            dup._resolve(words)
                            self._record(dup)
                        handle._dups.clear()
                        if self._inflight.get(handle.key) is handle:
                            del self._inflight[handle.key]
                    wrote = True
        # Only GC when this flush actually grew the store: all-hit flushes
        # (and the per-sweep flushes of plan-routed solvers) skip the
        # O(entries) stat scan entirely.
        if wrote and self.cache_max_bytes is not None:
            self.stats.cache_evictions += len(
                self.cache.prune(self.cache_max_bytes)
            )

    def cache_admission_min_blocks(self) -> int:
        """Current disk-admission floor in blocks (entries below it skip the
        disk tier; the in-memory front always caches).

        With ``cache_min_blocks=None`` the floor is *derived*: an entry is
        worth persisting only if reading it back is faster than re-solving
        it, so the floor is ``solve_rate * read_seconds`` — the number of
        blocks whose solve time equals one observed store read.  Until both
        rates have been observed (no dispatch yet, or no disk read yet) the
        floor is 0 and everything is admitted.
        """
        if self.cache_min_blocks is not None:
            return self.cache_min_blocks
        read_s = self.cache.mean_read_seconds()
        rate = self.stats.solve_blocks_per_sec()
        if read_s is None or rate is None:
            return 0
        return int(rate * read_s)

    def flush_async(self) -> FlushTicket:
        """Drain the queue on a background thread; returns a
        :class:`FlushTicket` whose ``wait()`` joins the drain.

        This is the DST hot path (``repro.dst``): the trainer submits a
        mask refresh, keeps stepping while the solve runs here, and only
        ``wait()``s at the swap step — by which time the ticket is
        normally already done, so the trainer never stalls on ``flush``.
        Queue handoff is locked, so submissions racing the drain are
        either folded into it or left pending for the next flush; a
        synchronous :meth:`flush` (including the implicit one in
        ``result()``) first joins any background drain, so laziness stays
        a throughput optimization, never a correctness concern.  One
        background drain runs at a time; a second ``flush_async`` chains
        behind the first.
        """
        ticket = FlushTicket()
        prev = self._bg_thread

        def drain():
            t0 = time.monotonic()
            try:
                if prev is not None:
                    prev.join()
                self.flush()
            except BaseException as e:  # surfaced on ticket.wait()
                ticket._error = e
            finally:
                ticket.seconds = time.monotonic() - t0
                ticket._event.set()

        thread = threading.Thread(
            target=drain, name="mask-service-flush", daemon=True
        )
        # Start BEFORE publishing: a concurrent flush() that reads
        # _bg_thread must never join a not-yet-started thread.  If it reads
        # the previous value instead, the drain lock still serializes.
        thread.start()
        self._bg_thread = thread
        return ticket

    def solve(self, w, pattern=None, *legacy, name: Optional[str] = None,
              n=None, m=None) -> jnp.ndarray:
        """Canonical synchronous solve: submit + flush + result.

            mask = service.solve(w, PatternSpec(2, 4))       # or "t2:4"

        Args:
          w: 2-D weight/score matrix (or a scan-stacked 3-D tensor treated
            as one submission).  The solve objective uses ``|w|``.
          pattern: transposable :class:`~repro.patterns.PatternSpec` or
            canonical string like ``"t2:4"``.
          name: journal/debug name; content-addressed when omitted.

        Returns the boolean mask shaped like ``w``.  Bit-identical to
        :func:`repro.core.solver.solve_mask` under the same
        :class:`SolverConfig`; repeated solves of identical content are
        cache hits and never re-dispatch.  The deprecated
        ``solve(name, w, n, m)`` form still works.  See
        ``docs/architecture.md`` for how a solve travels through the
        scheduler, cache and backends.
        """
        if isinstance(w, str):  # legacy solve(name, w, n, m)
            warnings.warn(
                "MaskService.solve(name, w, n, m) is deprecated; use "
                "solve(w, pattern, name=...)",
                DeprecationWarning,
                stacklevel=2,
            )
            name, w = w, pattern
            if len(legacy) == 2:
                spec = PatternSpec(legacy[0], legacy[1], True)
            elif len(legacy) == 1:
                spec = PatternSpec.coerce(legacy[0])
            else:
                spec = PatternSpec(n, m, True)
        else:
            if legacy:
                raise TypeError("solve(w, pattern) takes no extra positionals")
            spec = pattern_from_args(pattern, m, None, n=n,
                                     caller="MaskService.solve")
        handle = self.submit(name, w, spec)
        self.flush()
        return handle.result()

    # -- internals ----------------------------------------------------------

    def _record(self, handle: MaskHandle) -> None:
        if self.journal is not None and handle.journal:
            prior = self.journal.lookup(handle.name)
            if prior is None or prior.get("key") != handle.key:
                self.journal.record(
                    handle.name, handle.key, n=handle.n, m=handle.m
                )
