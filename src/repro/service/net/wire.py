"""Length-prefixed frame codec for the mask-service wire protocol.

Stdlib only (the deployment constraint: a mask server must not drag the
training stack's dependency set onto an ops box).  A frame is::

    uint32 BE  frame_len                  # bytes that follow, <= MAX_FRAME
    uint32 BE  header_len
    bytes      header                     # UTF-8 JSON object
    bytes      blob_0 | blob_1 | ...      # raw ndarray payloads, contiguous

The header describes the operation plus every blob's dtype and shape under
the reserved ``"blobs"`` key (``[[dtype_str, [dims...]], ...]``), so the
receiver can reassemble the arrays with zero copies beyond the socket read.
Masks travel as the service's native bit-packed uint32 row words (32x
smaller than bool block masks); score/weight tensors travel as the float32
``|W|`` block streams the solver consumes — the exact bytes the content
cache hashes, which is what makes a remote submit share cache entries with
an in-process one.

The codec is symmetric (client and server use the same two functions) and
framing errors fail loudly: a length prefix beyond :data:`MAX_FRAME` or a
short read mid-frame raises :class:`WireError` rather than desynchronizing
the stream.
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Optional, Sequence

import numpy as np

PROTO_VERSION = 1
MAX_FRAME = 1 << 30  # 1 GiB: no single tensor the repo handles comes close
_U32 = struct.Struct(">I")


class WireError(RuntimeError):
    """Framing/protocol violation — the connection is unusable afterwards."""


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes; None on clean EOF at a frame boundary."""
    if n == 0:
        return b""
    chunks, got = [], 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None  # peer closed between frames: normal shutdown
            raise WireError(f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_frame(sock: socket.socket, header: dict,
               blobs: Sequence[np.ndarray] = ()) -> None:
    """Serialize ``header`` + ``blobs`` as one frame and send it."""
    arrays = [np.ascontiguousarray(b) for b in blobs]
    header = dict(header)
    header["blobs"] = [[a.dtype.str, list(a.shape)] for a in arrays]
    hbytes = json.dumps(header, separators=(",", ":")).encode()
    payload_len = _U32.size + len(hbytes) + sum(a.nbytes for a in arrays)
    if payload_len > MAX_FRAME:
        raise WireError(f"frame of {payload_len} bytes exceeds MAX_FRAME")
    parts = [_U32.pack(payload_len), _U32.pack(len(hbytes)), hbytes]
    parts.extend(a.tobytes() for a in arrays)
    sock.sendall(b"".join(parts))


def recv_frame(
    sock: socket.socket,
) -> Optional[tuple[dict, list[np.ndarray]]]:
    """Receive one frame; returns ``(header, blobs)`` or None on clean EOF."""
    prefix = _recv_exact(sock, _U32.size)
    if prefix is None:
        return None
    (payload_len,) = _U32.unpack(prefix)
    if payload_len > MAX_FRAME or payload_len < _U32.size:
        raise WireError(f"bad frame length {payload_len}")
    payload = _recv_exact(sock, payload_len)
    if payload is None:
        raise WireError("connection closed before frame payload")
    (header_len,) = _U32.unpack(payload[: _U32.size])
    body_start = _U32.size + header_len
    if body_start > len(payload):
        raise WireError(f"header length {header_len} overruns frame")
    try:
        header = json.loads(payload[_U32.size : body_start].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"undecodable frame header: {e}") from e
    if not isinstance(header, dict):
        raise WireError("frame header is not a JSON object")
    blobs: list[np.ndarray] = []
    off = body_start
    for dtype_str, shape in header.pop("blobs", []):
        dt = np.dtype(dtype_str)
        count = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = dt.itemsize * count
        if off + nbytes > len(payload):
            raise WireError("blob overruns frame payload")
        blobs.append(
            np.frombuffer(payload, dtype=dt, count=count, offset=off)
            .reshape(shape)
            .copy()  # detach from the frame buffer
        )
        off += nbytes
    if off != len(payload):
        raise WireError(f"{len(payload) - off} trailing bytes in frame")
    return header, blobs


def request(sock: socket.socket, header: dict,
            blobs: Sequence[np.ndarray] = ()) -> tuple[dict, list[np.ndarray]]:
    """One strict request/response exchange (the client's only pattern)."""
    send_frame(sock, header, blobs)
    reply = recv_frame(sock)
    if reply is None:
        raise WireError("server closed the connection mid-request")
    return reply
