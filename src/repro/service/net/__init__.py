"""Network front-end for the mask service: MaskServer + MaskClient.

See ``docs/architecture.md`` ("Mask service over the network") for the wire
format and tenant lifecycle, and ``docs/deploy.md`` for running a server.
"""
from repro.service.net.client import MaskClient, RemoteError, RemoteHandle
from repro.service.net.server import MaskServer, TenantConfig, TokenBucket
from repro.service.net.wire import MAX_FRAME, PROTO_VERSION, WireError

__all__ = [
    "MaskClient",
    "MaskServer",
    "RemoteError",
    "RemoteHandle",
    "TenantConfig",
    "TokenBucket",
    "WireError",
    "PROTO_VERSION",
    "MAX_FRAME",
]
