"""Network front-end for the mask service: MaskServer + MaskClient.

See ``docs/architecture.md`` ("Mask service over the network") for the wire
format and tenant lifecycle, and ``docs/deploy.md`` for running a server.
"""
from repro.service.net.client import MaskClient, RemoteError, RemoteHandle
from repro.service.net.faults import ChaosProxy
from repro.service.net.resilience import (
    NO_RETRY,
    RetryBudgetExceeded,
    RetryPolicy,
)
from repro.service.net.server import (
    MaskServer,
    RequestFailed,
    TenantConfig,
    TokenBucket,
)
from repro.service.net.wire import MAX_FRAME, PROTO_VERSION, WireError

__all__ = [
    "ChaosProxy",
    "MaskClient",
    "MaskServer",
    "NO_RETRY",
    "RemoteError",
    "RemoteHandle",
    "RequestFailed",
    "RetryBudgetExceeded",
    "RetryPolicy",
    "TenantConfig",
    "TokenBucket",
    "WireError",
    "PROTO_VERSION",
    "MAX_FRAME",
]
