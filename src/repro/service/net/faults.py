"""ChaosProxy: a fault-injecting TCP proxy for exercising mask-service
resilience.

Sits between a :class:`~.client.MaskClient` and a
:class:`~.server.MaskServer` and misbehaves on purpose::

    with ChaosProxy((server.host, server.port), seed=0,
                    kill_rate=0.05, torn_rate=0.02,
                    latency_s=0.002) as proxy:
        client = MaskClient(proxy.address, retry=RetryPolicy(seed=0))
        ...

Faults injected per forwarded chunk (all probabilities independent, drawn
from one seeded RNG so a chaos schedule replays deterministically):

* ``latency_s`` (+ uniform ``latency_jitter_s``) — delay before forwarding,
  modelling a slow or congested link;
* ``kill_rate`` — abruptly close both sides mid-stream (the client sees a
  reset / EOF mid-frame, i.e. :class:`~.wire.WireError` or
  :class:`OSError`);
* ``torn_rate`` — forward only a prefix of the chunk, then kill: a *torn
  frame*, the nastiest transport failure the length-prefixed codec must
  survive.

Control-plane methods drive scripted scenarios: :meth:`kill_connections`
(sever every live flow now), :meth:`blackhole` (swallow traffic without
closing, for timeout paths), and :meth:`retarget` (point future connections
at a different backend — how the chaos bench models a server that was
killed and restarted on a new port).  Counters (``connections``, ``killed``,
``torn``, ``forwarded_bytes``) feed the bench report.

Plain stdlib threads + sockets, one pump thread per direction per
connection: the proxy is a test/ops harness, not a data-plane component,
and at mask-service message rates the thread-per-flow model is nowhere near
its limits.
"""
from __future__ import annotations

import random
import socket
import threading
import time
from typing import Union

_CHUNK = 1 << 16


class ChaosProxy:
    """Fault-injecting TCP relay; see module docstring.

    Args:
      target: backend ``(host, port)`` or ``"host:port"``.
      host: interface to listen on (loopback by default).
      seed: seeds the fault RNG — same seed, same fault schedule.
      latency_s / latency_jitter_s: per-chunk forwarding delay (base +
        ``uniform(0, jitter)``).
      kill_rate: per-chunk probability of severing the connection whole.
      torn_rate: per-chunk probability of forwarding a partial chunk and
        then severing — a torn frame on the receiving side.
    """

    def __init__(
        self,
        target: Union[str, tuple],
        *,
        host: str = "127.0.0.1",
        seed: int = 0,
        latency_s: float = 0.0,
        latency_jitter_s: float = 0.0,
        kill_rate: float = 0.0,
        torn_rate: float = 0.0,
    ):
        if isinstance(target, str):
            t_host, _, t_port = target.rpartition(":")
            target = (t_host, int(t_port))
        self.target = (str(target[0]), int(target[1]))
        self.latency_s = float(latency_s)
        self.latency_jitter_s = float(latency_jitter_s)
        self.kill_rate = float(kill_rate)
        self.torn_rate = float(torn_rate)
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()
        self._lock = threading.Lock()  # pairs / counters / flags
        self._pairs: set[tuple[socket.socket, socket.socket]] = set()
        self._threads: list[threading.Thread] = []
        self._stopping = False
        self._blackhole = False
        # Counters (read them after stop() for a settled view).
        self.connections = 0
        self.killed = 0
        self.torn = 0
        self.swallowed_bytes = 0
        self.forwarded_bytes = 0
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, 0))
        self._listener.listen(64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="chaos-accept", daemon=True
        )
        self._accept_thread.start()

    @property
    def address(self) -> str:
        """``host:port`` to hand a :class:`~.client.MaskClient`."""
        return f"{self.host}:{self.port}"

    # -- control plane ------------------------------------------------------

    def retarget(self, target: Union[str, tuple]) -> None:
        """Point *future* connections at a different backend (live flows are
        untouched — pair with :meth:`kill_connections` to force a re-dial).
        Models a backend restarted on a new port behind a stable address."""
        if isinstance(target, str):
            t_host, _, t_port = target.rpartition(":")
            target = (t_host, int(t_port))
        with self._lock:
            self.target = (str(target[0]), int(target[1]))

    def kill_connections(self) -> int:
        """Sever every live flow right now; returns how many died."""
        with self._lock:
            pairs = list(self._pairs)
        for pair in pairs:
            self._sever(pair)
        return len(pairs)

    def blackhole(self, on: bool = True) -> None:
        """Swallow traffic instead of forwarding (connections stay open —
        the receiver just never hears anything: the timeout failure mode,
        as opposed to the reset one)."""
        with self._lock:
            self._blackhole = on

    def stop(self) -> None:
        with self._lock:
            if self._stopping:
                return
            self._stopping = True
        self._slam(self._listener)  # close() alone cannot wake accept()
        self.kill_connections()
        self._accept_thread.join(timeout=5)
        for t in self._threads:
            t.join(timeout=5)

    def __enter__(self) -> "ChaosProxy":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- data plane ----------------------------------------------------------

    def _accept_loop(self) -> None:
        while True:
            try:
                downstream, _ = self._listener.accept()
            except OSError:
                return  # listener closed: stopping
            with self._lock:
                target = self.target
                self.connections += 1
            try:
                upstream = socket.create_connection(target, timeout=10)
            except OSError:
                downstream.close()
                continue
            for s in (downstream, upstream):
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            pair = (downstream, upstream)
            with self._lock:
                self._pairs.add(pair)
            for src, dst in ((downstream, upstream), (upstream, downstream)):
                t = threading.Thread(
                    target=self._pump, args=(src, dst, pair),
                    name="chaos-pump", daemon=True,
                )
                t.start()
                with self._lock:
                    self._threads.append(t)

    @staticmethod
    def _slam(sock: socket.socket) -> None:
        """Tear a socket down NOW: ``shutdown`` (not just ``close``) sends
        the FIN/RST immediately and wakes any thread blocked in ``recv`` on
        it — a bare ``close`` under a concurrent ``recv`` defers the actual
        teardown until the syscall returns, which can strand the peer."""
        try:
            sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            sock.close()
        except OSError:
            pass

    def _sever(self, pair) -> None:
        with self._lock:
            if pair not in self._pairs:
                return
            self._pairs.discard(pair)
            self.killed += 1
        for s in pair:
            self._slam(s)

    def _pump(self, src: socket.socket, dst: socket.socket, pair) -> None:
        while True:
            try:
                chunk = src.recv(_CHUNK)
            except OSError:
                break
            if not chunk:
                break
            with self._rng_lock:
                kill = self._rng.random() < self.kill_rate
                tear = (not kill) and self._rng.random() < self.torn_rate
                jitter = (
                    self._rng.uniform(0, self.latency_jitter_s)
                    if self.latency_jitter_s > 0 else 0.0
                )
            if self.latency_s > 0 or jitter > 0:
                time.sleep(self.latency_s + jitter)
            with self._lock:
                swallow = self._blackhole
            if swallow:
                with self._lock:
                    self.swallowed_bytes += len(chunk)
                continue
            if kill:
                self._sever(pair)
                break
            if tear:
                cut = max(1, len(chunk) // 2)
                try:
                    dst.sendall(chunk[:cut])
                except OSError:
                    pass
                with self._lock:
                    self.torn += 1
                    self.forwarded_bytes += cut
                self._sever(pair)
                break
            try:
                dst.sendall(chunk)
            except OSError:
                break
            with self._lock:
                self.forwarded_bytes += len(chunk)
        # One side done (EOF or fault): drop the whole flow.  Half-open
        # relays are not worth modelling for a strict request/response
        # protocol.
        with self._lock:
            live = pair in self._pairs
            if live:
                self._pairs.discard(pair)
        if live:
            for s in pair:
                self._slam(s)
