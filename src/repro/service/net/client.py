"""MaskClient: wire-compatible drop-in for :class:`MaskService`.

The client implements the same submit / submit_many / flush / flush_async /
results / solve surface as the in-process engine, so every consumer of the
service seam — ``prune_transformer(service=...)``, the ``solve_plan``
lockstep driver, the DST :class:`~repro.dst.controller.MaskRefreshController`
— runs unchanged against a remote solver:

    with MaskClient("solver-box:7463", tenant="team-a") as svc:
        report = prune_transformer(params, cfg, "t2:4", service=svc)

Division of labor (and why results are bit-identical to local solves): the
client runs the *cheap, deterministic* front half of ``MaskService.submit``
locally — ``tensor_to_blocks`` + content key over the float32 ``|W|`` block
stream, using the :class:`SolverConfig` the server advertises in its hello
reply — and ships the block stream itself.  The server feeds those exact
bytes to its inner engine, which re-derives the *same* content key (abs is
idempotent and re-blocking a (B, M, M) stream is the identity), so remote
and in-process submits of the same tensor share one cache entry, and the
mask that comes back (bit-packed uint32 row words, 32x smaller than bool)
is the same array of bits a local ``MaskService.solve`` would produce.

Client-side economics mirror the engine: a local content-keyed memory cache
resolves repeat submits without touching the network, and in-flight dedup
collapses identical concurrent submissions to one wire request.  Submits go
out eagerly on a pooled connection (the server starts batching/solving
while the caller keeps submitting); ``flush()`` is the wait barrier.
Thread-safety contract matches the engine: submits may race freely,
flushes serialize on a drain lock, ``flush_async`` chains on one
background thread.
"""
from __future__ import annotations

import itertools
import os
import socket
import threading
from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec, pattern_from_args
from repro.service.cache import content_key
from repro.service.engine import FlushTicket, MaskHandle, ServiceStats
from repro.service.net import wire
from repro.service.scheduler import tensor_to_blocks


class RemoteError(RuntimeError):
    """The server replied ``ok: false`` (validation, solve, or tenant
    error).  Framing-level failures raise :class:`wire.WireError` instead."""


class RemoteHandle(MaskHandle):
    """Future for one tensor submitted over the wire.

    Same surface as :class:`MaskHandle` (``result``/``mask_blocks``/
    ``words``/``done``); ``result()`` on an unresolved handle flushes the
    owning client.  Extra observability: ``server_latency_s`` (enqueue ->
    solve wall time inside the server) and ``server_cached`` (resolved from
    the server's shared cache tier), both None until resolved over the wire
    and for locally-resolved (client cache / dedup) handles.
    """

    def __init__(self, client: "MaskClient", name: str, pattern: PatternSpec,
                 key: str, geom: dict, rid: str, journal: bool = True):
        super().__init__(client, name, pattern, key, geom, journal=journal)
        self.id = rid
        self.server_latency_s: Optional[float] = None
        self.server_cached: Optional[bool] = None
        self._error: Optional[BaseException] = None

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        for dup in self._dups:
            dup._error = exc
        self._dups.clear()

    def result(self) -> jnp.ndarray:
        if self._error is not None:
            raise self._error
        return super().result()


class MaskClient:
    """TCP client for a :class:`~repro.service.net.server.MaskServer`.

    Args:
      address: ``"host:port"`` (or a ``(host, port)`` tuple).
      tenant: tenant name sent in the hello; scheduling quota and rate
        limits are per-tenant (see :class:`TenantConfig`).
      timeout: per-operation socket timeout in seconds.  None (default)
        blocks indefinitely — correct for ``flush`` barriers over large
        solves; set it for fail-fast health checks.
      local_cache: keep a client-side content-keyed memory cache of solved
        words so repeat submits of identical tensors skip the network
        entirely (counted in ``stats.cache_hits``, exactly like the
        engine's memory front).

    ``stats`` is a real :class:`ServiceStats` tracking the *client-side*
    counters (submitted / cache_hits / dedup_hits); solver-side aggregates
    live on the server — fetch them with :meth:`server_stats`.
    """

    def __init__(
        self,
        address: Union[str, tuple[str, int]],
        tenant: str = "default",
        *,
        timeout: Optional[float] = None,
        connect_timeout: float = 10.0,
        local_cache: bool = True,
    ):
        if isinstance(address, str):
            host, _, port = address.rpartition(":")
            if not host:
                raise ValueError(
                    f"address must be 'host:port', got {address!r}"
                )
            self.host, self.port = host, int(port)
        else:
            self.host, self.port = address[0], int(address[1])
        self.tenant = tenant
        self.timeout = timeout
        self.connect_timeout = connect_timeout
        self.local_cache = local_cache
        self.stats = ServiceStats()
        self._lock = threading.RLock()  # outstanding/dedup/cache/stats
        self._drain_lock = threading.RLock()  # serializes whole flushes
        self._pool: list[socket.socket] = []
        self._pool_lock = threading.Lock()
        self._bg_thread: Optional[threading.Thread] = None
        self._outstanding: dict[str, RemoteHandle] = {}  # id -> primary
        self._inflight: dict[str, RemoteHandle] = {}  # content key -> primary
        self._mem: dict[str, np.ndarray] = {}  # content key -> words
        self._ids = itertools.count()
        self._cid = f"{os.getpid():x}-{id(self) & 0xFFFFFF:x}"
        self._closed = False
        self.config: Optional[SolverConfig] = None
        self.server_name: Optional[str] = None
        self.quota: Optional[float] = None
        # Dial eagerly: submit() needs the server's SolverConfig for content
        # keys, and failing here beats failing mid-prune.
        self._checkin(self._dial())

    # -- connection pool ----------------------------------------------------

    def _dial(self) -> socket.socket:
        sock = socket.create_connection(
            (self.host, self.port), timeout=self.connect_timeout
        )
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(self.timeout)
        try:
            reply, _ = wire.request(sock, {
                "op": "hello",
                "proto": wire.PROTO_VERSION,
                "tenant": self.tenant,
            })
        except BaseException:
            sock.close()
            raise
        if not reply.get("ok"):
            sock.close()
            raise RemoteError(f"hello rejected: {reply.get('error')}")
        if self.config is None:
            self.config = SolverConfig(**reply["config"])
            self.server_name = reply.get("server")
            self.quota = reply.get("quota")
        return sock

    def _checkout(self) -> socket.socket:
        if self._closed:
            raise RuntimeError("MaskClient is closed")
        with self._pool_lock:
            if self._pool:
                return self._pool.pop()
        return self._dial()

    def _checkin(self, sock: socket.socket) -> None:
        with self._pool_lock:
            if not self._closed:
                self._pool.append(sock)
                return
        sock.close()

    def _request(self, header: dict, blobs=()) -> tuple[dict, list]:
        """One pooled request/response; not-ok replies raise
        :class:`RemoteError` (the connection stays usable — the reply frame
        arrived intact), transport failures discard the connection."""
        sock = self._checkout()
        try:
            reply, rblobs = wire.request(sock, header, blobs)
        except BaseException:
            sock.close()
            raise
        self._checkin(sock)
        if not reply.get("ok"):
            raise RemoteError(
                f"{reply.get('kind', 'error')}: {reply.get('error')}"
            )
        return reply, rblobs

    # -- MaskService surface ------------------------------------------------

    def submit(self, name: Optional[str], w, pattern=None, m=None, *,
               n=None, journal: bool = True) -> RemoteHandle:
        """Enqueue one tensor on the remote solver; returns a future.

        Same contract as :meth:`MaskService.submit` — transposable patterns
        only, ``name=None`` derives a content-addressed name, ``journal``
        controls the server-side journal record (written under
        ``"<tenant>:<name>"``).  The block stream goes out on the wire
        immediately unless the client's memory cache or in-flight dedup
        resolves it locally.
        """
        spec = pattern_from_args(pattern, m, None, n=n,
                                 caller="MaskClient.submit")
        handle, payload = self._prepare(name, w, spec, journal)
        if payload is not None:
            self._wire_submit([handle], [payload])
        return handle

    def submit_many(self, items, pattern=None, *, n=None,
                    m=None) -> list[RemoteHandle]:
        """Enqueue ``(name, w)`` pairs under one pattern — a single wire
        frame for everything the local cache/dedup does not absorb, so a
        per-sweep solve-plan batch costs one round trip."""
        spec = pattern_from_args(pattern, m, None, n=n,
                                 caller="MaskClient.submit_many")
        handles, send_handles, send_blobs = [], [], []
        for name, w in items:
            handle, payload = self._prepare(name, w, spec, True)
            handles.append(handle)
            if payload is not None:
                send_handles.append(handle)
                send_blobs.append(payload)
        if send_handles:
            self._wire_submit(send_handles, send_blobs)
        return handles

    def _prepare(self, name, w, spec: PatternSpec, journal: bool):
        """Local half of a submit: block, key, probe cache/dedup.  Returns
        ``(handle, blocks-or-None)``; None means resolved locally."""
        if not spec.transposable:
            raise ValueError(
                "MaskService solves transposable patterns; standard N:M "
                "masks are a cheap top-N (repro.core.solver.nm_mask)"
            )
        assert self.config is not None
        blocks, geom = tensor_to_blocks(w, spec.m)
        key = content_key(blocks, spec, self.config)
        if name is None:
            name = f"mask:{key[:12]}"
        rid = f"{self._cid}-{next(self._ids)}"
        handle = RemoteHandle(self, name, spec, key, geom, rid,
                              journal=journal)
        with self._lock:
            self.stats.submitted += 1
            words = self._mem.get(key)
            if words is not None:
                self.stats.cache_hits += 1
                handle._resolve(words)
                return handle, None
            primary = self._inflight.get(key)
            if primary is not None and not primary.done:
                primary._dups.append(handle)
                self.stats.dedup_hits += 1
                return handle, None
            self._inflight[key] = handle
            self._outstanding[rid] = handle
        return handle, blocks

    def _wire_submit(self, handles: list[RemoteHandle], blobs) -> None:
        header = {
            "op": "submit",
            "reqs": [
                {"id": h.id, "name": h.name, "pattern": h.pattern.canonical,
                 "journal": h.journal}
                for h in handles
            ],
        }
        try:
            self._request(header, blobs)
        except BaseException as e:
            # The server never saw (or rejected) these: fail the handles and
            # their dedup followers so result() reports the cause instead of
            # a flush hanging on ids the server does not know.
            with self._lock:
                for h in handles:
                    self._outstanding.pop(h.id, None)
                    if self._inflight.get(h.key) is h:
                        del self._inflight[h.key]
                    h._fail(e)
            raise

    def flush(self) -> None:
        """Barrier: block until every outstanding submission is solved and
        resolved into its handle.

        Folds in any active :meth:`flush_async` drain first, then waits on
        the server (which is free to batch this tenant's queue with other
        tenants' into shared mega-batches).  Concurrent flushes serialize;
        submissions racing the flush are drained by the next one, same as
        the engine.
        """
        bg = self._bg_thread
        if bg is not None and bg is not threading.current_thread():
            bg.join()
        with self._drain_lock:
            while True:
                with self._lock:
                    ids = [rid for rid, h in self._outstanding.items()
                           if not h.done]
                if not ids:
                    return
                reply, blobs = self._request({"op": "wait", "ids": ids})
                lat = reply.get("lat") or [None] * len(ids)
                cached = reply.get("cached") or [None] * len(ids)
                with self._lock:
                    for rid, words, t, hit in zip(
                        reply["ids"], blobs, lat, cached
                    ):
                        handle = self._outstanding.pop(rid, None)
                        if handle is None:
                            continue
                        handle.server_latency_s = t
                        handle.server_cached = hit
                        handle._resolve(words)
                        for dup in handle._dups:
                            dup._resolve(words)
                        handle._dups.clear()
                        if self._inflight.get(handle.key) is handle:
                            del self._inflight[handle.key]
                        if self.local_cache:
                            self._mem[handle.key] = words

    def flush_async(self) -> FlushTicket:
        """Background flush; returns the engine's :class:`FlushTicket`.
        The DST refresh controller calls this verbatim — the solve runs on
        the server while the trainer keeps stepping locally."""
        ticket = FlushTicket()
        prev = self._bg_thread

        def drain():
            import time as _time
            t0 = _time.monotonic()
            try:
                if prev is not None:
                    prev.join()
                self.flush()
            except BaseException as e:  # surfaced on ticket.wait()
                ticket._error = e
            finally:
                ticket.seconds = _time.monotonic() - t0
                ticket._event.set()

        thread = threading.Thread(
            target=drain, name="mask-client-flush", daemon=True
        )
        # Start BEFORE publishing (same reasoning as MaskService.flush_async:
        # a concurrent flush() must never join a not-yet-started thread).
        thread.start()
        self._bg_thread = thread
        return ticket

    def results(self, handles) -> list[jnp.ndarray]:
        """Resolve a batch of handles with at most one flush (same contract
        as :meth:`MaskService.results`)."""
        handles = list(handles)
        for h in handles:
            if h.service is not self:
                raise ValueError(
                    f"handle {h.name!r} belongs to a different MaskService"
                )
        if any(not h.done for h in handles):
            self.flush()
        return [h.result() for h in handles]

    def solve(self, w, pattern=None, *, name: Optional[str] = None,
              n=None, m=None) -> jnp.ndarray:
        """Synchronous remote solve: submit + flush + result.  Bit-identical
        to ``MaskService.solve`` on the server's config (property-tested in
        ``tests/test_net.py``)."""
        spec = pattern_from_args(pattern, m, None, n=n,
                                 caller="MaskClient.solve")
        handle = self.submit(name, w, spec)
        self.flush()
        return handle.result()

    # -- server ops ---------------------------------------------------------

    def ping(self) -> bool:
        reply, _ = self._request({"op": "ping"})
        return bool(reply.get("ok"))

    def server_stats(self) -> dict:
        """The server's live snapshot: inner-service counters plus the
        per-tenant scheduling/cache rows (see ``MaskServer.stats``)."""
        reply, _ = self._request({"op": "stats"})
        return {k: v for k, v in reply.items() if k != "ok"}

    def shutdown_server(self) -> None:
        """Ask the server to stop (works only with
        ``allow_remote_shutdown``); the connection is not reusable after."""
        sock = self._checkout()
        try:
            reply, _ = wire.request(sock, {"op": "shutdown"})
        finally:
            sock.close()
        if not reply.get("ok"):
            raise RemoteError(f"shutdown rejected: {reply.get('error')}")

    def close(self) -> None:
        with self._pool_lock:
            self._closed = True
            pool, self._pool = self._pool, []
        for sock in pool:
            try:
                sock.close()
            except OSError:
                pass

    def __enter__(self) -> "MaskClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
