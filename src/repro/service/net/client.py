"""MaskClient: wire-compatible, fault-tolerant drop-in for :class:`MaskService`.

The client implements the same submit / submit_many / flush / flush_async /
results / solve surface as the in-process engine, so every consumer of the
service seam — ``prune_transformer(service=...)``, the ``solve_plan``
lockstep driver, the DST :class:`~repro.dst.controller.MaskRefreshController`
— runs unchanged against a remote solver:

    with MaskClient("solver-box:7463", tenant="team-a") as svc:
        report = prune_transformer(params, cfg, "t2:4", service=svc)

Division of labor (and why results are bit-identical to local solves): the
client runs the *cheap, deterministic* front half of ``MaskService.submit``
locally — ``tensor_to_blocks`` + content key over the float32 ``|W|`` block
stream, using the :class:`SolverConfig` the server advertises in its hello
reply — and ships the block stream itself.  The server feeds those exact
bytes to its inner engine, which re-derives the *same* content key (abs is
idempotent and re-blocking a (B, M, M) stream is the identity), so remote
and in-process submits of the same tensor share one cache entry, and the
mask that comes back (bit-packed uint32 row words, 32x smaller than bool)
is the same array of bits a local ``MaskService.solve`` would produce.

Fault tolerance rides on that determinism.  Every request is idempotent
(content-addressed solves; duplicate request ids are absorbed server-side),
so the client may retry *anything* that failed at the transport level:

* **retry** — transport failures (:class:`OSError`, :class:`WireError`) and
  transient server rejections (``overloaded``/``draining``/``deadline``,
  which carry a ``retry_after`` hint) re-run under a
  :class:`~.resilience.RetryPolicy` (exponential backoff, decorrelated
  jitter, attempt + deadline budget);
* **failover** — ``MaskClient(["a:7463", "b:7463"])`` rotates through
  endpoints when one stops answering; endpoints must share a
  ``SolverConfig`` (checked at hello — a mismatched box is skipped, since
  its masks would not be bit-identical);
* **re-submission** — submitted block streams are retained until their
  handles resolve, so after a reconnect (or a server restart that lost its
  queue) the client re-ships every in-flight request; the server dedupes
  ids it still knows and re-solves content it lost, bit-identically;
* **degraded local fallback** — when every endpoint stays down past the
  retry budget, the client builds an in-process ``MaskService`` from the
  advertised ``SolverConfig`` and completes outstanding work locally
  (bit-identical by construction), flagging ``stats.degraded`` so the run
  is observable as degraded rather than silently slow.

Client-side economics mirror the engine: a local content-keyed memory cache
resolves repeat submits without touching the network, and in-flight dedup
collapses identical concurrent submissions to one wire request.  Submits go
out eagerly on a pooled connection (the server starts batching/solving
while the caller keeps submitting); ``flush()`` is the wait barrier.
Thread-safety contract matches the engine: submits may race freely,
flushes serialize on a drain lock, ``flush_async`` chains on one
background thread.
"""
from __future__ import annotations

import itertools
import logging
import os
import socket
import threading
from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec, pattern_from_args
from repro.service.cache import content_key
from repro.service.engine import (
    FlushTicket,
    MaskHandle,
    MaskService,
    ServiceStats,
)
from repro.service.net import wire
from repro.service.net.resilience import (
    TRANSIENT_KINDS,
    RetryBudgetExceeded,
    RetryPolicy,
)
from repro.service.scheduler import tensor_to_blocks

logger = logging.getLogger(__name__)


class RemoteError(RuntimeError):
    """The server replied ``ok: false`` (validation, solve, or tenant
    error).  Framing-level failures raise :class:`wire.WireError` instead.

    ``kind`` is the server's structured error class (exception type name,
    or a resilience kind like ``overloaded``/``draining``/``deadline``/
    ``unknown-ids``); ``retry_after`` is its backoff hint in seconds, when
    one was sent.
    """

    def __init__(self, msg: str, kind: str = "error",
                 retry_after: Optional[float] = None):
        super().__init__(msg)
        self.kind = kind
        self.retry_after = retry_after

    @property
    def transient(self) -> bool:
        """Worth retrying: the server rejected because of *its* state, not
        the request's content."""
        return self.kind in TRANSIENT_KINDS or self.kind == "unknown-ids"


class RemoteHandle(MaskHandle):
    """Future for one tensor submitted over the wire.

    Same surface as :class:`MaskHandle` (``result``/``mask_blocks``/
    ``words``/``done``); ``result()`` on an unresolved handle flushes the
    owning client.  Extra observability: ``server_latency_s`` (enqueue ->
    solve wall time inside the server) and ``server_cached`` (resolved from
    the server's shared cache tier), both None until resolved over the wire
    and for locally-resolved (client cache / dedup / degraded) handles.
    The submitted block stream is retained on the handle until resolution
    so a reconnect can re-ship it (idempotent re-submission) and the
    degraded fallback can solve it locally.
    """

    def __init__(self, client: "MaskClient", name: str, pattern: PatternSpec,
                 key: str, geom: dict, rid: str, journal: bool = True):
        super().__init__(client, name, pattern, key, geom, journal=journal)
        self.id = rid
        self.server_latency_s: Optional[float] = None
        self.server_cached: Optional[bool] = None
        self._error: Optional[BaseException] = None
        self._blocks: Optional[np.ndarray] = None  # retained until resolved

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._blocks = None
        for dup in self._dups:
            dup._error = exc
        self._dups.clear()

    def _resolve(self, words: np.ndarray) -> None:
        super()._resolve(words)
        self._blocks = None  # payload no longer needed for re-submission

    def result(self) -> jnp.ndarray:
        if self._error is not None:
            raise self._error
        return super().result()


def _parse_endpoint(address) -> tuple[str, int]:
    if isinstance(address, str):
        host, _, port = address.rpartition(":")
        if not host:
            raise ValueError(f"address must be 'host:port', got {address!r}")
        return host, int(port)
    return str(address[0]), int(address[1])


class MaskClient:
    """TCP client for one or more :class:`~repro.service.net.server.MaskServer`.

    Args:
      address: ``"host:port"`` (or a ``(host, port)`` tuple), or a *list*
        of them — a failover set of solver boxes sharing one
        ``SolverConfig`` (and ideally one cache volume; see
        ``docs/deploy.md``).  The first healthy endpoint serves; the rest
        are tried in order when it stops answering.
      tenant: tenant name sent in the hello; scheduling quota and rate
        limits are per-tenant (see :class:`TenantConfig`).
      timeout: per-operation socket timeout in seconds.  None (default)
        blocks indefinitely — correct for ``flush`` barriers over large
        solves; set it for fail-fast health checks.
      local_cache: keep a client-side content-keyed memory cache of solved
        words so repeat submits of identical tensors skip the network
        entirely (counted in ``stats.cache_hits``, exactly like the
        engine's memory front).
      retry: the :class:`~.resilience.RetryPolicy` governing every
        recovery episode (reconnects, transient rejections, failover
        sweeps).  ``RetryPolicy(max_attempts=1)`` restores fail-fast.
      fallback: ``"local"`` (default) arms the degraded in-process
        fallback once the retry budget is spent; ``"none"`` surfaces the
        failure instead (the pre-resilience behavior).
      fallback_config: lets a client *constructed while every endpoint is
        down* still degrade: without one successful hello the client has
        no server-advertised ``SolverConfig`` to build the local fallback
        from, so construction raises unless this pins it.

    ``stats`` is a real :class:`ServiceStats` tracking the *client-side*
    counters (submitted / cache_hits / dedup_hits / retries / failovers /
    degraded); solver-side aggregates live on the server — fetch them with
    :meth:`server_stats`.
    """

    def __init__(
        self,
        address: Union[str, tuple[str, int], Sequence],
        tenant: str = "default",
        *,
        timeout: Optional[float] = None,
        connect_timeout: float = 10.0,
        local_cache: bool = True,
        retry: Optional[RetryPolicy] = None,
        fallback: str = "local",
        fallback_config: Optional[SolverConfig] = None,
    ):
        if isinstance(address, (str, tuple)):
            addresses = [address]
        else:
            addresses = list(address)
        if not addresses:
            raise ValueError("need at least one server address")
        self.endpoints = [_parse_endpoint(a) for a in addresses]
        if fallback not in ("local", "none"):
            raise ValueError(f"fallback must be 'local'|'none', got {fallback!r}")
        self.tenant = tenant
        self.timeout = timeout
        self.connect_timeout = connect_timeout
        self.local_cache = local_cache
        self.retry = retry if retry is not None else RetryPolicy()
        self.fallback = fallback
        self.stats = ServiceStats()
        self._lock = threading.RLock()  # outstanding/dedup/cache/stats
        self._drain_lock = threading.RLock()  # serializes whole flushes
        self._ep_idx = 0  # current endpoint (rotated by failover)
        self._pool: list[socket.socket] = []
        self._pool_lock = threading.Lock()
        self._bg_thread: Optional[threading.Thread] = None
        self._outstanding: dict[str, RemoteHandle] = {}  # id -> primary
        self._inflight: dict[str, RemoteHandle] = {}  # content key -> primary
        self._mem: dict[str, np.ndarray] = {}  # content key -> words
        self._ids = itertools.count()
        self._cid = f"{os.getpid():x}-{id(self) & 0xFFFFFF:x}"
        self._closed = False
        self._fallback_service: Optional[MaskService] = None
        self.config: Optional[SolverConfig] = None
        self.server_name: Optional[str] = None
        self.quota: Optional[float] = None
        # Dial eagerly: submit() needs the server's SolverConfig for content
        # keys, and failing here beats failing mid-prune.  A down fleet at
        # construction degrades immediately iff a fallback_config pins the
        # solver (no hello ever advertised one).
        try:
            self._checkin(self._dial())
        except (OSError, wire.WireError) as e:
            if fallback == "local" and fallback_config is not None:
                self.config = fallback_config
                self._enter_degraded(e)
            else:
                raise

    # -- connection pool / endpoints ----------------------------------------

    @property
    def host(self) -> str:
        return self.endpoints[self._ep_idx][0]

    @property
    def port(self) -> int:
        return self.endpoints[self._ep_idx][1]

    @property
    def degraded(self) -> bool:
        """True once the client fell back to the local in-process solver."""
        return self.stats.degraded

    def _dial_endpoint(self, host: str, port: int) -> socket.socket:
        sock = socket.create_connection(
            (host, port), timeout=self.connect_timeout
        )
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(self.timeout)
        try:
            reply, _ = wire.request(sock, {
                "op": "hello",
                "proto": wire.PROTO_VERSION,
                "tenant": self.tenant,
            })
        except BaseException:
            sock.close()
            raise
        if not reply.get("ok"):
            sock.close()
            raise RemoteError(f"hello rejected: {reply.get('error')}",
                              kind=str(reply.get("kind", "error")))
        config = SolverConfig(**reply["config"])
        if self.config is None:
            self.config = config
            self.server_name = reply.get("server")
            self.quota = reply.get("quota")
        elif config != self.config:
            # A failover box solving under a different config would break
            # bit-identity AND content keys — treat it as unhealthy.
            sock.close()
            raise RemoteError(
                f"endpoint {host}:{port} advertises {config}, client keyed "
                f"on {self.config}", kind="config-mismatch",
            )
        return sock

    def _dial(self) -> socket.socket:
        """Connect to the first healthy endpoint, starting at the current
        one.  Rotating to a different endpoint counts as a failover and
        invalidates the pool (its sockets point at the old box)."""
        last: Optional[BaseException] = None
        n = len(self.endpoints)
        for k in range(n):
            i = (self._ep_idx + k) % n
            host, port = self.endpoints[i]
            try:
                sock = self._dial_endpoint(host, port)
            except (OSError, wire.WireError) as e:
                last = e
                continue
            except RemoteError as e:
                if e.kind == "config-mismatch":
                    last = e
                    continue
                raise  # rejected hello (tenant/proto): same on every box
            if i != self._ep_idx:
                logger.warning("mask client failing over %s -> %s:%d",
                               f"{self.host}:{self.port}", host, port)
                with self._pool_lock:
                    stale, self._pool = self._pool, []
                for s in stale:
                    try:
                        s.close()
                    except OSError:
                        pass
                self._ep_idx = i
                with self._lock:
                    self.stats.failovers += 1
            return sock
        assert last is not None
        if isinstance(last, RemoteError):
            raise last
        raise last

    def _checkout(self) -> socket.socket:
        if self._closed:
            raise RuntimeError("MaskClient is closed")
        with self._pool_lock:
            if self._pool:
                return self._pool.pop()
        return self._dial()

    def _checkin(self, sock: socket.socket) -> None:
        with self._pool_lock:
            if not self._closed:
                self._pool.append(sock)
                return
        sock.close()

    def _request_once(self, header: dict, blobs=()) -> tuple[dict, list]:
        """One pooled request/response; not-ok replies raise
        :class:`RemoteError` (the connection stays usable — the reply frame
        arrived intact), transport failures discard the connection."""
        sock = self._checkout()
        try:
            reply, rblobs = wire.request(sock, header, blobs)
        except BaseException:
            sock.close()
            raise
        self._checkin(sock)
        if not reply.get("ok"):
            raise RemoteError(
                f"{reply.get('kind', 'error')}: {reply.get('error')}",
                kind=str(reply.get("kind", "error")),
                retry_after=reply.get("retry_after"),
            )
        return reply, rblobs

    def _request(self, header: dict, blobs=()) -> tuple[dict, list]:
        """A request under the retry policy: transport failures and
        transient rejections back off (honoring ``retry_after``), dialing
        through the failover set each time; non-transient server errors
        raise immediately.  Exhausting the budget raises the final cause."""
        episode = None
        while True:
            try:
                return self._request_once(header, blobs)
            except (OSError, wire.WireError, RemoteError) as e:
                if isinstance(e, RemoteError) and not e.transient:
                    raise
                episode = episode if episode is not None else \
                    self.retry.backoff()
                with self._lock:
                    self.stats.retries += 1
                try:
                    episode.step(e, getattr(e, "retry_after", None))
                except RetryBudgetExceeded:
                    raise e from None

    # -- MaskService surface ------------------------------------------------

    def submit(self, name: Optional[str], w, pattern=None, m=None, *,
               n=None, journal: bool = True) -> RemoteHandle:
        """Enqueue one tensor on the remote solver; returns a future.

        Same contract as :meth:`MaskService.submit` — transposable patterns
        only, ``name=None`` derives a content-addressed name, ``journal``
        controls the server-side journal record (written under
        ``"<tenant>:<name>"``).  The block stream goes out on the wire
        immediately unless the client's memory cache or in-flight dedup
        resolves it locally.
        """
        spec = pattern_from_args(pattern, m, None, n=n,
                                 caller="MaskClient.submit")
        handle, payload = self._prepare(name, w, spec, journal)
        if payload is not None:
            self._wire_submit([handle])
        return handle

    def submit_many(self, items, pattern=None, *, n=None,
                    m=None) -> list[RemoteHandle]:
        """Enqueue ``(name, w)`` pairs under one pattern — a single wire
        frame for everything the local cache/dedup does not absorb, so a
        per-sweep solve-plan batch costs one round trip."""
        spec = pattern_from_args(pattern, m, None, n=n,
                                 caller="MaskClient.submit_many")
        handles, send_handles = [], []
        for name, w in items:
            handle, payload = self._prepare(name, w, spec, True)
            handles.append(handle)
            if payload is not None:
                send_handles.append(handle)
        if send_handles:
            self._wire_submit(send_handles)
        return handles

    def _prepare(self, name, w, spec: PatternSpec, journal: bool):
        """Local half of a submit: block, key, probe cache/dedup.  Returns
        ``(handle, blocks-or-None)``; None means resolved locally."""
        if not spec.transposable:
            raise ValueError(
                "MaskService solves transposable patterns; standard N:M "
                "masks are a cheap top-N (repro.core.solver.nm_mask)"
            )
        assert self.config is not None
        blocks, geom = tensor_to_blocks(w, spec.m)
        key = content_key(blocks, spec, self.config)
        if name is None:
            name = f"mask:{key[:12]}"
        rid = f"{self._cid}-{next(self._ids)}"
        handle = RemoteHandle(self, name, spec, key, geom, rid,
                              journal=journal)
        with self._lock:
            self.stats.submitted += 1
            words = self._mem.get(key)
            if words is not None:
                self.stats.cache_hits += 1
                handle._resolve(words)
                return handle, None
            primary = self._inflight.get(key)
            if primary is not None and not primary.done:
                primary._dups.append(handle)
                self.stats.dedup_hits += 1
                return handle, None
            handle._blocks = blocks
            self._inflight[key] = handle
            self._outstanding[rid] = handle
        return handle, blocks

    def _wire_submit(self, handles: list[RemoteHandle]) -> None:
        if self.stats.degraded:
            self._local_submit(handles)
            return
        header = {
            "op": "submit",
            "reqs": [
                {"id": h.id, "name": h.name, "pattern": h.pattern.canonical,
                 "journal": h.journal}
                for h in handles
            ],
        }
        blobs = [h._blocks for h in handles]
        try:
            self._request(header, blobs)
        except (OSError, wire.WireError, RemoteError) as e:
            # Retry budget spent (or a non-transient rejection).  The
            # payloads are still on the handles: degrade to the local
            # solver if armed, otherwise fail the handles and their dedup
            # followers so result() reports the cause instead of a flush
            # hanging on ids the server does not know.
            if self._can_degrade(e):
                self._enter_degraded(e)
                self._local_submit(handles)
                return
            with self._lock:
                for h in handles:
                    self._outstanding.pop(h.id, None)
                    if self._inflight.get(h.key) is h:
                        del self._inflight[h.key]
                    h._fail(e)
            raise

    def _resubmit_outstanding(self) -> int:
        """Re-ship every unresolved in-flight request (after a reconnect or
        a server restart).  Idempotent: the server absorbs ids it already
        holds and re-enqueues content it lost.  Returns how many went out."""
        with self._lock:
            handles = [h for h in self._outstanding.values()
                       if not h.done and h._blocks is not None]
        if not handles:
            return 0
        header = {
            "op": "submit",
            "reqs": [
                {"id": h.id, "name": h.name, "pattern": h.pattern.canonical,
                 "journal": h.journal}
                for h in handles
            ],
        }
        self._request_once(header, [h._blocks for h in handles])
        with self._lock:
            self.stats.resubmitted += len(handles)
        logger.info("mask client re-submitted %d in-flight requests",
                    len(handles))
        return len(handles)

    # -- degraded local fallback --------------------------------------------

    def _can_degrade(self, error: BaseException) -> bool:
        if self.fallback != "local" or self.config is None:
            return False
        if isinstance(error, RemoteError) and not error.transient:
            return False  # a validation error would fail locally too
        return True

    def _enter_degraded(self, cause: BaseException) -> None:
        """Arm the in-process fallback: a fresh ``MaskService`` under the
        server-advertised ``SolverConfig``, so every mask it produces is
        bit-identical to what the (dead) fleet would have returned."""
        with self._lock:
            if self.stats.degraded:
                return
            assert self.config is not None
            self._fallback_service = MaskService(self.config)
            self.stats.degraded = True
        logger.warning(
            "mask client DEGRADED: all %d endpoint(s) down (%s); solving "
            "locally under the advertised %s",
            len(self.endpoints), cause, self.config,
        )

    def _local_submit(self, handles: list[RemoteHandle]) -> None:
        assert self._fallback_service is not None
        for h in handles:
            assert h._blocks is not None, f"{h.name!r} lost its payload"
            self._fallback_service.submit(
                h.name, h._blocks, h.pattern, journal=False,
            )

    def _flush_degraded(self) -> None:
        """Drain via the local fallback: solve outstanding payloads in the
        in-process engine and resolve the remote handles from its cache
        (content keys match by construction — same blocks, same config)."""
        svc = self._fallback_service
        assert svc is not None
        with self._lock:
            pending = [h for h in self._outstanding.values() if not h.done]
            for h in pending:
                if h._blocks is not None:
                    svc.submit(h.name, h._blocks, h.pattern, journal=False)
        svc.flush()
        with self._lock:
            for h in pending:
                cached = svc.cache.get_packed(h.key)
                assert cached is not None, (
                    f"degraded solve missing {h.name!r} ({h.key[:12]})"
                )
                words = cached[0]
                self._outstanding.pop(h.id, None)
                h._resolve(words)
                for dup in h._dups:
                    dup._resolve(words)
                h._dups.clear()
                if self._inflight.get(h.key) is h:
                    del self._inflight[h.key]
                if self.local_cache:
                    self._mem[h.key] = words

    # -- flush / drain ------------------------------------------------------

    def flush(self) -> None:
        """Barrier: block until every outstanding submission is solved and
        resolved into its handle.

        Folds in any active :meth:`flush_async` drain first, then waits on
        the server (which is free to batch this tenant's queue with other
        tenants' into shared mega-batches).  Concurrent flushes serialize;
        submissions racing the flush are drained by the next one, same as
        the engine.

        This is where recovery lives: a transport failure or transient
        rejection mid-wait re-dials (failing over if needed), re-submits
        every unresolved in-flight request, and waits again — under the
        client's :class:`~.resilience.RetryPolicy`.  Once the budget is
        spent, the flush completes through the degraded local fallback
        (``fallback="local"``) or fails every outstanding handle with the
        root cause (``fallback="none"``).
        """
        bg = self._bg_thread
        if bg is not None and bg is not threading.current_thread():
            bg.join()
        with self._drain_lock:
            if self.stats.degraded:
                self._flush_degraded()
                return
            episode = None
            while True:
                with self._lock:
                    ids = [rid for rid, h in self._outstanding.items()
                           if not h.done]
                if not ids:
                    return
                try:
                    reply, blobs = self._request_once(
                        {"op": "wait", "ids": ids})
                except (OSError, wire.WireError, RemoteError) as e:
                    if isinstance(e, RemoteError) and not e.transient:
                        self._fail_outstanding(e)
                        raise
                    episode = episode if episode is not None else \
                        self.retry.backoff()
                    with self._lock:
                        self.stats.retries += 1
                    try:
                        episode.step(e, getattr(e, "retry_after", None))
                        self._resubmit_outstanding()
                    except RetryBudgetExceeded:
                        if self._can_degrade(e):
                            self._enter_degraded(e)
                            self._flush_degraded()
                            return
                        self._fail_outstanding(e)
                        raise e from None
                    except (OSError, wire.WireError, RemoteError):
                        pass  # re-submission failed too: next loop retries
                    continue
                self._absorb_wait_reply(reply, blobs)

    def _absorb_wait_reply(self, reply: dict, blobs: list) -> None:
        ids = reply["ids"]
        lat = reply.get("lat") or [None] * len(ids)
        cached = reply.get("cached") or [None] * len(ids)
        with self._lock:
            for rid, words, t, hit in zip(ids, blobs, lat, cached):
                handle = self._outstanding.pop(rid, None)
                if handle is None:
                    continue
                handle.server_latency_s = t
                handle.server_cached = hit
                handle._resolve(words)
                for dup in handle._dups:
                    dup._resolve(words)
                handle._dups.clear()
                if self._inflight.get(handle.key) is handle:
                    del self._inflight[handle.key]
                if self.local_cache:
                    self._mem[handle.key] = words

    def _fail_outstanding(self, error: BaseException) -> None:
        with self._lock:
            for rid in list(self._outstanding):
                h = self._outstanding.pop(rid)
                if self._inflight.get(h.key) is h:
                    del self._inflight[h.key]
                if not h.done:
                    h._fail(error)

    def flush_async(self) -> FlushTicket:
        """Background flush; returns the engine's :class:`FlushTicket`.
        The DST refresh controller calls this verbatim — the solve runs on
        the server while the trainer keeps stepping locally."""
        ticket = FlushTicket()
        prev = self._bg_thread

        def drain():
            import time as _time
            t0 = _time.monotonic()
            try:
                if prev is not None:
                    prev.join()
                self.flush()
            except BaseException as e:  # surfaced on ticket.wait()
                ticket._error = e
            finally:
                ticket.seconds = _time.monotonic() - t0
                ticket._event.set()

        thread = threading.Thread(
            target=drain, name="mask-client-flush", daemon=True
        )
        # Start BEFORE publishing (same reasoning as MaskService.flush_async:
        # a concurrent flush() must never join a not-yet-started thread).
        thread.start()
        self._bg_thread = thread
        return ticket

    def results(self, handles) -> list[jnp.ndarray]:
        """Resolve a batch of handles with at most one flush (same contract
        as :meth:`MaskService.results`)."""
        handles = list(handles)
        for h in handles:
            if h.service is not self:
                raise ValueError(
                    f"handle {h.name!r} belongs to a different MaskService"
                )
        if any(not h.done for h in handles):
            self.flush()
        return [h.result() for h in handles]

    def solve(self, w, pattern=None, *, name: Optional[str] = None,
              n=None, m=None) -> jnp.ndarray:
        """Synchronous remote solve: submit + flush + result.  Bit-identical
        to ``MaskService.solve`` on the server's config (property-tested in
        ``tests/test_net.py``)."""
        spec = pattern_from_args(pattern, m, None, n=n,
                                 caller="MaskClient.solve")
        handle = self.submit(name, w, spec)
        self.flush()
        return handle.result()

    # -- server ops ---------------------------------------------------------

    def ping(self) -> bool:
        reply, _ = self._request_once({"op": "ping"})
        return bool(reply.get("ok"))

    def health(self) -> dict:
        """The current endpoint's liveness snapshot (``draining``,
        ``accepting``, queue depth) — one probe, no retries, so the answer
        reflects *now*.  Raises on a dead endpoint."""
        reply, _ = self._request_once({"op": "health"})
        return {k: v for k, v in reply.items() if k != "ok"}

    def server_stats(self) -> dict:
        """The server's live snapshot: inner-service counters plus the
        per-tenant scheduling/cache rows (see ``MaskServer.stats``)."""
        reply, _ = self._request({"op": "stats"})
        return {k: v for k, v in reply.items() if k != "ok"}

    def shutdown_server(self) -> None:
        """Ask the server to stop (works only with
        ``allow_remote_shutdown``); the connection is not reusable after."""
        sock = self._checkout()
        try:
            reply, _ = wire.request(sock, {"op": "shutdown"})
        finally:
            sock.close()
        if not reply.get("ok"):
            raise RemoteError(f"shutdown rejected: {reply.get('error')}")

    def close(self) -> None:
        # Join any active background drain BEFORE yanking its sockets:
        # closing mid-flush_async would surface a spurious OSError on the
        # ticket instead of the drain's real result.
        bg = self._bg_thread
        if bg is not None and bg is not threading.current_thread():
            bg.join()
        with self._pool_lock:
            self._closed = True
            pool, self._pool = self._pool, []
        for sock in pool:
            try:
                sock.close()
            except OSError:
                pass

    def __enter__(self) -> "MaskClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
