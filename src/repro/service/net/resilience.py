"""Retry policy for the mask-service client: backoff, budgets, failover.

Mask solves are deterministic and content-addressed, so every wire request
is safely idempotent: re-submitting a block stream after a reconnect either
dedupes against the request the server still holds, or re-enqueues content
whose solve is bit-identical to the lost one.  That property is what makes
a *policy-driven* retry layer correct here — nothing in the protocol needs
two-phase bookkeeping; the client just needs to know how long to keep
trying and how to space the attempts.

:class:`RetryPolicy` is the declarative half (attempt/deadline budgets,
backoff shape); :class:`Backoff` is one *instance* of the policy ticking
through a recovery episode.  The backoff is exponential with decorrelated
jitter (the AWS architecture-blog variant): each delay is drawn uniformly
from ``[base, prev * 3]`` and clamped to ``cap``, which spreads a thundering
herd of reconnecting clients across the window instead of synchronizing
them at ``base * 2**k``.  A server-supplied ``retry_after`` (load shedding,
drain) overrides the drawn delay — the server knows its queue better than
the client's dice do.

Transport-level failures (:class:`OSError`, :class:`~.wire.WireError`) are
always retryable: the connection is gone or desynchronized either way, and
the pool discards it.  Application-level :class:`~.client.RemoteError`
replies are retryable only for the kinds the server marks transient
(``overloaded``, ``draining``, ``deadline`` — see
:data:`TRANSIENT_KINDS`); a validation error will fail identically on
every endpoint forever and retrying it just burns the budget.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Optional

#: ``RemoteError.kind`` values that are worth retrying: the server rejected
#: the request because of *its* current state, not the request's content.
TRANSIENT_KINDS = frozenset({"overloaded", "draining", "deadline", "shutdown"})


class RetryBudgetExceeded(RuntimeError):
    """Every endpoint stayed down past the policy's attempt/deadline budget.

    Carries ``last_error`` (the final transport failure) so callers — and
    the degraded-fallback path that usually catches this — can report the
    root cause instead of a bare budget number.
    """

    def __init__(self, msg: str, last_error: Optional[BaseException] = None):
        super().__init__(msg)
        self.last_error = last_error


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Declarative retry budget for :class:`~.client.MaskClient`.

    Args:
      max_attempts: total tries per recovery episode (first try included).
      base_s: floor of every backoff draw; also the first delay's scale.
      cap_s: ceiling on any single delay (keeps the decorrelated draw from
        random-walking into minutes).
      deadline_s: wall-clock budget per recovery episode; ``None`` means
        attempts alone bound the episode.  When both are set, whichever
        runs out first ends the episode.
      seed: seeds the jitter RNG — chaos tests pin it so a replayed fault
        schedule produces the same delay sequence.
    """

    max_attempts: int = 6
    base_s: float = 0.05
    cap_s: float = 2.0
    deadline_s: Optional[float] = 30.0
    seed: Optional[int] = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}"
            )
        if self.base_s <= 0 or self.cap_s < self.base_s:
            raise ValueError(
                f"need 0 < base_s <= cap_s, got base_s={self.base_s} "
                f"cap_s={self.cap_s}"
            )
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")

    def backoff(self) -> "Backoff":
        """A fresh episode counter (one per recovery, not per client)."""
        return Backoff(self)


#: Zero-patience policy: one attempt, no waiting.  Useful for health probes
#: and for tests that want failure paths to run instantly.
NO_RETRY = RetryPolicy(max_attempts=1, deadline_s=None)


class Backoff:
    """One recovery episode ticking through a :class:`RetryPolicy`.

    Usage::

        episode = policy.backoff()
        while True:
            try:
                return attempt()
            except transient as e:
                episode.step(e)          # sleeps, or raises RetryBudgetExceeded

    ``step`` accounts the failed attempt, raises
    :class:`RetryBudgetExceeded` when the policy's budget is spent, and
    otherwise sleeps the next decorrelated-jitter delay (or the server's
    ``retry_after`` hint, when one accompanied the failure).
    """

    def __init__(self, policy: RetryPolicy):
        self.policy = policy
        self.attempts = 0  # completed (failed) attempts
        self.slept_s = 0.0
        self._prev = policy.base_s
        self._rng = random.Random(policy.seed)
        self._t0 = time.monotonic()

    def elapsed_s(self) -> float:
        return time.monotonic() - self._t0

    def exhausted(self) -> bool:
        if self.attempts >= self.policy.max_attempts:
            return True
        dl = self.policy.deadline_s
        return dl is not None and self.elapsed_s() >= dl

    def next_delay(self, retry_after: Optional[float] = None) -> float:
        """The next sleep, without sleeping (decorrelated jitter draw or the
        server hint, clipped so a sleep never overshoots the deadline)."""
        if retry_after is not None and retry_after >= 0:
            delay = min(float(retry_after), self.policy.cap_s)
        else:
            delay = min(
                self.policy.cap_s,
                self._rng.uniform(self.policy.base_s, self._prev * 3.0),
            )
            self._prev = delay
        dl = self.policy.deadline_s
        if dl is not None:
            delay = max(0.0, min(delay, dl - self.elapsed_s()))
        return delay

    def step(self, error: Optional[BaseException] = None,
             retry_after: Optional[float] = None) -> float:
        """Account one failed attempt; sleep toward the next or give up."""
        self.attempts += 1
        if self.exhausted():
            raise RetryBudgetExceeded(
                f"retry budget exhausted after {self.attempts} attempts / "
                f"{self.elapsed_s():.2f}s (policy {self.policy}); "
                f"last error: {error}",
                last_error=error,
            )
        delay = self.next_delay(retry_after)
        if delay > 0:
            time.sleep(delay)
            self.slept_s += delay
        return delay
