"""MaskServer: multi-tenant network front-end for one :class:`MaskService`.

This is the process ROADMAP item 1 asks for: the submit/flush future API was
always the seam for an RPC layer — here something finally listens on it.  A
``MaskServer`` owns ONE inner :class:`repro.service.MaskService` (and with
it the content-addressed cache, journal, bucket ladders and fused backend)
and exposes it over TCP to any number of tenants:

::

    client conns          per-tenant queues         one solver thread
    ------------          -----------------         -----------------
    hello/submit/wait --> token bucket -> deque --> deficit-weighted round
    (thread per conn)     (rate limit,   (FIFO      robin drains a "round"
                           backpressure)  within     of requests, submits
                                          tenant)    them ALL to the inner
                                                     service, ONE flush
                                                     (cross-tenant bucketed
                                                     mega-batch), resolves

Scheduling: each drain round hands every backlogged tenant a block quantum
proportional to its configured ``quota`` (deficit round-robin).  A tenant's
unspent quantum carries over while it stays backlogged, so a tenant whose
head request is huge eventually accumulates the credit to run it — and if
no head fits any tenant's credit, the most-credited tenant is force-served.
Both properties together make the drain starvation-free: no tenant waits
forever behind another's flood, and a tenant's long-run block share tracks
``quota_i / sum(quota)`` whenever it has backlog.  Within a round, requests
from *all* tenants solve as one shape-bucketed mega-batch via the inner
service — multi-tenancy costs no batching efficiency.

The shared tier: because the inner service's cache is content-addressed,
two tenants pruning the same open-weights checkpoint hit each other's
entries — tenant B's submits of tensors tenant A already solved resolve
from cache inside the drain round, never re-dispatching.  Per-tenant
``cache_hits``/``dedup_hits`` counters make the sharing observable
(``benchmarks/service_load.py`` gates on it).

Transport is the stdlib-only framed protocol of :mod:`.wire`; masks return
as bit-packed uint32 words (32x smaller than bool).  Deployment recipe:
``docs/deploy.md``; CLI: ``python -m repro.launch.serve_masks``.
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import socket
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec
from repro.service.engine import MaskService
from repro.service.net import wire

logger = logging.getLogger(__name__)

SERVER_NAME = "tsenor-maskserver/1"


def solver_config_to_wire(config: SolverConfig) -> dict:
    """The SolverConfig fields a client needs to compute content keys that
    match the server's (see ``cache.solver_fingerprint``)."""
    return {
        "iters": config.iters,
        "ls_steps": config.ls_steps,
        "tau_scale": config.tau_scale,
        "tol": config.tol,
        "backend": config.backend,
        "block_batch": config.block_batch,
    }


def solver_config_from_wire(d: dict) -> SolverConfig:
    return SolverConfig(**d)


class RequestFailed(RuntimeError):
    """A structured server-side rejection.

    ``kind`` travels in the error reply (clients classify retryability on
    it — see :data:`~.resilience.TRANSIENT_KINDS`); ``retry_after`` is the
    server's backoff hint in seconds, which the client's
    :class:`~.resilience.Backoff` honors over its own jitter draw.
    """

    def __init__(self, msg: str, kind: str = "error",
                 retry_after: Optional[float] = None):
        super().__init__(msg)
        self.kind = kind
        self.retry_after = retry_after


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Per-tenant scheduling knobs.

    ``quota``: weighted share of each drain round's block budget (relative
    to the other backlogged tenants' quotas).
    ``rate``: token-bucket refill in blocks/sec; submits past it block the
    submitting connection (backpressure, never drops).  None = unlimited.
    ``burst``: bucket capacity in blocks (default: one round's budget).
    """

    quota: float = 1.0
    rate: Optional[float] = None
    burst: Optional[float] = None

    def __post_init__(self):
        if self.quota <= 0:
            raise ValueError(f"quota must be > 0, got {self.quota}")
        if self.rate is not None and self.rate <= 0:
            raise ValueError(f"rate must be > 0 blocks/sec, got {self.rate}")


class TokenBucket:
    """Blocks/sec rate limiter; ``acquire`` sleeps (bounded) until funded.

    Requests larger than ``burst`` are admitted once the bucket is full and
    drive the balance negative — a later refill pays the debt — so one huge
    tensor is delayed, not deadlocked.
    """

    def __init__(self, rate: float, burst: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._t = time.monotonic()
        self._lock = threading.Lock()

    def acquire(self, cost: float, should_abort=lambda: False,
                timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        need = min(cost, self.burst)
        while True:
            with self._lock:
                now = time.monotonic()
                self._tokens = min(
                    self.burst, self._tokens + (now - self._t) * self.rate
                )
                self._t = now
                if self._tokens >= need:
                    self._tokens -= cost
                    return True
                wait = (need - self._tokens) / self.rate
            if should_abort():
                return False
            if deadline is not None and time.monotonic() >= deadline:
                return False
            time.sleep(min(wait, 0.05))


class _Request:
    """One submitted tensor travelling queue -> drain round -> wait reply."""

    __slots__ = ("id", "name", "pattern", "journal", "blocks", "nblocks",
                 "tenant", "event", "words", "error", "error_kind",
                 "enqueued_at", "solved_at", "cached")

    def __init__(self, rid: str, name: str, pattern: str, journal: bool,
                 blocks: np.ndarray, tenant: "_Tenant"):
        self.id = rid
        self.name = name
        self.pattern = pattern
        self.journal = journal
        self.blocks = blocks
        self.nblocks = int(blocks.shape[0])
        self.tenant = tenant
        self.event = threading.Event()
        self.words: Optional[np.ndarray] = None
        self.error: Optional[str] = None
        self.error_kind = "error"
        self.enqueued_at = time.monotonic()
        self.solved_at: Optional[float] = None
        self.cached = False

    def fail(self, msg: str, kind: str = "error") -> None:
        self.error = msg
        self.error_kind = kind
        self.tenant.failed += 1
        self.event.set()


class _Tenant:
    """Server-side tenant state: queue, credit, rate bucket, counters."""

    def __init__(self, name: str, cfg: TenantConfig, round_blocks: int):
        self.name = name
        self.cfg = cfg
        self.queue: deque[_Request] = deque()
        self.deficit = 0.0  # unspent round credit, in blocks
        self.bucket: Optional[TokenBucket] = None
        if cfg.rate is not None:
            burst = cfg.burst if cfg.burst is not None else float(round_blocks)
            self.bucket = TokenBucket(cfg.rate, burst)
        # Counters (mutated by handler threads under the server lock, and by
        # the single scheduler thread for the solve-side ones).
        self.submitted = 0
        self.blocks_in = 0
        self.resolved = 0
        self.resubmitted = 0  # duplicate ids absorbed (client reconnects)
        self.failed = 0  # requests failed (deadline, shed, shutdown, solve)
        self.cache_hits = 0
        self.dedup_hits = 0
        self.queue_seconds = 0.0  # sum of enqueue->resolve latencies
        self.results: dict[str, _Request] = {}  # popped by wait

    def stats(self) -> dict:
        return {
            "quota": self.cfg.quota,
            "rate": self.cfg.rate,
            "submitted": self.submitted,
            "blocks": self.blocks_in,
            "resolved": self.resolved,
            "resubmitted": self.resubmitted,
            "failed": self.failed,
            "cache_hits": self.cache_hits,
            "dedup_hits": self.dedup_hits,
            "queued": len(self.queue),
            "waiting_results": len(self.results),
            "queue_seconds": self.queue_seconds,
        }


class MaskServer:
    """Threaded TCP server wrapping one :class:`MaskService` for N tenants.

    Args:
      service: the inner solver engine (owns config/cache/journal).  Default
        is a fresh in-memory ``MaskService(SolverConfig())``.
      host/port: bind address; ``port=0`` picks an ephemeral port (read it
        back from ``.port`` — the test/benchmark idiom).
      tenants: name -> :class:`TenantConfig` pre-registrations.  Unknown
        tenants that ``hello`` in are auto-registered with
        ``TenantConfig(default_quota, default_rate)`` unless
        ``strict_tenants`` is set.
      round_blocks: block budget one drain round distributes across
        backlogged tenants (quota-weighted).
      batch_window_s: how long the drain thread lingers after a wake-up so
        concurrent submitters land in the same round (bigger mega-batches
        at the cost of that much added latency).
      allow_remote_shutdown: accept the ``shutdown`` op (handy for tests
        and CI; disable for real deployments via ``serve-masks
        --no-remote-shutdown``).
      max_queue_blocks: per-tenant load-shedding bound.  A submit that
        would push a tenant's queued blocks past it is rejected with a
        structured ``overloaded`` error carrying a ``retry_after`` hint
        (derived from the observed solve rate) instead of queueing without
        bound; the client's backoff honors the hint.  ``None`` disables
        shedding (backpressure via ``rate`` still applies).
      request_deadline_s: fail requests still queued after this many
        seconds with a ``deadline`` error (retryable — the client
        re-submits within its own budget).  ``None`` disables.
      drain_grace_s: default grace window for :meth:`drain` — how long a
        SIGTERM'd server keeps solving its backlog before exiting.
    """

    def __init__(
        self,
        service: Optional[MaskService] = None,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        tenants: Optional[dict[str, TenantConfig]] = None,
        default_quota: float = 1.0,
        default_rate: Optional[float] = None,
        strict_tenants: bool = False,
        round_blocks: int = 4096,
        batch_window_s: float = 0.002,
        allow_remote_shutdown: bool = True,
        rate_timeout_s: float = 120.0,
        max_queue_blocks: Optional[int] = None,
        request_deadline_s: Optional[float] = None,
        drain_grace_s: float = 30.0,
    ):
        self.service = service if service is not None else MaskService()
        self.host = host
        self._requested_port = port
        self.default_quota = default_quota
        self.default_rate = default_rate
        self.strict_tenants = strict_tenants
        self.round_blocks = int(round_blocks)
        self.batch_window_s = batch_window_s
        self.allow_remote_shutdown = allow_remote_shutdown
        self.rate_timeout_s = rate_timeout_s
        self.max_queue_blocks = max_queue_blocks
        self.request_deadline_s = request_deadline_s
        self.drain_grace_s = drain_grace_s
        self._tenants: dict[str, _Tenant] = {}
        for name, cfg in (tenants or {}).items():
            self._tenants[name] = _Tenant(name, cfg, self.round_blocks)
        self._cv = threading.Condition()
        self._running = False
        self._draining = False
        self._drain_requested = False
        self._sock: Optional[socket.socket] = None
        self._threads: list[threading.Thread] = []
        self._conns: set[socket.socket] = set()
        self._started_at: Optional[float] = None
        self.port: Optional[int] = None
        self.rounds = 0

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "MaskServer":
        assert not self._running, "server already started"
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind((self.host, self._requested_port))
        sock.listen(64)
        self._sock = sock
        self.port = sock.getsockname()[1]
        self._running = True
        self._started_at = time.monotonic()
        for target, name in ((self._accept_loop, "mask-server-accept"),
                             (self._drain_loop, "mask-server-drain")):
            t = threading.Thread(target=target, name=name, daemon=True)
            t.start()
            self._threads.append(t)
        logger.info("mask server listening on %s:%d", self.host, self.port)
        return self

    @property
    def address(self) -> str:
        assert self.port is not None, "server not started"
        return f"{self.host}:{self.port}"

    def stop(self) -> None:
        with self._cv:
            if not self._running:
                return
            self._running = False
            self._cv.notify_all()
        if self._sock is not None:
            # shutdown() before close(): a bare close() does not wake a
            # thread blocked in accept() (the in-progress syscall pins the
            # open file description), which would stall stop() on the join.
            try:
                self._sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._sock.close()
            except OSError:
                pass
        for conn in list(self._conns):
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
        for t in self._threads:
            if t is not threading.current_thread():
                t.join(timeout=10)
        # Fail anything still queued so blocked waiters wake with an error
        # instead of hanging on a dead server.
        with self._cv:
            for tenant in self._tenants.values():
                while tenant.queue:
                    tenant.queue.popleft().fail("server shut down",
                                                kind="shutdown")
        logger.info("mask server stopped (%d rounds)", self.rounds)

    def __enter__(self) -> "MaskServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def drain(self, grace_s: Optional[float] = None) -> None:
        """Graceful shutdown: stop accepting, finish in-flight, then stop.

        The drain sequence — the SIGTERM story of ``docs/deploy.md``:

        1. close the listener (no new connections) and flip ``_draining``:
           new ``submit`` ops are rejected with a structured ``draining``
           error + ``retry_after``, so clients fail over or back off
           instead of queueing into a dying server;
        2. let the scheduler finish every already-queued solve (bounded by
           ``grace_s``), and linger so connected waiters pick their
           results up over still-open connections;
        3. fsync the journal (every completion durably recorded — a
           restarted server warm-starts from cache + journal) and
           :meth:`stop`.

        Requests still unsolved when the grace expires fail with a
        ``shutdown`` error; clients re-submit them elsewhere (idempotent).
        """
        grace = self.drain_grace_s if grace_s is None else grace_s
        with self._cv:
            if not self._running or self._draining:
                return
            self._draining = True
            self._cv.notify_all()
        logger.info("mask server draining (grace %.1fs)", grace)
        if self._sock is not None:
            try:  # shutdown first: close() alone cannot wake accept()
                self._sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._sock.close()  # accept loop exits; port is released
            except OSError:
                pass
        deadline = time.monotonic() + grace

        def _backlog() -> bool:
            with self._cv:
                return any(
                    t.queue or any(not r.event.is_set()
                                   for r in t.results.values())
                    for t in self._tenants.values()
                )

        def _unclaimed() -> bool:
            with self._cv:
                return any(t.results for t in self._tenants.values())

        while _backlog() and time.monotonic() < deadline:
            time.sleep(0.02)
        # Solves done (or grace gone): give connected waiters a moment to
        # collect results before the connections die with stop().
        while _unclaimed() and time.monotonic() < deadline:
            time.sleep(0.02)
        if self.service.journal is not None:
            self.service.journal.sync()
        self.stop()

    def install_signal_handlers(self, grace_s: Optional[float] = None) -> None:
        """Route SIGTERM/SIGINT to a graceful :meth:`drain`.

        Main-thread only (a signal constraint).  The handler just sets a
        flag; :meth:`serve_forever` notices it and runs the drain outside
        signal context, so journal fsyncs and joins never run in a handler.
        """
        if grace_s is not None:
            self.drain_grace_s = grace_s

        def _handler(signum, frame):  # noqa: ARG001 — signal signature
            logger.info("signal %d received: requesting drain", signum)
            self._drain_requested = True

        signal.signal(signal.SIGTERM, _handler)
        signal.signal(signal.SIGINT, _handler)

    def serve_forever(self) -> None:
        """Block until :meth:`stop` (CLI entry point's main thread parks
        here; the accept/drain threads do the work).  A drain request —
        SIGTERM/SIGINT via :meth:`install_signal_handlers`, or Ctrl-C —
        exits through the graceful :meth:`drain` path."""
        if not self._running:
            self.start()
        try:
            while self._running:
                if self._drain_requested:
                    self.drain()
                    break
                time.sleep(0.2)
        except KeyboardInterrupt:
            self.drain()
        finally:
            self.stop()

    # -- connection side ----------------------------------------------------

    def _accept_loop(self) -> None:
        assert self._sock is not None
        while self._running:
            try:
                conn, addr = self._sock.accept()
            except OSError:
                break  # listener closed by stop()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._conns.add(conn)
            t = threading.Thread(
                target=self._handle_conn, args=(conn, addr),
                name=f"mask-server-conn-{addr[1]}", daemon=True,
            )
            t.start()

    def _handle_conn(self, conn: socket.socket, addr) -> None:
        tenant: Optional[_Tenant] = None
        try:
            while self._running:
                try:
                    frame = wire.recv_frame(conn)
                except (wire.WireError, OSError) as e:
                    if self._running:
                        logger.debug("conn %s dropped: %s", addr, e)
                    break
                if frame is None:
                    break
                header, blobs = frame
                op = str(header.get("op"))
                try:
                    reply, rblobs, tenant = self._dispatch(
                        op, header, blobs, tenant
                    )
                except Exception as e:  # noqa: BLE001 — reply, don't die
                    reply, rblobs = {
                        "ok": False,
                        "error": str(e),
                        "kind": getattr(e, "kind", type(e).__name__),
                    }, []
                    retry_after = getattr(e, "retry_after", None)
                    if retry_after is not None:
                        reply["retry_after"] = retry_after
                try:
                    wire.send_frame(conn, reply, rblobs)
                except OSError:
                    break
                if op == "shutdown" and reply.get("ok"):
                    threading.Thread(target=self.stop, daemon=True).start()
                    break
        finally:
            self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _require_tenant(self, tenant: Optional[_Tenant]) -> _Tenant:
        if tenant is None:
            raise wire.WireError("op requires a prior hello")
        return tenant

    def _dispatch(self, op, header, blobs, tenant):
        if op == "hello":
            tenant = self._hello(header)
            return {
                "ok": True,
                "proto": wire.PROTO_VERSION,
                "server": SERVER_NAME,
                "tenant": tenant.name,
                "quota": tenant.cfg.quota,
                "config": solver_config_to_wire(self.service.config),
            }, [], tenant
        if op == "ping":
            return {"ok": True}, [], tenant
        if op == "health":
            return {"ok": True, **self.health()}, [], tenant
        if op == "submit":
            return self._submit(self._require_tenant(tenant),
                                header, blobs) + (tenant,)
        if op == "wait":
            return self._wait(self._require_tenant(tenant),
                              header) + (tenant,)
        if op == "stats":
            return {"ok": True, **self.stats()}, [], tenant
        if op == "shutdown":
            if not self.allow_remote_shutdown:
                raise PermissionError("remote shutdown disabled")
            return {"ok": True}, [], tenant
        raise wire.WireError(f"unknown op {op!r}")

    def _hello(self, header) -> _Tenant:
        proto = header.get("proto")
        if proto != wire.PROTO_VERSION:
            raise wire.WireError(
                f"protocol mismatch: client {proto}, "
                f"server {wire.PROTO_VERSION}"
            )
        name = str(header.get("tenant") or "default")
        with self._cv:
            tenant = self._tenants.get(name)
            if tenant is None:
                if self.strict_tenants:
                    raise PermissionError(f"unknown tenant {name!r}")
                tenant = _Tenant(
                    name,
                    TenantConfig(quota=self.default_quota,
                                 rate=self.default_rate),
                    self.round_blocks,
                )
                self._tenants[name] = tenant
        return tenant

    def _submit(self, tenant: _Tenant, header, blobs):
        reqs = header.get("reqs") or []
        if len(reqs) != len(blobs):
            raise wire.WireError(
                f"submit declares {len(reqs)} requests but {len(blobs)} blobs"
            )
        parsed: list[_Request] = []
        for meta, blocks in zip(reqs, blobs):
            spec = PatternSpec.parse(str(meta["pattern"]))
            if not spec.transposable:
                raise ValueError(
                    "MaskService solves transposable patterns; standard N:M "
                    "masks are a cheap top-N (repro.core.solver.nm_mask)"
                )
            if blocks.ndim != 3 or blocks.shape[-2:] != (spec.m, spec.m):
                raise ValueError(
                    f"submit blob must be a (B, {spec.m}, {spec.m}) block "
                    f"stream, got shape {tuple(blocks.shape)}"
                )
            parsed.append(_Request(
                str(meta["id"]), str(meta.get("name") or meta["id"]),
                spec.canonical, bool(meta.get("journal", True)),
                np.ascontiguousarray(blocks, np.float32), tenant,
            ))
        # Duplicate ids are *idempotent*, not errors: a client re-submitting
        # its in-flight keys after a reconnect must land on the original
        # request (still queued, solving, or already solved and awaiting
        # pickup) instead of enqueueing the content twice or being bounced.
        with self._cv:
            fresh = [r for r in parsed if r.id not in tenant.results]
            tenant.resubmitted += len(parsed) - len(fresh)
        if self._draining:
            raise RequestFailed(
                "server is draining: submit elsewhere or retry after "
                "restart", kind="draining", retry_after=1.0,
            )
        cost = sum(r.nblocks for r in fresh)
        if self.max_queue_blocks is not None and fresh:
            with self._cv:
                backlog = sum(r.nblocks for r in tenant.queue)
            # An empty queue always admits: a single submission larger than
            # the bound must still be solvable, else that content could
            # never pass — the bound sheds pile-up, not individual size.
            if backlog and backlog + cost > self.max_queue_blocks:
                raise RequestFailed(
                    f"tenant {tenant.name!r} queue at {backlog} blocks; "
                    f"+{cost} exceeds max_queue_blocks="
                    f"{self.max_queue_blocks}",
                    kind="overloaded",
                    retry_after=self._retry_after_hint(backlog),
                )
        # Rate limit BEFORE enqueueing: an over-rate tenant's connection
        # blocks right here (backpressure), so its flood never reaches the
        # queue and other tenants' drain rounds.
        if tenant.bucket is not None and fresh:
            ok = tenant.bucket.acquire(
                cost, should_abort=lambda: not self._running,
                timeout=self.rate_timeout_s,
            )
            if not ok:
                raise RuntimeError(
                    f"tenant {tenant.name!r} rate limit: {cost} blocks not "
                    f"funded within {self.rate_timeout_s}s"
                )
        with self._cv:
            for r in fresh:
                if r.id in tenant.results:
                    continue  # raced a concurrent duplicate: keep the first
                tenant.results[r.id] = r
                tenant.queue.append(r)
                tenant.submitted += 1
                tenant.blocks_in += r.nblocks
            self._cv.notify_all()
        return {"ok": True, "queued": len(fresh)}, []

    def _retry_after_hint(self, backlog_blocks: int) -> float:
        """How long an overloaded tenant should wait: the backlog's expected
        solve time at the observed rate (bounded to a sane retry window)."""
        rate = self.service.stats.solve_blocks_per_sec()
        if not rate:
            return 0.25
        return float(min(10.0, max(0.05, backlog_blocks / rate)))

    def _wait(self, tenant: _Tenant, header):
        ids = [str(i) for i in header.get("ids") or []]
        timeout = header.get("timeout")
        with self._cv:
            missing = [i for i in ids if i not in tenant.results]
        if missing:
            # Structured + retryable: after a server restart every in-flight
            # id is "unknown" here, and the client's recovery path re-submits
            # the content (idempotent) rather than giving up.
            raise RequestFailed(
                f"unknown request ids {missing[:3]!r} (already waited, "
                "never submitted by this tenant, or lost to a restart)",
                kind="unknown-ids",
            )
        deadline = None if timeout is None else time.monotonic() + timeout
        reqs = [tenant.results[i] for i in ids]
        for r in reqs:
            left = None if deadline is None else deadline - time.monotonic()
            if not r.event.wait(left):
                raise TimeoutError(f"request {r.id!r} not solved in time")
        failed = [r for r in reqs if r.error]
        if failed:
            # Pop the failed ids: a retried wait then reports them unknown,
            # which funnels every failure mode (deadline, shed, restart)
            # into the client's single re-submission path.
            with self._cv:
                for r in failed:
                    tenant.results.pop(r.id, None)
            kinds = {r.error_kind for r in failed}
            raise RequestFailed(
                f"solve failed: {({r.id: r.error for r in failed})}",
                kind=kinds.pop() if len(kinds) == 1 else "error",
            )
        with self._cv:
            for r in reqs:
                tenant.results.pop(r.id, None)
        lat = [r.solved_at - r.enqueued_at for r in reqs]
        cached = [bool(r.cached) for r in reqs]
        return (
            {"ok": True, "ids": ids, "lat": lat, "cached": cached},
            [r.words for r in reqs],
        )

    # -- scheduler side -----------------------------------------------------

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                while self._running and not any(
                    t.queue for t in self._tenants.values()
                ):
                    self._cv.wait(0.5)
                if not self._running:
                    return
            if self.batch_window_s:
                time.sleep(self.batch_window_s)  # let co-submitters land
            self._expire_overdue()
            with self._cv:
                round_reqs = self._take_round()
            if round_reqs:
                self._solve_round(round_reqs)

    def _expire_overdue(self) -> None:
        """Per-request deadline: fail anything queued past the budget with a
        structured (retryable) ``deadline`` error before it wastes a round.
        Requests already taken into a round are past admission — they solve."""
        if self.request_deadline_s is None:
            return
        cutoff = time.monotonic() - self.request_deadline_s
        with self._cv:
            for t in self._tenants.values():
                if not t.queue:
                    continue
                keep: deque[_Request] = deque()
                while t.queue:
                    req = t.queue.popleft()
                    if req.enqueued_at < cutoff:
                        req.fail(
                            f"request {req.id!r} queued past "
                            f"request_deadline_s={self.request_deadline_s}",
                            kind="deadline",
                        )
                    else:
                        keep.append(req)
                t.queue = keep

    def _take_round(self) -> list[_Request]:
        """Deficit round-robin over backlogged tenants (under the lock).

        Every backlogged tenant's credit grows by ``round_blocks * quota /
        total_quota``; requests pop FIFO while they fit the credit.  Credit
        resets when a tenant's backlog empties (no banking while idle).  If
        nothing fits anywhere, the most-credited tenant (normalized by
        quota) is force-served one request — a huge head request is delayed
        proportionally to its size, never starved.
        """
        active = [t for t in self._tenants.values() if t.queue]
        if not active:
            return []
        total_quota = sum(t.cfg.quota for t in active)
        taken: list[_Request] = []
        for t in active:
            t.deficit += self.round_blocks * t.cfg.quota / total_quota
            while t.queue and t.queue[0].nblocks <= t.deficit:
                req = t.queue.popleft()
                t.deficit -= req.nblocks
                taken.append(req)
            if not t.queue:
                t.deficit = 0.0
        if not taken:
            t = max(active, key=lambda t: t.deficit / t.cfg.quota)
            taken.append(t.queue.popleft())
            t.deficit = 0.0
        self.rounds += 1
        return taken

    def _solve_round(self, round_reqs: list[_Request]) -> None:
        """Submit one round to the inner service, flush once, resolve.

        Runs on the single drain thread — the only caller of the inner
        service — so cross-round ordering is deterministic and per-request
        cache/dedup attribution (stat deltas around each submit) is exact.
        """
        inner = self.service
        submitted: list[tuple[_Request, object]] = []
        for req in round_reqs:
            hits0 = inner.stats.cache_hits
            dups0 = inner.stats.dedup_hits
            try:
                handle = inner.submit(
                    f"{req.tenant.name}:{req.name}", req.blocks,
                    PatternSpec.parse(req.pattern), journal=req.journal,
                )
            except Exception as e:  # noqa: BLE001 — fail one, not the round
                req.fail(f"{type(e).__name__}: {e}")
                continue
            finally:
                req.blocks = None  # the queue holds no payloads past here
            if inner.stats.cache_hits > hits0:
                req.cached = True
                req.tenant.cache_hits += 1
            elif inner.stats.dedup_hits > dups0:
                req.tenant.dedup_hits += 1
            submitted.append((req, handle))
        if not submitted:
            return
        try:
            inner.flush()
        except Exception as e:  # noqa: BLE001 — surface on every waiter
            for req, _ in submitted:
                req.fail(f"{type(e).__name__}: {e}")
            return
        now = time.monotonic()
        with self._cv:
            for req, handle in submitted:
                req.words = handle.words()
                req.solved_at = now
                req.tenant.resolved += 1
                req.tenant.queue_seconds += now - req.enqueued_at
                req.event.set()

    # -- observability ------------------------------------------------------

    def health(self) -> dict:
        """Cheap liveness/readiness snapshot for the ``health`` wire op.

        ``draining: true`` tells a client to fail over *now* — the server
        still answers waits but will not accept work.  ``queued_blocks``
        lets a failover client prefer the least-loaded endpoint.
        """
        with self._cv:
            queued = sum(len(t.queue) for t in self._tenants.values())
            queued_blocks = sum(
                r.nblocks for t in self._tenants.values() for r in t.queue
            )
        return {
            "server": SERVER_NAME,
            "draining": self._draining,
            "accepting": self._running and not self._draining,
            "queued": queued,
            "queued_blocks": queued_blocks,
            "uptime_seconds": (
                time.monotonic() - self._started_at if self._started_at
                else 0.0
            ),
        }

    def stats(self) -> dict:
        """Json-ready snapshot: inner service counters + per-tenant rows."""
        s = self.service.stats
        return {
            "server": SERVER_NAME,
            "uptime_seconds": (
                time.monotonic() - self._started_at if self._started_at
                else 0.0
            ),
            "draining": self._draining,
            "rounds": self.rounds,
            "service": {
                "submitted": s.submitted,
                "cache_hits": s.cache_hits,
                "dedup_hits": s.dedup_hits,
                "cache_skips": s.cache_skips,
                "cache_evictions": s.cache_evictions,
                "blocks_solved": s.blocks_solved,
                "batches": s.batches,
                "solve_seconds": s.solve_seconds,
            },
            "tenants": {
                name: t.stats() for name, t in sorted(self._tenants.items())
            },
        }
