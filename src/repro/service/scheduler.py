"""Shape-bucketed scheduling of M x M block problems.

The transposable N:M solver is embarrassingly parallel over M x M blocks
(every op in Dykstra + rounding is batched over the leading axis), so the
only thing that matters for throughput at model scale is how blocks are
*dispatched*: the naive per-tensor path pays one XLA compilation per distinct
block count and one dispatch per tensor, which wrecks occupancy on the long
tail of small layers.

The scheduler instead treats the whole model as one stream of blocks per
:class:`~repro.patterns.PatternSpec` group and packs it into a small number
of shape-bucketed mega-batches:

  * bucket sizes are the geometric ladder ``base * growth^k`` capped at
    ``max_bucket`` — every workload compiles at most ``len(ladder)`` programs
    per pattern instead of one per tensor;
  * :meth:`BucketPolicy.for_device` derives the ladder from the solve
    kernel's VMEM plan (``repro.kernels.vmem``): the base bucket is exactly
    one kernel tile and every rung a tile multiple, so mega-batches never
    pad a partial tile, and the ladder growth is tuned against the measured
    :meth:`StreamStats.padding_waste` of earlier streams (high observed
    waste -> finer ladder);
  * the plan greedily emits the largest bucket that fits the remaining
    stream, then rounds the tail UP to the smallest bucket that covers it
    (or, with ``tail_decompose`` — the ``for_device`` default — covers the
    tail with a descending run of smaller rungs so padding is bounded by
    ``base`` instead of by the covering rung), padding with all-zero
    sentinel blocks (blocks are independent, so sentinels can never
    contaminate real results — they are sliced off after the solve);
  * mega-batches are dispatched back-to-back without blocking, so host-side
    packing of batch ``k+1`` overlaps the device solve of batch ``k`` (JAX
    async dispatch);
  * with more than one local device (and a traceable backend), each
    mega-batch is split over a 1-D ``("blocks",)`` device mesh via
    ``compat.shard_map`` — blocks are independent, so sharding the leading
    axis is semantics-free and model-scale solves use every local chip;
  * results are scattered back to per-tensor block streams in submission
    order.

Bit-exactness: every mega-batch is solved by the exact same backend program
as the per-tensor path (``repro.core.backends``), and every per-block
operation in the solver reduces only within its own block, so masks are
identical to ``solve_mask`` bit for bit — sharded or not.

:class:`StreamStats` additionally tracks padding waste per bucket size
(padded blocks / dispatched blocks), giving the ROADMAP cost-model work a
measurable baseline; per-stream figures log at DEBUG and the aggregate is
emitted once via :meth:`StreamStats.summary` (a sequential solver produces
thousands of tiny streams — one INFO line each would flood the log).

Sequential solvers also motivate the ladder's ``sub_rungs``: their per-sweep
flushes are far smaller than a VMEM-sized base bucket, so
:meth:`BucketPolicy.for_device` extends the ladder below the kernel tile
with power-of-two rungs down to ``VPU_ALIGN``, bounding sentinel padding per
stream by one sublane instead of one tile.
"""
from __future__ import annotations

import dataclasses
import functools
import logging

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.backends import get_backend
from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Geometric ladder of mega-batch sizes (in blocks)."""

    base: int = 512        # smallest full-rate dispatched batch
    growth: int = 4        # ladder ratio
    max_bucket: int = 32768  # device-memory cap per dispatch
    shard_devices: bool = True  # split mega-batches over local devices
    tail_decompose: bool = False  # cover the tail with smaller rungs instead
    #                               of one covering bucket (padding < base)
    min_bucket: int = 0    # smallest sub-base rung for tiny streams; 0 keeps
    #                        the historic behavior (tails round up to base)

    # Observed padding-waste fraction above which ``for_device`` drops to a
    # finer ladder growth.
    WASTE_THRESHOLD = 0.25

    def ladder(self) -> tuple[int, ...]:
        sizes = [self.base]
        while sizes[-1] * self.growth <= self.max_bucket:
            sizes.append(sizes[-1] * self.growth)
        return tuple(sizes)

    def sub_rungs(self) -> tuple[int, ...]:
        """Descending power-of-two rungs below ``base``, down to
        ``min_bucket`` — the small-stream ladder extension.

        Sequential solvers (SparseGPT sweeps, ALPS iterations) flush many
        *tiny* streams: a handful of blocks per request, far below the
        VMEM-sized ``base``.  Rounding every such stream up to ``base``
        (the historic tail rule) made sentinel padding dominate real work.
        Sub-base rungs bound that padding by ``min_bucket - 1`` blocks per
        stream while staying a fixed power-of-two set, so the compile count
        stays bounded by ``log2(base / min_bucket)`` extra programs.
        Empty when ``min_bucket`` is 0 (historic behavior).
        """
        if not self.min_bucket:
            return ()
        floor = min(self.min_bucket, self.base)
        rungs, s = [], self.base // 2
        while s >= floor and s > 0:
            rungs.append(s)
            s //= 2
        return tuple(rungs)

    def _covering(self, remaining: int) -> int:
        """Smallest rung (sub-base rungs included) covering ``remaining``."""
        candidates = sorted(self.sub_rungs()) + list(self.ladder())
        return next(s for s in candidates if s >= remaining)

    def plan(self, total: int) -> list[int]:
        """Bucket sizes covering ``total`` blocks (sum(plan) >= total)."""
        assert total > 0, total
        sizes = self.ladder()
        out = []
        remaining = total
        while remaining >= sizes[-1]:
            out.append(sizes[-1])
            remaining -= sizes[-1]
        if remaining and self.tail_decompose:
            # Descending run of rungs: each compiles once like any ladder
            # member, and the final round-up bounds the sentinel padding by
            # the smallest rung instead of by the covering bucket.
            for s in reversed(sizes):
                while remaining >= s:
                    out.append(s)
                    remaining -= s
            for s in self.sub_rungs():
                while remaining >= s:
                    out.append(s)
                    remaining -= s
            if remaining:
                out.append(self._covering(remaining))
        elif remaining:
            out.append(self._covering(remaining))
        return out

    @classmethod
    def for_device(
        cls,
        m: int,
        device=None,
        *,
        stats: "StreamStats | None" = None,
        max_bucket_bytes: int = 256 * 1024 * 1024,
        shard_devices: bool = True,
    ) -> "BucketPolicy":
        """VMEM-aware ladder for M x M blocks on ``device``.

        The base bucket is one tile of the fused solve kernel (the binding
        VMEM constraint among the solver kernels), so every rung is a tile
        multiple and the kernels never pad a partial tile.  ``max_bucket``
        caps a dispatch's |W| bytes at ``max_bucket_bytes``.  When ``stats``
        from earlier streams show more than ``WASTE_THRESHOLD`` padding at
        some bucket size, the ladder growth drops from 4 to 2 — trading one
        or two extra compiles for proportionally less sentinel work.

        The ladder is extended *below* the tile with power-of-two
        ``sub_rungs`` down to one VPU sublane (``min_bucket = VPU_ALIGN``),
        so the many-small-blocks streams of sequential solvers (SparseGPT /
        ALPS driving the service one sweep at a time) pad by at most
        ``VPU_ALIGN - 1`` sentinel blocks instead of a whole kernel tile.
        """
        from repro.kernels.fused_solve import fused_block_b
        from repro.kernels.vmem import VPU_ALIGN

        base = fused_block_b(m, device)
        max_bucket = max(
            base, (max_bucket_bytes // (4 * m * m)) // base * base
        )
        growth = 4
        if stats is not None:
            waste = stats.padding_waste()
            if waste and max(waste.values()) > cls.WASTE_THRESHOLD:
                growth = 2
        return cls(
            base=base,
            growth=growth,
            max_bucket=max_bucket,
            shard_devices=shard_devices,
            tail_decompose=True,
            min_bucket=min(VPU_ALIGN, base),
        )


@dataclasses.dataclass
class StreamStats:
    blocks_solved: int = 0     # real (non-sentinel) blocks dispatched
    blocks_padded: int = 0     # sentinel blocks added to fill buckets
    batches: int = 0           # device dispatches
    # Per-bucket-size accounting for the padding-waste baseline.
    bucket_blocks: dict[int, int] = dataclasses.field(default_factory=dict)
    bucket_padded: dict[int, int] = dataclasses.field(default_factory=dict)

    def note_batch(self, bucket: int, real: int, padded: int) -> None:
        self.blocks_solved += real
        self.blocks_padded += padded
        self.batches += 1
        self.bucket_blocks[bucket] = self.bucket_blocks.get(bucket, 0) + real + padded
        self.bucket_padded[bucket] = self.bucket_padded.get(bucket, 0) + padded

    def padding_waste(self) -> dict[int, float]:
        """bucket size -> padded fraction of all blocks dispatched at it."""
        return {
            b: self.bucket_padded.get(b, 0) / total
            for b, total in sorted(self.bucket_blocks.items())
            if total
        }

    def waste_summary(self) -> str:
        return " ".join(
            f"{b}:{frac:.3f}" for b, frac in self.padding_waste().items()
        ) or "-"

    def summary(self) -> str:
        """One-line aggregate of everything dispatched through these stats.

        This is the padding-waste report: ``solve_stream`` only *accumulates*
        here (per-stream chatter stays at DEBUG — a sequential solver calls
        ``solve_stream`` once per sweep, which used to flood the log with
        one INFO line per chunk), and consumers emit this line once per
        run (e.g. ``prune_transformer`` at the end of a prune).
        """
        return (
            f"blocks={self.blocks_solved} batches={self.batches} "
            f"padded={self.blocks_padded} "
            f"waste_per_bucket=[{self.waste_summary()}]"
        )


def pad_blocks_2d(w_abs: np.ndarray, m: int) -> tuple[np.ndarray, tuple[int, int]]:
    """numpy twin of ``core.blocks.pad_to_multiple`` (host-side packing)."""
    r, c = w_abs.shape
    pr, pc = (-r) % m, (-c) % m
    if pr or pc:
        w_abs = np.pad(w_abs, ((0, pr), (0, pc)))
    return w_abs, (r, c)


def to_blocks_2d(w_abs: np.ndarray, m: int) -> np.ndarray:
    """numpy twin of ``core.blocks.to_blocks``: (R, C) -> (B, M, M)."""
    r, c = w_abs.shape
    assert r % m == 0 and c % m == 0, (r, c, m)
    return np.ascontiguousarray(
        w_abs.reshape(r // m, m, c // m, m).transpose(0, 2, 1, 3).reshape(-1, m, m)
    )


def from_blocks_2d(blocks: np.ndarray, shape: tuple[int, int]) -> np.ndarray:
    """Inverse of :func:`to_blocks_2d`; ``shape`` is the padded matrix shape."""
    r, c = shape
    m = blocks.shape[-1]
    return blocks.reshape(r // m, c // m, m, m).transpose(0, 2, 1, 3).reshape(r, c)


def tensor_to_blocks(w: np.ndarray, m: int) -> tuple[np.ndarray, dict]:
    """|w| -> one (B, M, M) float32 block stream for a 2-D or stacked
    tensor (any leading dims: (L, R, C), (L, E, R, C), ...), plus the
    geometry needed to reassemble the mask."""
    w_abs = np.abs(np.asarray(w)).astype(np.float32)
    if w_abs.ndim == 2:
        padded, orig = pad_blocks_2d(w_abs, m)
        return to_blocks_2d(padded, m), {
            "shape": orig, "padded": padded.shape, "layers": None,
        }
    assert w_abs.ndim >= 3, w_abs.shape
    lead = w_abs.shape[:-2]
    flat = w_abs.reshape(-1, *w_abs.shape[-2:])
    slices = [pad_blocks_2d(flat[i], m) for i in range(flat.shape[0])]
    blocks = np.concatenate([to_blocks_2d(p, m) for p, _ in slices], axis=0)
    return blocks, {
        "shape": slices[0][1], "padded": slices[0][0].shape,
        "layers": flat.shape[0], "lead": lead,
    }


def blocks_to_mask(mask_blocks: np.ndarray, geom: dict) -> np.ndarray:
    """Reassemble a per-tensor bool mask from its solved block stream."""
    r, c = geom["shape"]
    if geom["layers"] is None:
        return from_blocks_2d(mask_blocks, geom["padded"])[:r, :c]
    per = mask_blocks.shape[0] // geom["layers"]
    out = np.stack([
        from_blocks_2d(mask_blocks[i * per : (i + 1) * per], geom["padded"])[:r, :c]
        for i in range(geom["layers"])
    ])
    lead = geom.get("lead")
    if lead is not None and tuple(lead) != out.shape[:1]:
        out = out.reshape(*lead, r, c)
    return out


# ---------------------------------------------------------------------------
# Mesh-sharded mega-batch dispatch.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _block_mesh(ndev: int):
    """1-D mesh over all local devices; blocks shard along it."""
    return compat.make_mesh(
        (ndev,), ("blocks",), axis_types=compat.auto_axis_types(1)
    )


def _solve_packed_fn(backend, pattern, config):
    """Device-side (B, M, M) -> (B, M) uint32 packed solve for ``backend``.

    Backends exposing ``solve_packed`` (the fused kernel) emit the words
    directly — the mask never exists unpacked on the device; for the rest
    the bool solve is bit-packed on device, so only the 32x-smaller words
    ever cross to the host.
    """
    from repro.sparsity import bitpack

    if hasattr(backend, "solve_packed"):
        return lambda blocks: backend.solve_packed(blocks, pattern, config)
    return lambda blocks: bitpack.pack_rows(
        backend.solve(blocks, pattern, config)
    )


@functools.lru_cache(maxsize=None)
def _sharded_solver(backend, n, m, iters, ls_steps, tau_scale, tol, ndev,
                    packed):
    """jitted shard_map of ``backend.solve`` over the local-device mesh.

    Cached per (backend *instance*, pattern, solver statics, device count) so
    repeat dispatches reuse the compiled program while a re-registered
    backend name (``register_backend(..., overwrite=True)``) gets a fresh
    entry instead of a stale one.
    """
    pattern = PatternSpec(n, m, True)
    config = SolverConfig(
        iters=iters, ls_steps=ls_steps, tau_scale=tau_scale, tol=tol,
        backend=backend.name,
    )

    if packed:
        solve_shard = _solve_packed_fn(backend, pattern, config)
    else:
        def solve_shard(blocks):
            return backend.solve(blocks, pattern, config)

    fn = compat.shard_map(
        solve_shard,
        mesh=_block_mesh(ndev),
        in_specs=P("blocks"),
        out_specs=P("blocks"),
        axis_names=frozenset({"blocks"}),
        check_vma=False,
    )
    return jax.jit(fn)


def dispatch_batch(
    batch: np.ndarray,
    pattern: PatternSpec,
    config: SolverConfig,
    shard_devices: bool = True,
    packed: bool = False,
) -> tuple[jnp.ndarray, int]:
    """Solve one mega-batch, sharded over local devices when possible.

    Returns ``(result, device_pad)`` where ``result`` is (B, M, M) bool
    masks, or (B, M) uint32 bit-packed rows when ``packed`` (32x less
    device->host traffic), and ``device_pad`` counts the sentinel blocks
    appended to make the batch divisible by the device count (already
    cropped from the result).
    """
    backend = get_backend(config.backend)
    ndev = jax.local_device_count()
    traceable = getattr(backend, "traceable", False)
    if shard_devices and ndev > 1 and traceable:
        pad = (-batch.shape[0]) % ndev
        if pad:
            batch = np.concatenate(
                [batch, np.zeros((pad,) + batch.shape[1:], batch.dtype)], axis=0
            )
        solver = _sharded_solver(
            backend, pattern.n, pattern.m,
            config.iters, config.ls_steps, config.tau_scale, config.tol,
            ndev, packed,
        )
        solved = solver(batch)
        return (solved[: solved.shape[0] - pad] if pad else solved), pad
    if packed:
        if traceable:
            return _solve_packed_fn(backend, pattern, config)(
                jnp.asarray(batch)
            ), 0
        from repro.sparsity import bitpack

        # Host-side backend (e.g. "exact"): pack on the host and stay there
        # — the consumer scatters from host memory anyway.
        solved = np.asarray(backend.solve(jnp.asarray(batch), pattern, config))
        return bitpack.pack_rows_np(solved), 0
    return backend.solve(jnp.asarray(batch), pattern, config), 0


def solve_stream(
    block_arrays: list[np.ndarray],
    pattern,
    config: SolverConfig = SolverConfig(),
    policy: BucketPolicy = BucketPolicy(),
    stats: StreamStats | None = None,
    packed: bool = False,
) -> list[np.ndarray]:
    """Solve a list of per-tensor (B_i, M, M) block streams as one bucketed
    mega-batch sequence; returns per-tensor bool mask block streams — or,
    with ``packed=True``, per-tensor (B_i, M) uint32 bit-packed mask rows
    (``repro.sparsity.bitpack`` layout; 32x less device->host traffic, and
    the format the service cache stores verbatim).

    All arrays must share the same M.  The concatenated stream is cut at
    bucket boundaries regardless of tensor boundaries, so one tensor may span
    several buckets and one bucket may hold many tensors.  ``pattern`` may be
    a :class:`PatternSpec` or a bare int N (M is the block side).
    """
    if not block_arrays:
        return []
    m = block_arrays[0].shape[-1]
    if isinstance(pattern, int) and not isinstance(pattern, bool):
        spec = PatternSpec(pattern, m, True)
    else:
        spec = PatternSpec.coerce(pattern)
    for a in block_arrays:
        assert a.ndim == 3 and a.shape[-2:] == (m, m), (a.shape, m)
    stats = stats if stats is not None else StreamStats()
    local = StreamStats()  # this stream only, for the log line

    total = sum(a.shape[0] for a in block_arrays)
    plan = policy.plan(total)

    # Cut the virtual concatenated stream into buckets, dispatch each without
    # blocking, and remember which (tensor, range) each bucket slice feeds.
    cursor_t, cursor_off = 0, 0
    pending = []  # (device result, [(tensor_idx, tensor_off, count, bucket_off)])
    for bucket in plan:
        parts, segmap = [], []
        filled = 0
        while filled < bucket and cursor_t < len(block_arrays):
            arr = block_arrays[cursor_t]
            take = min(bucket - filled, arr.shape[0] - cursor_off)
            parts.append(arr[cursor_off : cursor_off + take])
            segmap.append((cursor_t, cursor_off, take, filled))
            filled += take
            cursor_off += take
            if cursor_off == arr.shape[0]:
                cursor_t, cursor_off = cursor_t + 1, 0
        if filled < bucket:  # tail bucket: sentinel zero blocks
            parts.append(np.zeros((bucket - filled, m, m), np.float32))
        batch = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        solved, device_pad = dispatch_batch(
            batch, spec, config, shard_devices=policy.shard_devices,
            packed=packed,
        )
        for st in (stats, local):
            st.note_batch(bucket, filled, (bucket - filled) + device_pad)
        pending.append((solved, segmap))

    if packed:
        from repro.sparsity.bitpack import words_per_row

        wpr = words_per_row(m)
        word_shape = (m,) if wpr == 1 else (m, wpr)
        outs = [
            np.empty((a.shape[0],) + word_shape, dtype=np.uint32)
            for a in block_arrays
        ]
    else:
        outs = [
            np.empty((a.shape[0], m, m), dtype=bool) for a in block_arrays
        ]
    for solved, segmap in pending:
        host = np.asarray(solved)  # blocks until this bucket's solve is done
        for tensor_idx, tensor_off, count, bucket_off in segmap:
            outs[tensor_idx][tensor_off : tensor_off + count] = host[
                bucket_off : bucket_off + count
            ]
    # Per-stream accounting stays at DEBUG: sequential solvers invoke
    # solve_stream once per sweep, so an INFO line here fires per chunk of
    # the overall workload.  The aggregate is emitted once via
    # ``StreamStats.summary()`` (see ``MaskService.stats``).
    logger.debug(
        "solve_stream pattern=%s tensors=%d blocks=%d batches=%d padded=%d "
        "waste_per_bucket=[%s]",
        spec.canonical, len(block_arrays), local.blocks_solved, local.batches,
        local.blocks_padded, local.waste_summary(),
    )
    return outs
