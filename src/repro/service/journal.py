"""Append-only completion journal for resumable model-scale runs.

One JSON line per completed tensor:

    {"name": "layer003/mlp/down", "key": "<sha256>", "extra": {...}}

The journal is the unit of crash-resume: a killed run leaves the journal
with every tensor completed so far, and the next run skips straight past
them by fetching their payloads from the content store under the recorded
key.  Appends are flushed + fsynced per record so at most the in-flight
tensor is lost on a kill; a torn final line (crash mid-append) is ignored on
read, which is the same corruption discipline as ``CheckpointManager``'s
atomic commits.
"""
from __future__ import annotations

import json
import logging
import os
import threading
from typing import Optional

logger = logging.getLogger(__name__)


class Journal:
    def __init__(self, path: str):
        """``path``: journal file; parent directories are created."""
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._completed: Optional[dict[str, dict]] = None
        self._tail_checked = False
        # Appends come from whichever thread resolves a handle (foreground
        # flush, background drain, server scheduler); serialize them so two
        # records never interleave within one file write.
        self._lock = threading.Lock()

    def _needs_newline(self) -> bool:
        """True when the file ends mid-line (torn tail from a crash) — the
        next append must not glue onto it and corrupt itself too."""
        if self._tail_checked:
            return False
        self._tail_checked = True
        try:
            with open(self.path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                return f.read(1) != b"\n"
        except (OSError, ValueError):  # missing or empty file
            return False

    def completed(self) -> dict[str, dict]:
        """name -> record for every durably recorded tensor (last wins).

        Replay is crash-tolerant: a truncated *final* line (the partial
        write of a kill mid-append/fsync) is skipped with a warning so
        resume actually resumes — at most that one in-flight record is
        re-solved.  A malformed line anywhere *else* means real corruption
        (bit rot, concurrent writers without the lock); those are skipped
        too, but warned per-line with their position so the loss is
        visible instead of silently shrinking the resume set.
        """
        if self._completed is None:
            out: dict[str, dict] = {}
            if os.path.exists(self.path):
                with open(self.path) as f:
                    lines = f.readlines()
                for lineno, raw in enumerate(lines, start=1):
                    line = raw.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        if lineno == len(lines):
                            logger.warning(
                                "journal %s: skipping torn final record "
                                "(crash mid-append); the in-flight tensor "
                                "will re-solve", self.path,
                            )
                        else:
                            logger.warning(
                                "journal %s: skipping corrupt record at "
                                "line %d (not valid JSON)", self.path, lineno,
                            )
                        continue
                    if isinstance(rec, dict) and "name" in rec:
                        out[rec["name"]] = rec
            self._completed = out
        return self._completed

    def lookup(self, name: str) -> Optional[dict]:
        return self.completed().get(name)

    def sync(self) -> None:
        """Force the journal durable (drain/shutdown belt-and-braces).

        Every :meth:`record` already fsyncs, so this is normally a no-op —
        it exists for the server's graceful-drain sequence, which must not
        exit between a write and its fsync under any future buffering.
        """
        with self._lock:
            try:
                fd = os.open(self.path, os.O_RDONLY)
            except OSError:
                return  # nothing recorded yet
            try:
                os.fsync(fd)
            finally:
                os.close(fd)

    def record(self, name: str, key: str, **extra) -> None:
        rec = {"name": name, "key": key}
        if extra:
            rec.update(extra)
        with self._lock:
            lead = "\n" if self._needs_newline() else ""
            with open(self.path, "a") as f:
                f.write(lead + json.dumps(rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self.completed()[name] = rec
