"""Append-only completion journal for resumable model-scale runs.

One JSON line per completed tensor:

    {"name": "layer003/mlp/down", "key": "<sha256>", "extra": {...}}

The journal is the unit of crash-resume: a killed run leaves the journal
with every tensor completed so far, and the next run skips straight past
them by fetching their payloads from the content store under the recorded
key.  Appends are flushed + fsynced per record so at most the in-flight
tensor is lost on a kill; a torn final line (crash mid-append) is ignored on
read, which is the same corruption discipline as ``CheckpointManager``'s
atomic commits.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional


class Journal:
    def __init__(self, path: str):
        """``path``: journal file; parent directories are created."""
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._completed: Optional[dict[str, dict]] = None
        self._tail_checked = False
        # Appends come from whichever thread resolves a handle (foreground
        # flush, background drain, server scheduler); serialize them so two
        # records never interleave within one file write.
        self._lock = threading.Lock()

    def _needs_newline(self) -> bool:
        """True when the file ends mid-line (torn tail from a crash) — the
        next append must not glue onto it and corrupt itself too."""
        if self._tail_checked:
            return False
        self._tail_checked = True
        try:
            with open(self.path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                return f.read(1) != b"\n"
        except (OSError, ValueError):  # missing or empty file
            return False

    def completed(self) -> dict[str, dict]:
        """name -> record for every durably recorded tensor (last wins)."""
        if self._completed is None:
            out: dict[str, dict] = {}
            if os.path.exists(self.path):
                with open(self.path) as f:
                    for line in f:
                        line = line.strip()
                        if not line:
                            continue
                        try:
                            rec = json.loads(line)
                        except json.JSONDecodeError:
                            continue  # torn tail from a mid-append crash
                        if isinstance(rec, dict) and "name" in rec:
                            out[rec["name"]] = rec
            self._completed = out
        return self._completed

    def lookup(self, name: str) -> Optional[dict]:
        return self.completed().get(name)

    def record(self, name: str, key: str, **extra) -> None:
        rec = {"name": name, "key": key}
        if extra:
            rec.update(extra)
        with self._lock:
            lead = "\n" if self._needs_newline() else ""
            with open(self.path, "a") as f:
                f.write(lead + json.dumps(rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self.completed()[name] = rec
