"""Content-addressed cache for solved mask blocks.

Key format (see README "Mask service"):

    sha256( "tsenor-mask-v1" | n | m | solver fingerprint
            | block-array shape | block-array bytes )           -> hex digest

The hash runs over the exact (B, M, M) float32 ``|W|`` block stream the
solver consumes — after abs/cast/padding — so two tensors that produce the
same block stream share one cache entry regardless of where they came from.
The solver fingerprint covers every :class:`SolverConfig` field that can
change the output mask; bumping the version tag invalidates all entries when
solver semantics change.

The cache is two-level: an in-process dict in front of an optional
:class:`repro.checkpoint.ContentStore` (atomic ``<key>.npz`` files), which is
what makes re-pruning and crash-resume near-free.

On-disk payload format (versioned via the ``cache_format`` field):

* v2 (current): ``mask_bits`` — the bool block stream bit-packed with
  ``np.packbits`` (8x smaller than raw bool) — plus ``shape``.
* v1 (legacy): raw bool ``mask`` array.  Old entries still load.
"""
from __future__ import annotations

import hashlib
import warnings
from typing import Optional

import numpy as np

from repro.checkpoint.manager import ContentStore
from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec

_VERSION = "tsenor-mask-v1"
_CACHE_FORMAT = 2  # v2: packbits payload; v1 raw-bool entries still load


def solver_fingerprint(config: SolverConfig) -> str:
    """Stable string of the SolverConfig fields that affect the solved mask.

    ``block_batch`` is deliberately excluded: it only chunks the dispatch and
    never changes per-block results.  The backend is included out of caution
    — the Pallas path is verified equal to XLA in tests, but a cache must
    never have to trust that.  The two original backends keep their historic
    ``use_kernel=...`` spelling so pre-registry cache entries stay reachable.
    """
    if config.backend in ("dense-jit", "pallas"):
        backend_part = f"use_kernel={config.backend == 'pallas'}"
    else:
        backend_part = f"backend={config.backend}"
    return (
        f"iters={config.iters};ls_steps={config.ls_steps};"
        f"tau_scale={config.tau_scale!r};{backend_part}"
    )


def content_key(w_abs_blocks: np.ndarray, pattern, config=None, _legacy=None) -> str:
    """Content hash of one tensor's block stream + problem parameters.

    ``pattern`` is a :class:`PatternSpec` (or canonical string); the
    deprecated ``content_key(blocks, n, m, config)`` form still works.
    """
    if isinstance(pattern, int) and not isinstance(pattern, bool):
        warnings.warn(
            "content_key(blocks, n, m, config) is deprecated; pass a "
            "PatternSpec: content_key(blocks, pattern, config)",
            DeprecationWarning,
            stacklevel=2,
        )
        spec = PatternSpec(pattern, config, True)  # (n, m) legacy positions
        config = _legacy
    else:
        spec = PatternSpec.coerce(pattern)
    assert config is not None, "content_key needs a SolverConfig"
    blocks = np.ascontiguousarray(w_abs_blocks, dtype=np.float32)
    h = hashlib.sha256()
    h.update(_VERSION.encode())
    h.update(f"|n={spec.n}|m={spec.m}|{solver_fingerprint(config)}|".encode())
    h.update(str(blocks.shape).encode())
    h.update(blocks.tobytes())
    return h.hexdigest()


class MaskCache:
    """In-memory dict over an optional disk ContentStore; counts hits/misses."""

    def __init__(self, store: Optional[ContentStore] = None):
        self.store = store
        self._mem: dict[str, np.ndarray] = {}
        self.mem_hits = 0
        self.disk_hits = 0
        self.misses = 0

    def get(self, key: str) -> Optional[np.ndarray]:
        """Solved (B, M, M) bool mask blocks for ``key``, or None."""
        if key in self._mem:
            self.mem_hits += 1
            return self._mem[key]
        if self.store is not None and self.store.has(key):
            mask = _decode_entry(self.store.get(key))
            self._mem[key] = mask
            self.disk_hits += 1
            return mask
        self.misses += 1
        return None

    def put(self, key: str, mask_blocks: np.ndarray) -> None:
        mask = np.asarray(mask_blocks, dtype=bool)
        self._mem[key] = mask
        if self.store is not None:
            self.store.put(
                key,
                mask_bits=np.packbits(mask.reshape(-1)),
                shape=np.asarray(mask.shape, np.int64),
                cache_format=np.asarray(_CACHE_FORMAT, np.int64),
            )

    @property
    def hits(self) -> int:
        return self.mem_hits + self.disk_hits


def _decode_entry(data: dict[str, np.ndarray]) -> np.ndarray:
    """Decode a stored cache entry, tolerating the v1 raw-bool format."""
    if "mask_bits" in data:
        shape = tuple(int(v) for v in data["shape"])
        count = int(np.prod(shape)) if shape else 0
        return (
            np.unpackbits(data["mask_bits"], count=count)
            .astype(bool)
            .reshape(shape)
        )
    return data["mask"].astype(bool)  # v1: raw bool blocks
