"""Content-addressed cache for solved mask blocks.

Key format (see README "Mask service"):

    sha256( "tsenor-mask-v1" | n | m | solver fingerprint
            | block-array shape | block-array bytes )           -> hex digest

The hash runs over the exact (B, M, M) float32 ``|W|`` block stream the
solver consumes — after abs/cast/padding — so two tensors that produce the
same block stream share one cache entry regardless of where they came from.
The solver fingerprint covers every :class:`SolverConfig` field that can
change the output mask; bumping the version tag invalidates all entries when
solver semantics change.

The cache is two-level: an in-process dict in front of an optional
:class:`repro.checkpoint.ContentStore` (atomic ``<key>.npz`` files), which is
what makes re-pruning and crash-resume near-free.
"""
from __future__ import annotations

import hashlib
from typing import Optional

import numpy as np

from repro.checkpoint.manager import ContentStore
from repro.core.solver import SolverConfig

_VERSION = "tsenor-mask-v1"


def solver_fingerprint(config: SolverConfig) -> str:
    """Stable string of the SolverConfig fields that affect the solved mask.

    ``block_batch`` is deliberately excluded: it only chunks the dispatch and
    never changes per-block results.  ``use_kernel`` is included out of
    caution — the Pallas path is verified equal to XLA in tests, but a cache
    must never have to trust that.
    """
    return (
        f"iters={config.iters};ls_steps={config.ls_steps};"
        f"tau_scale={config.tau_scale!r};use_kernel={bool(config.use_kernel)}"
    )


def content_key(
    w_abs_blocks: np.ndarray, n: int, m: int, config: SolverConfig
) -> str:
    """Content hash of one tensor's block stream + problem parameters."""
    blocks = np.ascontiguousarray(w_abs_blocks, dtype=np.float32)
    h = hashlib.sha256()
    h.update(_VERSION.encode())
    h.update(f"|n={n}|m={m}|{solver_fingerprint(config)}|".encode())
    h.update(str(blocks.shape).encode())
    h.update(blocks.tobytes())
    return h.hexdigest()


class MaskCache:
    """In-memory dict over an optional disk ContentStore; counts hits/misses."""

    def __init__(self, store: Optional[ContentStore] = None):
        self.store = store
        self._mem: dict[str, np.ndarray] = {}
        self.mem_hits = 0
        self.disk_hits = 0
        self.misses = 0

    def get(self, key: str) -> Optional[np.ndarray]:
        """Solved (B, M, M) bool mask blocks for ``key``, or None."""
        if key in self._mem:
            self.mem_hits += 1
            return self._mem[key]
        if self.store is not None and self.store.has(key):
            mask = self.store.get(key)["mask"].astype(bool)
            self._mem[key] = mask
            self.disk_hits += 1
            return mask
        self.misses += 1
        return None

    def put(self, key: str, mask_blocks: np.ndarray) -> None:
        mask = np.asarray(mask_blocks, dtype=bool)
        self._mem[key] = mask
        if self.store is not None:
            # np.packbits would halve the footprint further; bool npz already
            # compresses the 1-bit payload well enough for mask volumes.
            self.store.put(key, mask=mask)

    @property
    def hits(self) -> int:
        return self.mem_hits + self.disk_hits
