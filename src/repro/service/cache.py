"""Content-addressed cache for solved mask blocks.

Key format (see README "Mask service"):

    sha256( "tsenor-mask-v1" | n | m | solver fingerprint
            | block-array shape | block-array bytes )           -> hex digest

The hash runs over the exact (B, M, M) float32 ``|W|`` block stream the
solver consumes — after abs/cast/padding — so two tensors that produce the
same block stream share one cache entry regardless of where they came from.
The solver fingerprint covers every :class:`SolverConfig` field that can
change the output mask; bumping the version tag invalidates all entries when
solver semantics change.

The cache is two-level: an in-process dict in front of an optional
:class:`repro.checkpoint.ContentStore` (atomic ``<key>.npz`` files), which is
what makes re-pruning and crash-resume near-free.

On-disk payload format (versioned via the ``cache_format`` field):

* v3 (current): ``mask_words`` — (B, M) uint32 bit-packed mask rows in the
  ``repro.sparsity.bitpack`` layout (bit j of a row word = column j), plus
  ``shape``.  This is exactly what the ``pallas-fused`` kernel writes and
  what the packed scheduler path ships to the host, so a solved mega-batch
  feeds the cache with no host-side repacking; it is also the in-memory
  representation (32x smaller than raw bool).
* v2 (legacy): ``mask_bits`` — the bool stream packed with ``np.packbits``
  — plus ``shape``.  Still loads.
* v1 (legacy): raw bool ``mask`` array.  Still loads.
"""
from __future__ import annotations

import hashlib
import time
import warnings
from typing import Optional

import numpy as np

from repro.checkpoint.manager import ContentStore
from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec
from repro.sparsity import bitpack

_VERSION = "tsenor-mask-v1"
_CACHE_FORMAT = 3  # v3: uint32 row-words payload; v1/v2 entries still load


def solver_fingerprint(config: SolverConfig) -> str:
    """Stable string of the SolverConfig fields that affect the solved mask.

    ``block_batch`` is deliberately excluded: it only chunks the dispatch and
    never changes per-block results.  The backend is included out of caution
    — the Pallas path is verified equal to XLA in tests, but a cache must
    never have to trust that.  The two original backends keep their historic
    ``use_kernel=...`` spelling so pre-registry cache entries stay reachable.
    """
    if config.backend in ("dense-jit", "pallas"):
        backend_part = f"use_kernel={config.backend == 'pallas'}"
    else:
        backend_part = f"backend={config.backend}"
    # tol=0 keeps the historic fingerprint so pre-tol cache entries stay
    # reachable; any other tolerance changes the solved mask and must miss.
    tol_part = f";tol={config.tol!r}" if getattr(config, "tol", 0.0) else ""
    return (
        f"iters={config.iters};ls_steps={config.ls_steps};"
        f"tau_scale={config.tau_scale!r};{backend_part}{tol_part}"
    )


def content_key(w_abs_blocks: np.ndarray, pattern, config=None, _legacy=None) -> str:
    """Content hash of one tensor's block stream + problem parameters.

    ``pattern`` is a :class:`PatternSpec` (or canonical string); the
    deprecated ``content_key(blocks, n, m, config)`` form still works.
    """
    if isinstance(pattern, int) and not isinstance(pattern, bool):
        warnings.warn(
            "content_key(blocks, n, m, config) is deprecated; pass a "
            "PatternSpec: content_key(blocks, pattern, config)",
            DeprecationWarning,
            stacklevel=2,
        )
        spec = PatternSpec(pattern, config, True)  # (n, m) legacy positions
        config = _legacy
    else:
        spec = PatternSpec.coerce(pattern)
    assert config is not None, "content_key needs a SolverConfig"
    blocks = np.ascontiguousarray(w_abs_blocks, dtype=np.float32)
    h = hashlib.sha256()
    h.update(_VERSION.encode())
    h.update(f"|n={spec.n}|m={spec.m}|{solver_fingerprint(config)}|".encode())
    h.update(str(blocks.shape).encode())
    h.update(blocks.tobytes())
    return h.hexdigest()


class MaskCache:
    """In-memory dict over an optional disk ContentStore; counts hits/misses.

    Entries are held (in memory and on disk) as ``(words, shape)``: the
    (B, M) uint32 bit-packed rows of the (B, M, M) bool block masks.  The
    packed accessors are the native path; ``get``/``put`` keep the bool API
    for callers that want materialized masks.
    """

    def __init__(self, store: Optional[ContentStore] = None,
                 track_access: bool = False):
        self.store = store
        # When a byte bound will prune this store, mem hits must bump the
        # disk LRU clock too (or the hottest keys evict first); unbounded
        # caches skip the per-hit utime syscall.
        self.track_access = track_access
        self._mem: dict[str, tuple[np.ndarray, tuple[int, ...]]] = {}
        self.mem_hits = 0
        self.disk_hits = 0
        self.misses = 0
        # Observed disk-read cost, the denominator of the size-aware
        # admission policy (MaskService.cache_admission_min_blocks): entries
        # whose re-solve is faster than one mean store read skip the disk.
        self.read_seconds = 0.0
        self.disk_reads = 0

    def get_packed(
        self, key: str
    ) -> Optional[tuple[np.ndarray, tuple[int, ...]]]:
        """((B, M) uint32 words, (B, M, M) shape) for ``key``, or None."""
        if key in self._mem:
            self.mem_hits += 1
            if self.store is not None and self.track_access:
                self.store.touch(key)
            return self._mem[key]
        if self.store is not None:
            t0 = time.monotonic()
            # get_or_none, not has()+get(): another process's prune() may
            # delete the entry between the two calls — the store tolerates
            # the race and this cache sees a plain miss, never an OSError.
            data = self.store.get_or_none(key)
            if data is not None:
                try:
                    entry = _decode_entry(data)
                except (KeyError, ValueError):
                    # Foreign/corrupt payload under our key: treat as miss.
                    self.misses += 1
                    return None
                self.read_seconds += time.monotonic() - t0
                self.disk_reads += 1
                self._mem[key] = entry
                self.disk_hits += 1
                return entry
        self.misses += 1
        return None

    def mean_read_seconds(self) -> Optional[float]:
        """Mean observed wall time of one disk read (open + decompress +
        decode), or None with no disk store / no reads yet.  Per-entry, not
        per-byte: for the word-packed payloads this store holds, the open
        and zip overheads dominate far past the admission-relevant sizes."""
        if self.store is None or not self.disk_reads:
            return None
        return self.read_seconds / self.disk_reads

    def get(self, key: str) -> Optional[np.ndarray]:
        """Solved (B, M, M) bool mask blocks for ``key``, or None."""
        entry = self.get_packed(key)
        if entry is None:
            return None
        words, shape = entry
        return bitpack.unpack_rows_np(words, shape[-1]).reshape(shape)

    def put_packed(
        self, key: str, words: np.ndarray, shape: tuple[int, ...],
        disk: bool = True,
    ) -> None:
        """Store bit-packed mask rows verbatim (no repacking round-trip).

        ``disk=False`` keeps the entry in the in-memory front only — the
        size-aware admission path for entries cheaper to re-solve than to
        read back (``MaskService.cache_admission_min_blocks``)."""
        words = np.asarray(words, np.uint32)
        shape = tuple(int(v) for v in shape)
        self._mem[key] = (words, shape)
        if self.store is not None and disk:
            self.store.put(
                key,
                mask_words=words,
                shape=np.asarray(shape, np.int64),
                cache_format=np.asarray(_CACHE_FORMAT, np.int64),
            )

    def put(self, key: str, mask_blocks: np.ndarray) -> None:
        mask = np.asarray(mask_blocks, dtype=bool)
        self.put_packed(key, bitpack.pack_rows_np(mask), mask.shape)

    def prune(self, max_bytes: int) -> list[str]:
        """Bound the *disk* store to ``max_bytes`` via LRU eviction
        (:meth:`repro.checkpoint.ContentStore.prune`); returns evicted keys.
        The in-memory front stays intact — its entries are still-valid
        content and re-persist naturally if solved again after a restart."""
        if self.store is None:
            return []
        return self.store.prune(max_bytes)

    @property
    def hits(self) -> int:
        return self.mem_hits + self.disk_hits


def _decode_entry(
    data: dict[str, np.ndarray]
) -> tuple[np.ndarray, tuple[int, ...]]:
    """Decode a stored entry to (words, shape), tolerating v1/v2 formats."""
    if "mask_words" in data:  # v3: native packed rows
        shape = tuple(int(v) for v in data["shape"])
        return np.asarray(data["mask_words"], np.uint32), shape
    if "mask_bits" in data:  # v2: np.packbits payload
        shape = tuple(int(v) for v in data["shape"])
        count = int(np.prod(shape)) if shape else 0
        mask = (
            np.unpackbits(data["mask_bits"], count=count)
            .astype(bool)
            .reshape(shape)
        )
    else:  # v1: raw bool blocks
        mask = data["mask"].astype(bool)
    return bitpack.pack_rows_np(mask), mask.shape
