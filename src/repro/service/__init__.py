"""Batched mask-solver engine: shape-bucketed scheduling, content-addressed
caching, and resumable model-scale pruning.

The per-tensor API (``core.solver.solve_mask``) re-dispatches and
re-compiles per weight matrix; this package treats the whole model as one
stream of M x M block problems instead — ``MaskService.solve(w, pattern)``
is the canonical solve path.  Mega-batches shard over all local devices via
``compat.shard_map``.  See README "Mask service" for the architecture and
``examples/mask_service.py`` for a runnable tour.
"""
from repro.service.cache import MaskCache, content_key, solver_fingerprint
from repro.service.engine import (
    FlushTicket,
    MaskHandle,
    MaskService,
    ServiceStats,
)
from repro.service.journal import Journal
from repro.service.scheduler import BucketPolicy, StreamStats, solve_stream

# The network front-end imports the engine above — keep it last.
from repro.service.net import (  # noqa: E402
    MaskClient,
    MaskServer,
    RemoteError,
    RetryPolicy,
    TenantConfig,
)

__all__ = [
    "BucketPolicy",
    "FlushTicket",
    "Journal",
    "MaskCache",
    "MaskClient",
    "MaskHandle",
    "MaskServer",
    "MaskService",
    "RemoteError",
    "RetryPolicy",
    "ServiceStats",
    "StreamStats",
    "TenantConfig",
    "content_key",
    "solver_fingerprint",
    "solve_stream",
]
