"""Host-side input pipeline: background prefetch + device placement.

Wraps any ``batch(step)`` source (SyntheticLM/SyntheticEmbeds or a real
corpus reader with the same contract) with a prefetch thread and sharded
``jax.device_put``.  State is just the step counter — checkpoint/resume needs
no iterator files (the source is a pure function of the step).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Optional

import jax


class Prefetcher:
    def __init__(
        self,
        source: Any,
        start_step: int = 0,
        prefetch: int = 2,
        shardings: Optional[dict] = None,
    ):
        self.source = source
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _place(self, batch: dict) -> dict:
        out = {}
        for k, v in batch.items():
            sh = (self.shardings or {}).get(k)
            out[k] = jax.device_put(v, sh) if sh is not None else jax.numpy.asarray(v)
        return out

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            self._q.put((step, batch))
            step += 1

    def batch(self, step: int) -> dict:
        """TrainLoop-compatible: returns the batch for ``step`` (prefetched
        when consumed sequentially; falls back to direct compute on skips)."""
        while True:
            try:
                s, b = self._q.get(timeout=60)
            except queue.Empty:  # producer died
                return self._place(self.source.batch(step))
            if s == step:
                return self._place(b)
            if s > step:  # resumed backwards: compute directly
                return self._place(self.source.batch(step))
            # s < step: drain stale entries

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
