"""Deterministic synthetic data pipeline (offline container: no corpora)."""
from repro.data.synthetic import SyntheticLM, SyntheticEmbeds, calibration_batch

__all__ = ["SyntheticLM", "SyntheticEmbeds", "calibration_batch"]
