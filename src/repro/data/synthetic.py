"""Deterministic synthetic token/embedding streams.

Every batch is a pure function of (seed, step) via a splitmix64-style hash,
so the pipeline is: (1) resumable from a checkpointed step counter alone —
no iterator state files; (2) identical across hosts — each data shard slices
the same global batch, which is what a multi-host input pipeline must
guarantee; (3) cheap enough to never bottleneck the CPU container.

The token stream is *learnable* (a noisy Markov chain over the vocab), so a
few hundred training steps show a clearly decreasing loss — used by the
end-to-end example and the fine-tuning benchmark.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class SyntheticLM:
    """Noisy-Markov synthetic LM data: batch(step) -> tokens/labels."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 3          # next token depends on previous via affine map
    noise: int = 7          # 1-in-noise tokens are uniform random

    def batch(self, step: int) -> dict:
        b, s, v = self.global_batch, self.seq_len + 1, self.vocab_size
        idx = np.arange(b, dtype=np.uint64) + np.uint64(step) * np.uint64(b)
        seeds = _splitmix64(idx ^ np.uint64(self.seed * 0x9E3779B9))
        toks = np.zeros((b, s), np.int64)
        toks[:, 0] = (seeds % np.uint64(v)).astype(np.int64)
        state = seeds
        for t in range(1, s):
            state = _splitmix64(state)
            markov = (toks[:, t - 1] * self.order + 1) % v
            rnd = (state % np.uint64(v)).astype(np.int64)
            use_rnd = (state >> np.uint64(32)) % np.uint64(self.noise) == 0
            toks[:, t] = np.where(use_rnd, rnd, markov)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass
class SyntheticEmbeds:
    """Stub modality frontend (vlm/audio): precomputed frame/patch embeds."""

    d_model: int
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        b, s, d = self.global_batch, self.seq_len, self.d_model
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        embeds = rng.standard_normal((b, s, d), np.float32) * 0.02
        labels = rng.integers(0, self.vocab_size, (b, s)).astype(np.int32)
        return {"embeds": embeds, "labels": labels}


def calibration_batch(
    vocab_size: int, seq_len: int, batch: int, seed: int = 0
) -> np.ndarray:
    """Token batch for layer-wise pruning calibration."""
    data = SyntheticLM(vocab_size, seq_len, batch, seed=seed)
    return data.batch(0)["tokens"]
