"""Pytree key-path stringification, shared by every subsystem.

One precedence (``key`` → ``idx`` → ``name``) for turning a
``jax.tree_util`` path entry (``DictKey``/``SequenceKey``/``GetAttrKey``/
legacy objects) into a string, so mask names (``sparsity.masks``),
checkpoint leaf files (``checkpoint.manager``) and compressed-leaf
identification (``sparsity.params``) all agree on how a leaf is addressed.
Dependency-free (no jax import) on purpose.
"""
from __future__ import annotations


def path_entry_str(entry) -> str:
    """String form of one key-path entry."""
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def path_str(path, sep: str = "/") -> str:
    """Join a whole key path (tuple of entries) with ``sep``."""
    return sep.join(path_entry_str(p) for p in path)
