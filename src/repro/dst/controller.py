"""MaskRefreshController: re-solve masks under a live trainer, stall-free.

The refresh lifecycle (one "refresh" = one support swap):

::

    step t                 steps t..t+k-1           step t+k  (= swap step)
    ------                 ----------------         ------------------------
    snapshot |W_t|     →   trainer keeps stepping;  wait() the flush ticket
    submit_many to the     MaskService solves the   (normally already done),
    MaskService, start     new masks on its back-   recompress SparseParams
    a background flush     ground flush thread      + remap AdamW moments

The controller is pure host-side bookkeeping between jitted steps: it never
touches the step function's trace.  Swapping a pattern with a different N
changes the compressed leaf shapes, so ``jax.jit`` re-traces the step once
per schedule stage — expected and paid once per stage, not per step.

Two modes:

* ``mode="async"`` (default) — the lifecycle above: masks for step
  ``t+lookahead`` are solved from step-``t`` weights while training
  continues (Hubara et al.'s transposable-mask training regime; the
  ``lookahead`` staleness is the price of never stalling the step loop).
* ``mode="sync"`` — snapshot, solve and swap all at the swap step.  Slower
  (the trainer blocks on the solve) but *bit-identical* to calling
  ``sparsify_pytree`` + ``recompress`` + ``remap_moments`` by hand at that
  step (property-tested in ``tests/test_dst.py``), which makes it the
  correctness oracle for the async path.

Checkpoint integration: ``state_dict()`` rides checkpoint metadata (see
``TrainLoop``); on resume, a refresh that was in flight is re-armed — the
solve re-submits from the restored weights, and the MaskService content
cache (same weights → same key) turns the re-solve into a hit whenever the
restored state matches the snapshotted one.

Failure tolerance: a refresh is an *optimization*, never a liveness
dependency of the train loop.  When the solve fails or times out (a remote
:class:`~repro.service.net.MaskClient` whose retry budget ran dry, a
``refresh_timeout_s`` overrun), the swap is skipped — training continues
under the old support, a ``failed`` :class:`RefreshEvent` records the root
cause, and the refresh re-arms at the next cadence (the same descriptor
mechanism checkpoint resume uses), up to ``max_refresh_retries`` before the
stage's refresh is abandoned.  Nothing raises into the step loop.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.solver import SolverConfig
from repro.dst.schedule import SparsitySchedule, schedule_from_spec
from repro.dst.telemetry import RefreshEvent
from repro.patterns import PatternSpec
from repro.service.engine import FlushTicket, MaskService
from repro.sparsity.params import (
    NMCompressed,
    recompress,
    remap_tree,
)
from repro.treepath import path_str

MODES = ("async", "sync")


class _Ticket:
    """One in-flight refresh: submitted handles + where/when they land."""

    def __init__(self, submit_step: int, swap_step: int, pattern: PatternSpec,
                 handles: list, treedef, flush: Optional[FlushTicket],
                 retries: int = 0):
        self.submit_step = submit_step
        self.swap_step = swap_step
        self.pattern = pattern
        self.handles = handles      # aligned with treedef; None at dense leaves
        self.treedef = treedef
        self.flush = flush          # None in sync mode (solved inline)
        self.retries = retries      # failed attempts behind this refresh


class MaskRefreshController:
    """Evolves the transposable N:M support of a compressed TrainState.

    Drive it through ``StepConfig(refresh=controller)`` (the step builder
    wraps the jitted step with :meth:`on_step`) or call :meth:`on_step`
    yourself with the pre-step host step counter and TrainState.

    Args:
      schedule: a :class:`~repro.dst.schedule.SparsitySchedule`.
      service: MaskService the re-solves route through (its SolverConfig
        shapes the masks); a fresh in-memory one per controller by default.
        A :class:`repro.service.net.MaskClient` works here unchanged — the
        trainer keeps stepping while a remote solver box does the refresh
        (``flush_async`` drains over the wire on a background thread).
      lookahead: async mode's snapshot-to-swap distance k — masks landing
        at step ``s`` are solved from step ``s - k`` weights.
      mode: ``"async"`` or ``"sync"`` (see module docstring).
      log: line sink for per-refresh summaries.
      refresh_timeout_s: cap on how long a due swap may block on its flush
        ticket before the refresh counts as failed (old mask kept, retry
        re-armed).  None (default) waits as long as the service does — the
        right setting for an in-process service; set it when the service is
        a remote client whose outage should cost bounded trainer time.
      max_refresh_retries: failed attempts per refresh before the swap is
        abandoned for good (the schedule moves on to its next stage).
    """

    def __init__(
        self,
        schedule: SparsitySchedule,
        service: Optional[MaskService] = None,
        solver: Optional[SolverConfig] = None,
        lookahead: int = 10,
        mode: str = "async",
        log: Callable[[str], None] = lambda s: None,
        refresh_timeout_s: Optional[float] = None,
        max_refresh_retries: int = 3,
    ):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if lookahead < 0:
            raise ValueError(f"lookahead must be >= 0, got {lookahead}")
        self.schedule = schedule
        self.service = service if service is not None else \
            MaskService(solver if solver is not None else SolverConfig())
        if refresh_timeout_s is not None and refresh_timeout_s <= 0:
            raise ValueError(
                f"refresh_timeout_s must be > 0, got {refresh_timeout_s}"
            )
        self.lookahead = lookahead if mode == "async" else 0
        self.mode = mode
        self.log = log
        self.refresh_timeout_s = refresh_timeout_s
        self.max_refresh_retries = max_refresh_retries
        self.events: list[RefreshEvent] = []
        self._ticket: Optional[_Ticket] = None
        self._next_scan = 1  # swap step 0 is the initial compression
        self._rearm: Optional[dict] = None  # resume: re-submit descriptor

    # -- the per-step hook ---------------------------------------------------

    def on_step(self, step: int, state):
        """Pre-step hook: apply a due swap, then arm a due refresh.

        ``step`` is the step about to run; a swap whose ``swap_step <= step``
        takes effect now, so that step already trains under the new support.
        Returns the (possibly swapped) TrainState.
        """
        state = self._maybe_swap(step, state)
        self._maybe_submit(step, state)
        # Sync mode (and a resumed/late async ticket): the refresh armed for
        # this very step completes before the step runs.
        state = self._maybe_swap(step, state)
        return state

    # -- submit side ---------------------------------------------------------

    def _maybe_submit(self, step: int, state) -> None:
        if self._rearm is not None and self._ticket is None:
            d, self._rearm = self._rearm, None
            self._try_submit(step, max(d["swap_step"], step),
                             PatternSpec.parse(d["pattern"]), state,
                             retries=int(d.get("retries", 0)))
        limit = step + self.lookahead
        s = self._next_scan
        while s <= limit:
            target = self.schedule.swap_at(s)
            if target is not None:
                if self._ticket is not None:
                    break  # one refresh in flight at a time; retry next step
                self._try_submit(step, s, target, state)
                s += 1
                break
            s += 1
        self._next_scan = s

    def _try_submit(self, step: int, swap_step: int, pattern: PatternSpec,
                    state, retries: int = 0) -> None:
        """Arm a refresh; a submission that fails outright (e.g. a remote
        client whose retry budget ran dry with no fallback) is recorded and
        re-armed instead of raising into the train loop."""
        try:
            self._submit(step, swap_step, pattern, state, retries=retries)
        except (OSError, RuntimeError) as e:
            self._ticket = None
            self._record_failure(step, swap_step, pattern, e, 0.0,
                                 synchronous=self.mode == "sync",
                                 submit_step=step, retries=retries)

    def _submit(self, step: int, swap_step: int, pattern: PatternSpec,
                state, retries: int = 0) -> None:
        params = state.params
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda x: isinstance(x, NMCompressed)
        )
        handles = []
        for path, leaf in flat:
            if not isinstance(leaf, NMCompressed):
                handles.append(None)
                continue
            # Magnitude scores from the live compressed weights: positions
            # outside the current support decompress to 0, so a refresh can
            # tighten or re-arrange the support but never resurrect a slot
            # the trainer has no value for.
            w = leaf.decompress()
            handles.append(self.service.submit(
                f"{path_str(path)}@{swap_step}", w, pattern, journal=False
            ))
        flush = None
        if self.mode == "async":
            flush = self.service.flush_async()
        self._ticket = _Ticket(step, swap_step, pattern, handles, treedef,
                               flush, retries=retries)

    # -- swap side -----------------------------------------------------------

    def _maybe_swap(self, step: int, state):
        tk = self._ticket
        if tk is None or step < tk.swap_step:
            return state
        t0 = time.perf_counter()
        try:
            if tk.flush is not None:
                if not tk.flush.wait(timeout=self.refresh_timeout_s):
                    raise TimeoutError(
                        f"refresh flush still running after "
                        f"refresh_timeout_s={self.refresh_timeout_s}"
                    )
            else:
                self.service.flush()
            masks_flat = [
                None if h is None else h.result() for h in tk.handles
            ]
        except (OSError, RuntimeError) as e:
            # The solve never landed (dead service past its retry budget,
            # timeout, failed flush).  Keep training under the old support;
            # the refresh re-arms at the next cadence.
            self._ticket = None
            self._record_failure(
                step, tk.swap_step, tk.pattern, e,
                time.perf_counter() - t0, synchronous=tk.flush is None,
                submit_step=tk.submit_step, retries=tk.retries,
            )
            return state
        wait = time.perf_counter() - t0
        masks = jax.tree_util.tree_unflatten(tk.treedef, masks_flat)
        new_params, flips = recompress(state.params, masks, tk.pattern)
        from repro.optim.adamw import remap_moments

        new_opt = remap_moments(state.opt_state, state.params, new_params)
        new_ef = state.ef
        if new_ef is not None:
            new_ef = remap_tree(new_ef, state.params, new_params)
        event = RefreshEvent(
            submit_step=tk.submit_step,
            swap_step=tk.swap_step,
            pattern=tk.pattern.canonical,
            wait_seconds=wait,
            solve_seconds=tk.flush.seconds if tk.flush is not None else wait,
            synchronous=tk.flush is None,
            flips=flips,
        ).finalize()
        self.events.append(event)
        self.log(f"[dst] {event.summary()}")
        self._ticket = None
        return state._replace(params=new_params, opt_state=new_opt,
                              ef=new_ef)

    def _record_failure(self, step: int, swap_step: int,
                        pattern: PatternSpec, error: BaseException,
                        wait: float, *, synchronous: bool, submit_step: int,
                        retries: int) -> None:
        """Record a failed refresh and re-arm it one cadence out (or abandon
        past ``max_refresh_retries``).  The re-arm rides the same descriptor
        checkpoint resume uses, so a run killed mid-outage resumes with its
        pending retry intact."""
        event = RefreshEvent(
            submit_step=submit_step,
            swap_step=swap_step,
            pattern=pattern.canonical,
            wait_seconds=wait,
            synchronous=synchronous,
            failed=True,
            error=f"{type(error).__name__}: {error}",
        ).finalize()
        self.events.append(event)
        self.log(f"[dst] {event.summary()}")
        if retries < self.max_refresh_retries:
            # Next cadence, never this step: swapping at <= step would make
            # the second _maybe_swap of this very on_step block the trainer
            # synchronously on a service that just failed.
            self._rearm = {
                "submit_step": submit_step,
                "swap_step": step + max(1, self.lookahead),
                "pattern": pattern.canonical,
                "retries": retries + 1,
            }
        else:
            self.log(
                f"[dst] refresh {pattern.canonical} abandoned after "
                f"{retries + 1} failed attempts; training continues under "
                f"the old mask"
            )

    # -- checkpoint integration ---------------------------------------------

    def state_dict(self) -> dict:
        """Json-serializable refresh state for checkpoint metadata."""
        tk = self._ticket
        return {
            "version": 1,
            "schedule": self.schedule.spec(),
            "mode": self.mode,
            "lookahead": self.lookahead,
            "next_scan": self._next_scan,
            "inflight": self._rearm if tk is None else {
                "submit_step": tk.submit_step,
                "swap_step": tk.swap_step,
                "pattern": tk.pattern.canonical,
                "retries": tk.retries,
            },
            "events": [e.to_json() for e in self.events],
        }

    def load_state_dict(self, d: dict) -> None:
        """Resume from :meth:`state_dict` metadata.

        The schedule must match the checkpointed one (a DST run's masks are
        meaningless under a different schedule).  An in-flight refresh is
        re-armed: the next :meth:`on_step` re-snapshots the restored weights
        and re-submits for the same swap step — the service's content cache
        dedupes when the weights are the ones originally snapshotted.
        """
        saved = schedule_from_spec(d["schedule"])
        if saved.spec() != self.schedule.spec():
            raise ValueError(
                "resuming a DST run under a different schedule: checkpoint "
                f"has {saved.spec()}, controller has {self.schedule.spec()}"
            )
        self._next_scan = int(d["next_scan"])
        self._rearm = d.get("inflight")
        self._ticket = None
        self.events = [RefreshEvent.from_json(e) for e in d.get("events", [])]

    # -- telemetry -----------------------------------------------------------

    def stall_seconds(self) -> float:
        """Trainer time spent blocked on async flushes (the number the
        ``benchmarks/dst_loop.py`` gate holds near zero)."""
        return float(sum(
            e.wait_seconds for e in self.events if not e.synchronous
        ))

    def telemetry(self) -> dict:
        """Json-ready rollup (written into ``BENCH_dst.json``)."""
        return {
            "mode": self.mode,
            "lookahead": self.lookahead,
            "refreshes": len(self.events),
            "failed_refreshes": sum(1 for e in self.events if e.failed),
            "stall_seconds": self.stall_seconds(),
            "events": [e.to_json() for e in self.events],
            "service": {
                "submitted": self.service.stats.submitted,
                "cache_hits": self.service.stats.cache_hits,
                "dedup_hits": self.service.stats.dedup_hits,
            },
        }


def wrap_step_with_refresh(step_fn: Callable, controller: Any) -> Callable:
    """Wrap a jitted ``step(state, batch)`` so each call first routes the
    pre-step state through ``controller.on_step``.  The controller is
    exposed as ``.refresh`` on the wrapper (``TrainLoop`` discovers it there
    for checkpoint metadata)."""

    def step_with_refresh(state, batch):
        t = int(np.asarray(jax.tree.leaves(state.step)[0]))
        state = controller.on_step(t, state)
        return step_fn(state, batch)

    step_with_refresh.refresh = controller
    return step_with_refresh
