"""MaskRefreshController: re-solve masks under a live trainer, stall-free.

The refresh lifecycle (one "refresh" = one support swap):

::

    step t                 steps t..t+k-1           step t+k  (= swap step)
    ------                 ----------------         ------------------------
    snapshot |W_t|     →   trainer keeps stepping;  wait() the flush ticket
    submit_many to the     MaskService solves the   (normally already done),
    MaskService, start     new masks on its back-   recompress SparseParams
    a background flush     ground flush thread      + remap AdamW moments

The controller is pure host-side bookkeeping between jitted steps: it never
touches the step function's trace.  Swapping a pattern with a different N
changes the compressed leaf shapes, so ``jax.jit`` re-traces the step once
per schedule stage — expected and paid once per stage, not per step.

Two modes:

* ``mode="async"`` (default) — the lifecycle above: masks for step
  ``t+lookahead`` are solved from step-``t`` weights while training
  continues (Hubara et al.'s transposable-mask training regime; the
  ``lookahead`` staleness is the price of never stalling the step loop).
* ``mode="sync"`` — snapshot, solve and swap all at the swap step.  Slower
  (the trainer blocks on the solve) but *bit-identical* to calling
  ``sparsify_pytree`` + ``recompress`` + ``remap_moments`` by hand at that
  step (property-tested in ``tests/test_dst.py``), which makes it the
  correctness oracle for the async path.

Checkpoint integration: ``state_dict()`` rides checkpoint metadata (see
``TrainLoop``); on resume, a refresh that was in flight is re-armed — the
solve re-submits from the restored weights, and the MaskService content
cache (same weights → same key) turns the re-solve into a hit whenever the
restored state matches the snapshotted one.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.solver import SolverConfig
from repro.dst.schedule import SparsitySchedule, schedule_from_spec
from repro.dst.telemetry import RefreshEvent
from repro.patterns import PatternSpec
from repro.service.engine import FlushTicket, MaskService
from repro.sparsity.params import (
    NMCompressed,
    recompress,
    remap_tree,
)
from repro.treepath import path_str

MODES = ("async", "sync")


class _Ticket:
    """One in-flight refresh: submitted handles + where/when they land."""

    def __init__(self, submit_step: int, swap_step: int, pattern: PatternSpec,
                 handles: list, treedef, flush: Optional[FlushTicket]):
        self.submit_step = submit_step
        self.swap_step = swap_step
        self.pattern = pattern
        self.handles = handles      # aligned with treedef; None at dense leaves
        self.treedef = treedef
        self.flush = flush          # None in sync mode (solved inline)


class MaskRefreshController:
    """Evolves the transposable N:M support of a compressed TrainState.

    Drive it through ``StepConfig(refresh=controller)`` (the step builder
    wraps the jitted step with :meth:`on_step`) or call :meth:`on_step`
    yourself with the pre-step host step counter and TrainState.

    Args:
      schedule: a :class:`~repro.dst.schedule.SparsitySchedule`.
      service: MaskService the re-solves route through (its SolverConfig
        shapes the masks); a fresh in-memory one per controller by default.
        A :class:`repro.service.net.MaskClient` works here unchanged — the
        trainer keeps stepping while a remote solver box does the refresh
        (``flush_async`` drains over the wire on a background thread).
      lookahead: async mode's snapshot-to-swap distance k — masks landing
        at step ``s`` are solved from step ``s - k`` weights.
      mode: ``"async"`` or ``"sync"`` (see module docstring).
      log: line sink for per-refresh summaries.
    """

    def __init__(
        self,
        schedule: SparsitySchedule,
        service: Optional[MaskService] = None,
        solver: Optional[SolverConfig] = None,
        lookahead: int = 10,
        mode: str = "async",
        log: Callable[[str], None] = lambda s: None,
    ):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if lookahead < 0:
            raise ValueError(f"lookahead must be >= 0, got {lookahead}")
        self.schedule = schedule
        self.service = service if service is not None else \
            MaskService(solver if solver is not None else SolverConfig())
        self.lookahead = lookahead if mode == "async" else 0
        self.mode = mode
        self.log = log
        self.events: list[RefreshEvent] = []
        self._ticket: Optional[_Ticket] = None
        self._next_scan = 1  # swap step 0 is the initial compression
        self._rearm: Optional[dict] = None  # resume: re-submit descriptor

    # -- the per-step hook ---------------------------------------------------

    def on_step(self, step: int, state):
        """Pre-step hook: apply a due swap, then arm a due refresh.

        ``step`` is the step about to run; a swap whose ``swap_step <= step``
        takes effect now, so that step already trains under the new support.
        Returns the (possibly swapped) TrainState.
        """
        state = self._maybe_swap(step, state)
        self._maybe_submit(step, state)
        # Sync mode (and a resumed/late async ticket): the refresh armed for
        # this very step completes before the step runs.
        state = self._maybe_swap(step, state)
        return state

    # -- submit side ---------------------------------------------------------

    def _maybe_submit(self, step: int, state) -> None:
        if self._rearm is not None and self._ticket is None:
            d, self._rearm = self._rearm, None
            self._submit(step, max(d["swap_step"], step),
                         PatternSpec.parse(d["pattern"]), state)
        limit = step + self.lookahead
        s = self._next_scan
        while s <= limit:
            target = self.schedule.swap_at(s)
            if target is not None:
                if self._ticket is not None:
                    break  # one refresh in flight at a time; retry next step
                self._submit(step, s, target, state)
                s += 1
                break
            s += 1
        self._next_scan = s

    def _submit(self, step: int, swap_step: int, pattern: PatternSpec,
                state) -> None:
        params = state.params
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda x: isinstance(x, NMCompressed)
        )
        handles = []
        for path, leaf in flat:
            if not isinstance(leaf, NMCompressed):
                handles.append(None)
                continue
            # Magnitude scores from the live compressed weights: positions
            # outside the current support decompress to 0, so a refresh can
            # tighten or re-arrange the support but never resurrect a slot
            # the trainer has no value for.
            w = leaf.decompress()
            handles.append(self.service.submit(
                f"{path_str(path)}@{swap_step}", w, pattern, journal=False
            ))
        flush = None
        if self.mode == "async":
            flush = self.service.flush_async()
        self._ticket = _Ticket(step, swap_step, pattern, handles, treedef,
                               flush)

    # -- swap side -----------------------------------------------------------

    def _maybe_swap(self, step: int, state):
        tk = self._ticket
        if tk is None or step < tk.swap_step:
            return state
        t0 = time.perf_counter()
        if tk.flush is not None:
            tk.flush.wait()
        else:
            self.service.flush()
        wait = time.perf_counter() - t0
        masks_flat = [None if h is None else h.result() for h in tk.handles]
        masks = jax.tree_util.tree_unflatten(tk.treedef, masks_flat)
        new_params, flips = recompress(state.params, masks, tk.pattern)
        from repro.optim.adamw import remap_moments

        new_opt = remap_moments(state.opt_state, state.params, new_params)
        new_ef = state.ef
        if new_ef is not None:
            new_ef = remap_tree(new_ef, state.params, new_params)
        event = RefreshEvent(
            submit_step=tk.submit_step,
            swap_step=tk.swap_step,
            pattern=tk.pattern.canonical,
            wait_seconds=wait,
            solve_seconds=tk.flush.seconds if tk.flush is not None else wait,
            synchronous=tk.flush is None,
            flips=flips,
        ).finalize()
        self.events.append(event)
        self.log(f"[dst] {event.summary()}")
        self._ticket = None
        return state._replace(params=new_params, opt_state=new_opt,
                              ef=new_ef)

    # -- checkpoint integration ---------------------------------------------

    def state_dict(self) -> dict:
        """Json-serializable refresh state for checkpoint metadata."""
        tk = self._ticket
        return {
            "version": 1,
            "schedule": self.schedule.spec(),
            "mode": self.mode,
            "lookahead": self.lookahead,
            "next_scan": self._next_scan,
            "inflight": None if tk is None else {
                "submit_step": tk.submit_step,
                "swap_step": tk.swap_step,
                "pattern": tk.pattern.canonical,
            },
            "events": [e.to_json() for e in self.events],
        }

    def load_state_dict(self, d: dict) -> None:
        """Resume from :meth:`state_dict` metadata.

        The schedule must match the checkpointed one (a DST run's masks are
        meaningless under a different schedule).  An in-flight refresh is
        re-armed: the next :meth:`on_step` re-snapshots the restored weights
        and re-submits for the same swap step — the service's content cache
        dedupes when the weights are the ones originally snapshotted.
        """
        saved = schedule_from_spec(d["schedule"])
        if saved.spec() != self.schedule.spec():
            raise ValueError(
                "resuming a DST run under a different schedule: checkpoint "
                f"has {saved.spec()}, controller has {self.schedule.spec()}"
            )
        self._next_scan = int(d["next_scan"])
        self._rearm = d.get("inflight")
        self._ticket = None
        self.events = [RefreshEvent.from_json(e) for e in d.get("events", [])]

    # -- telemetry -----------------------------------------------------------

    def stall_seconds(self) -> float:
        """Trainer time spent blocked on async flushes (the number the
        ``benchmarks/dst_loop.py`` gate holds near zero)."""
        return float(sum(
            e.wait_seconds for e in self.events if not e.synchronous
        ))

    def telemetry(self) -> dict:
        """Json-ready rollup (written into ``BENCH_dst.json``)."""
        return {
            "mode": self.mode,
            "lookahead": self.lookahead,
            "refreshes": len(self.events),
            "stall_seconds": self.stall_seconds(),
            "events": [e.to_json() for e in self.events],
            "service": {
                "submitted": self.service.stats.submitted,
                "cache_hits": self.service.stats.cache_hits,
                "dedup_hits": self.service.stats.dedup_hits,
            },
        }


def wrap_step_with_refresh(step_fn: Callable, controller: Any) -> Callable:
    """Wrap a jitted ``step(state, batch)`` so each call first routes the
    pre-step state through ``controller.on_step``.  The controller is
    exposed as ``.refresh`` on the wrapper (``TrainLoop`` discovers it there
    for checkpoint metadata)."""

    def step_with_refresh(state, batch):
        t = int(np.asarray(jax.tree.leaves(state.step)[0]))
        state = controller.on_step(t, state)
        return step_fn(state, batch)

    step_with_refresh.refresh = controller
    return step_with_refresh
