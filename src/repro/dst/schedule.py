"""Sparsity schedules: which transposable N:M pattern governs which step.

A :class:`SparsitySchedule` answers two questions the refresh controller
asks every step:

* ``pattern_at(step)`` — the pattern training *should* be running under at
  ``step`` (drives the initial compression and resume checks);
* ``swap_at(step)`` — the pattern whose freshly-solved mask takes effect at
  ``step``, or ``None`` if no refresh lands there.

Three shapes cover the literature:

* :class:`StaticSchedule` — one pattern forever, re-solved every ``every``
  steps (plain DST: same sparsity, moving support);
* :class:`StepwiseSchedule` — explicit ``(start_step, pattern)`` stages;
  a refresh lands exactly at each stage boundary;
* :func:`decaying_nm` — the Kao et al. decaying-mask recipe ("Training
  Recipe for N:M Structured Sparsity with Decaying Pruning Mask",
  PAPERS.md) as a :class:`StepwiseSchedule` constructor: N decays linearly
  from ``n_start`` to ``n_end`` over evenly spaced boundaries (e.g.
  24:32 → 20:32 → 16:32), relaxing toward the target sparsity instead of
  jumping there one-shot.

Schedules serialize to plain dicts (``spec()`` / :func:`schedule_from_spec`)
so a resumed run can verify it is continuing the schedule it checkpointed.
M is fixed across every stage — decaying N changes the OT marginals of each
block solve (``docs/solver_math.md``), but the block geometry (and therefore
the compressed layout's group size) must not move under a live tree.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.patterns import PatternSpec


class SparsitySchedule:
    """Protocol base; subclasses implement ``pattern_at`` and ``swap_at``."""

    def pattern_at(self, step: int) -> PatternSpec:
        raise NotImplementedError

    def swap_at(self, step: int) -> Optional[PatternSpec]:
        raise NotImplementedError

    def spec(self) -> dict:
        raise NotImplementedError

    @property
    def initial(self) -> PatternSpec:
        """The pattern the run starts under (prune/compress with this)."""
        return self.pattern_at(0)

    @property
    def final(self) -> PatternSpec:
        """The pattern the run converges to (the serve-time artifact)."""
        raise NotImplementedError


def _coerce_transposable(pattern) -> PatternSpec:
    spec = PatternSpec.coerce(pattern)
    if not spec.transposable:
        raise ValueError(
            f"DST schedules need transposable patterns (got {spec}): the "
            "refresh re-solves through MaskService and swaps a compressed "
            "buffer that serves both W and W^T"
        )
    return spec


@dataclasses.dataclass(frozen=True)
class StaticSchedule(SparsitySchedule):
    """One pattern, periodically re-solved: refreshes land every ``every``
    steps starting at ``start`` (default ``every``) until ``stop``."""

    pattern: PatternSpec
    every: int
    start: Optional[int] = None
    stop: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "pattern", _coerce_transposable(self.pattern))
        if self.every < 1:
            raise ValueError(f"StaticSchedule needs every >= 1, got {self.every}")

    def pattern_at(self, step: int) -> PatternSpec:
        return self.pattern

    def swap_at(self, step: int) -> Optional[PatternSpec]:
        first = self.every if self.start is None else self.start
        if step < first or (self.stop is not None and step > self.stop):
            return None
        return self.pattern if (step - first) % self.every == 0 else None

    @property
    def final(self) -> PatternSpec:
        return self.pattern

    def spec(self) -> dict:
        return {"kind": "static", "pattern": self.pattern.canonical,
                "every": self.every, "start": self.start, "stop": self.stop}


@dataclasses.dataclass(frozen=True)
class StepwiseSchedule(SparsitySchedule):
    """Explicit stages: ``[(start_step, pattern), ...]`` with strictly
    increasing start steps, the first at 0 (the initial compression)."""

    stages: tuple  # ((start_step, PatternSpec), ...)

    def __post_init__(self):
        stages = tuple(
            (int(s), _coerce_transposable(p)) for s, p in self.stages
        )
        if not stages:
            raise ValueError("StepwiseSchedule needs at least one stage")
        if stages[0][0] != 0:
            raise ValueError(
                f"first stage must start at step 0 (the initial pattern), "
                f"got {stages[0][0]}"
            )
        starts = [s for s, _ in stages]
        if sorted(set(starts)) != starts:
            raise ValueError(f"stage starts must strictly increase: {starts}")
        ms = {p.m for _, p in stages}
        if len(ms) != 1:
            raise ValueError(
                f"all stages must share one M (the compressed group size is "
                f"static under a live tree), got M in {sorted(ms)}"
            )
        object.__setattr__(self, "stages", stages)

    def pattern_at(self, step: int) -> PatternSpec:
        current = self.stages[0][1]
        for start, pat in self.stages:
            if step >= start:
                current = pat
        return current

    def swap_at(self, step: int) -> Optional[PatternSpec]:
        for start, pat in self.stages[1:]:  # stage 0 is the initial prune
            if start == step:
                return pat
        return None

    @property
    def final(self) -> PatternSpec:
        return self.stages[-1][1]

    def spec(self) -> dict:
        return {"kind": "stepwise",
                "stages": [[s, p.canonical] for s, p in self.stages]}


def decaying_nm(m: int, n_start: int, n_end: int, total_steps: int,
                stages: Optional[int] = None) -> StepwiseSchedule:
    """Kao-style decaying N:M schedule as a :class:`StepwiseSchedule`.

    N steps down linearly from ``n_start`` to ``n_end`` across ``stages``
    patterns (default: one stage per distinct N on the line, e.g.
    ``decaying_nm(32, 24, 16, 300)`` → 24:32 at step 0, 20:32 at 100,
    16:32 at 200) with evenly spaced boundaries over ``total_steps``; the
    final stage gets the same slice of the budget as every other, so the
    target pattern trains for the last ``total_steps / stages`` steps.
    """
    if n_end > n_start:
        raise ValueError(
            f"decaying_nm decays: n_start ({n_start}) must be >= n_end "
            f"({n_end})"
        )
    if stages is None:
        stages = min(n_start - n_end + 1, 3) if n_start > n_end else 1
    if stages < 1:
        raise ValueError(f"decaying_nm needs stages >= 1, got {stages}")
    if stages > 1 and total_steps < stages:
        raise ValueError(
            f"total_steps ({total_steps}) too small for {stages} stages"
        )
    ns: Sequence[int]
    if stages == 1:
        ns = [n_end]
    else:
        span = n_start - n_end
        ns = [round(n_start - span * i / (stages - 1)) for i in range(stages)]
    out = []
    for i, n in enumerate(ns):
        start = (total_steps * i) // stages
        out.append((start, PatternSpec(int(n), m, True)))
    # Collapse duplicate consecutive Ns from rounding (no-op boundaries).
    dedup = [out[0]]
    for start, pat in out[1:]:
        if pat != dedup[-1][1]:
            dedup.append((start, pat))
    return StepwiseSchedule(tuple(dedup))


def schedule_from_spec(d: dict) -> SparsitySchedule:
    """Inverse of ``SparsitySchedule.spec()`` (checkpoint resume path)."""
    kind = d.get("kind")
    if kind == "static":
        return StaticSchedule(PatternSpec.parse(d["pattern"]), d["every"],
                              d.get("start"), d.get("stop"))
    if kind == "stepwise":
        return StepwiseSchedule(
            tuple((s, PatternSpec.parse(p)) for s, p in d["stages"])
        )
    raise ValueError(f"unknown schedule spec kind: {kind!r}")
