"""Churn telemetry for dynamic sparse training.

A mask refresh is only worth its solve cost if it actually *moves* support
— and only safe if it doesn't move too much of it (Kao et al.: late-stage
churn destroys recovered accuracy).  This module measures that movement:

* :func:`mask_flip_stats` — one old/new mask pair's churn (kept / added /
  dropped positions, flip rate over the dense positions);
* :class:`RefreshEvent` — everything one refresh did: when it snapshotted,
  when it swapped, what pattern it solved, how long the trainer waited on
  the async flush (the "stall" the bench gates on), and the per-layer flip
  stats from :func:`repro.sparsity.params.recompress`;
* :func:`aggregate_flips` — tree-level rollup the loop logs per refresh.

Everything here is plain numpy/python: records are json-serializable so
they ride checkpoints (``BENCH_dst.json``, the ckpt ``dst`` metadata) as-is.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def mask_flip_stats(old_mask, new_mask) -> dict:
    """Churn between two boolean masks of the same dense shape.

    Returns ``{"kept", "added", "dropped", "nnz_old", "nnz_new", "size",
    "flip_rate"}`` — ``flip_rate`` is the fraction of dense positions whose
    membership changed (the symmetric difference over the full size), the
    number Kao-style decaying schedules watch per refresh.
    """
    old = np.asarray(old_mask, bool)
    new = np.asarray(new_mask, bool)
    assert old.shape == new.shape, (old.shape, new.shape)
    kept = int(np.sum(old & new))
    added = int(np.sum(~old & new))
    dropped = int(np.sum(old & ~new))
    return {
        "kept": kept,
        "added": added,
        "dropped": dropped,
        "nnz_old": int(np.sum(old)),
        "nnz_new": int(np.sum(new)),
        "size": int(old.size),
        "flip_rate": (added + dropped) / max(int(old.size), 1),
    }


def aggregate_flips(per_layer: dict) -> dict:
    """Roll per-layer :func:`mask_flip_stats` dicts up to one tree-level
    record (counts sum; ``flip_rate`` is recomputed over the total size)."""
    total = {"kept": 0, "added": 0, "dropped": 0, "nnz_old": 0,
             "nnz_new": 0, "size": 0}
    for st in per_layer.values():
        for k in total:
            total[k] += st[k]
    total["flip_rate"] = (
        (total["added"] + total["dropped"]) / max(total["size"], 1)
    )
    return total


@dataclasses.dataclass
class RefreshEvent:
    """One completed mask refresh, as recorded by the controller."""

    submit_step: int            # step whose weights were snapshotted
    swap_step: int              # first step trained under the new support
    pattern: str                # canonical PatternSpec string solved
    wait_seconds: float = 0.0   # trainer time spent blocked on the flush
    solve_seconds: float = 0.0  # background wall-clock of the flush itself
    synchronous: bool = False   # sync mode: solved inline at swap_step
    failed: bool = False        # refresh abandoned: trained on under old mask
    error: Optional[str] = None  # root cause when failed
    flips: dict = dataclasses.field(default_factory=dict)  # path -> stats
    total: Optional[dict] = None  # aggregate_flips(flips)

    def finalize(self) -> "RefreshEvent":
        self.total = aggregate_flips(self.flips)
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "RefreshEvent":
        return cls(**d)

    def summary(self) -> str:
        if self.failed:
            return (
                f"refresh@{self.swap_step} {self.pattern} FAILED "
                f"(snapshot@{self.submit_step}): {self.error} "
                f"— kept the old mask"
            )
        tot = self.total or aggregate_flips(self.flips)
        return (
            f"refresh@{self.swap_step} {self.pattern} "
            f"(snapshot@{self.submit_step}, "
            f"{'sync' if self.synchronous else 'async'}) "
            f"flip_rate={tot['flip_rate']:.4f} "
            f"nnz {tot['nnz_old']} -> {tot['nnz_new']} "
            f"wait={self.wait_seconds * 1e3:.1f}ms"
        )
