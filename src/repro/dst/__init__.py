"""Dynamic sparse training: transposable N:M masks that evolve under the
trainer without stalling it.

Every other flow in the repo is prune-once-then-train; this package
re-solves masks *during* training — on a :class:`SparsitySchedule`
(static cadence, stepwise stages, or the Kao-style decaying N:M of
:func:`decaying_nm`) — and swaps the support of a live compressed
TrainState via :func:`repro.sparsity.params.recompress` +
:func:`repro.optim.adamw.remap_moments`.  The solve itself rides
``MaskService.flush_async`` on a background thread, so the step loop never
blocks on a mask solve (``mode="async"``); ``mode="sync"`` is the
bit-identical-to-manual oracle.

See ``docs/architecture.md`` ("Dynamic sparse training") for the refresh
lifecycle and decision tables, and ``benchmarks/dst_loop.py`` for the
overhead/stall/quality gates.
"""
from repro.dst.controller import MaskRefreshController, wrap_step_with_refresh
from repro.dst.schedule import (
    SparsitySchedule,
    StaticSchedule,
    StepwiseSchedule,
    decaying_nm,
    schedule_from_spec,
)
from repro.dst.telemetry import RefreshEvent, aggregate_flips, mask_flip_stats

__all__ = [
    "MaskRefreshController",
    "RefreshEvent",
    "SparsitySchedule",
    "StaticSchedule",
    "StepwiseSchedule",
    "aggregate_flips",
    "decaying_nm",
    "mask_flip_stats",
    "schedule_from_spec",
    "wrap_step_with_refresh",
]
