"""Launchers: production mesh, multi-pod dry-run, train/prune drivers."""
