"""Stand the mask service up as a network server.

    PYTHONPATH=src python -m repro.launch.serve_masks \
        --port 7463 --dir /var/cache/tsenor --iters 150 \
        --tenant team-a:quota=3 --tenant team-b:quota=1,rate=2e5

One process, one inner :class:`MaskService`: every tenant's submissions
drain through the same shape-bucketed mega-batch scheduler and share the
same content-addressed cache tier (``--dir`` makes it durable; point two
servers at one volume and they share entries through the filesystem —
``ContentStore`` writes are multi-process safe).  Deployment recipes
(systemd unit, k8s manifest, cache-volume sharing): ``docs/deploy.md``.

Tenant grammar: ``NAME[:k=v,...]`` with keys ``quota`` (relative share of
each scheduling round), ``rate`` (blocks/sec token-bucket limit) and
``burst`` (bucket depth in blocks).  Unlisted tenants are admitted with
``--default-quota`` unless ``--strict-tenants`` is set.
"""
from __future__ import annotations

import argparse
from typing import Optional

from repro.core.solver import SolverConfig
from repro.service import MaskService, MaskServer, TenantConfig


def parse_tenant(text: str) -> tuple[str, TenantConfig]:
    """``"team-a:quota=3,rate=2e5"`` -> ``("team-a", TenantConfig(...))``."""
    name, _, opts = text.partition(":")
    if not name:
        raise ValueError(f"tenant spec {text!r} has an empty name")
    kwargs: dict[str, Optional[float]] = {}
    for part in filter(None, opts.split(",")):
        k, eq, v = part.partition("=")
        if not eq or k not in ("quota", "rate", "burst"):
            raise ValueError(
                f"bad tenant option {part!r} in {text!r} "
                "(want quota=/rate=/burst=)"
            )
        kwargs[k] = float(v)
    return name, TenantConfig(**kwargs)


def build_server(argv: Optional[list[str]] = None) -> MaskServer:
    ap = argparse.ArgumentParser(
        description="TSENOR mask-solving server (see docs/deploy.md)"
    )
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address (0.0.0.0 to serve off-box)")
    ap.add_argument("--port", type=int, default=7463,
                    help="TCP port; 0 picks an ephemeral one")
    ap.add_argument("--dir", default=None,
                    help="persistent root: content store + journal live "
                         "here; omit for an in-memory cache")
    ap.add_argument("--iters", type=int, default=150,
                    help="Dykstra iterations (the solve-quality knob)")
    ap.add_argument("--backend", default=None,
                    help="solver backend override (see repro.core.backends)")
    ap.add_argument("--cache-max-bytes", type=int, default=None,
                    help="LRU-bound the disk cache to this many bytes")
    ap.add_argument("--cache-min-blocks", type=int, default=None,
                    help="disk-admission floor in blocks (default: derived "
                         "from observed solve vs read rates; 0 admits all)")
    ap.add_argument("--tenant", action="append", default=[],
                    metavar="NAME[:quota=Q,rate=R,burst=B]",
                    help="pre-register a tenant (repeatable)")
    ap.add_argument("--default-quota", type=float, default=1.0)
    ap.add_argument("--default-rate", type=float, default=None,
                    help="blocks/sec limit for auto-registered tenants")
    ap.add_argument("--strict-tenants", action="store_true",
                    help="reject hellos from unregistered tenants")
    ap.add_argument("--round-blocks", type=int, default=4096,
                    help="block budget one scheduling round splits "
                         "quota-weighted across backlogged tenants")
    ap.add_argument("--batch-window-ms", type=float, default=2.0,
                    help="linger before draining so concurrent submitters "
                         "share one mega-batch")
    ap.add_argument("--no-remote-shutdown", action="store_true",
                    help="ignore the shutdown op (production setting)")
    ap.add_argument("--max-queue-blocks", type=int, default=None,
                    help="load-shed ceiling: reject submits once the queued "
                         "backlog exceeds this many blocks (clients back "
                         "off per the reply's retry_after hint)")
    ap.add_argument("--request-deadline", type=float, default=None,
                    metavar="SECONDS",
                    help="fail queued requests older than this instead of "
                         "solving them late (clients re-submit)")
    ap.add_argument("--drain-grace", type=float, default=30.0,
                    metavar="SECONDS",
                    help="SIGTERM/SIGINT drain budget: finish in-flight "
                         "work for up to this long before exiting")
    args = ap.parse_args(argv)

    solver_kwargs = {"iters": args.iters}
    if args.backend is not None:
        solver_kwargs["backend"] = args.backend
    service = MaskService(
        SolverConfig(**solver_kwargs),
        directory=args.dir,
        cache_max_bytes=args.cache_max_bytes,
        cache_min_blocks=args.cache_min_blocks,
    )
    return MaskServer(
        service,
        host=args.host,
        port=args.port,
        tenants=dict(parse_tenant(t) for t in args.tenant),
        default_quota=args.default_quota,
        default_rate=args.default_rate,
        strict_tenants=args.strict_tenants,
        round_blocks=args.round_blocks,
        batch_window_s=args.batch_window_ms / 1e3,
        allow_remote_shutdown=not args.no_remote_shutdown,
        max_queue_blocks=args.max_queue_blocks,
        request_deadline_s=args.request_deadline,
        drain_grace_s=args.drain_grace,
    )


def main(argv: Optional[list[str]] = None) -> None:
    server = build_server(argv)
    server.start()
    # SIGTERM/SIGINT drain gracefully: stop accepting, finish in-flight
    # solves (bounded by --drain-grace), sync the journal, exit 0 — the
    # contract a rolling restart relies on (docs/deploy.md).
    server.install_signal_handlers()
    print(f"[serve-masks] listening on {server.address} "
          f"(config: {server.service.config})", flush=True)
    server.serve_forever()
    print("[serve-masks] drained, exiting", flush=True)


if __name__ == "__main__":
    main()
