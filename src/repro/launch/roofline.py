"""Aggregate dry-run reports into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir: str, tag_filter: str | None = None):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        name = os.path.basename(path)[: -len(".json")]
        parts = name.split("__")
        r["_mesh_tag"] = parts[2] if len(parts) > 2 else "pod1"
        r["_extra_tag"] = parts[3] if len(parts) > 3 else ""
        if tag_filter is not None and r["_extra_tag"] != tag_filter:
            continue
        rows.append(r)
    return rows


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['_mesh_tag']} | skipped | "
                f"— | — | — | — | — | {r['reason'][:40]} |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['_mesh_tag']} | ERROR | "
                f"— | — | — | — | — | {r.get('error', '')[:40]} |")
    dom = {"compute_s": "compute", "memory_s": "memory",
           "collective_s": "collective"}[r["bottleneck"]]
    ratio = r.get("useful_flops_ratio", 0.0)
    total = r["compute_s"] + r["memory_s"] + r["collective_s"]
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    ideal = r["model_flops"] / r["chips"] / 197e12
    # roofline fraction: ideal model-FLOPs time / dominant-term time.
    frac = ideal / bound if bound else 0.0
    return (f"| {r['arch']} | {r['shape']} | {r['_mesh_tag']} | ok "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {dom} | {frac:.3f} | "
            f"useful={ratio:.2f} temp={r.get('temp_size_in_bytes', 0) / 2**30:.1f}GiB |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.out, args.tag if args.tag else None)
    rows = [r for r in rows if not r["_extra_tag"] or r["_extra_tag"] == args.tag]
    print("| arch | shape | mesh | status | compute_s | memory_s | "
          "collective_s | bottleneck | roofline_frac | notes |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
