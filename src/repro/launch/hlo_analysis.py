"""While-aware HLO cost & collective analysis for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — with
scan-over-layers models that under-counts FLOPs by the layer count (verified:
a 7-step scan reports exactly 1/7 of the analytic FLOPs).  This module parses
``compiled.as_text()`` (post-SPMD-partitioning, scheduled HLO) into a
computation call graph, extracts scan trip counts from while-condition
constants, and accumulates per-instruction costs scaled by the dynamic
execution multiplier.

Post-scheduled HLO references operands by name only, so a per-computation
symbol table (instruction -> result shape text) resolves operand sizes.

Per instruction:
  * ``dot``: FLOPs = 2 * |output| * prod(lhs contracting dims)   (exact)
  * elementwise/transcendental/reduce: max(|out|, |in|) FLOPs    (estimate)
  * HBM bytes: operands + result of *top-level* instructions — computations
    reached via ``calls=``/``to_apply=`` are fusion internals whose traffic
    is the fusion boundary; while bodies ARE top-level.
  * collective bytes: operand sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, x multiplier.

All numbers are per full module execution — global across the mesh; divide
by chip count for per-chip roofline terms.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "floor",
    "select", "compare", "and", "or", "xor", "convert", "clamp", "sign",
    "cosine", "sine", "logistic", "exponential-minus-one", "log-plus-one",
    "remainder", "atan2", "reduce", "reduce-window", "map",
}
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _shapes_bytes(text: str) -> float:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            total += _DTYPE_BYTES[dt] * _shape_elems(dims)
    return float(total)


def _shapes_elems_total(text: str) -> int:
    return sum(_shape_elems(d) for t, d in _SHAPE_RE.findall(text) if t in _DTYPE_BYTES)


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_text: str
    args_text: str
    attrs_text: str


def _split_args(rest: str) -> tuple[str, str]:
    depth = 1
    for idx, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:idx], rest[idx + 1 :]
    return rest, ""


def parse_module(hlo: str):
    """-> (computations {name: [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur, cur_lines = None, []
    for line in hlo.splitlines():
        hm = _HEADER_RE.match(line)
        if hm and " = " not in line.split("{")[0]:
            cur = hm.group(2)
            cur_lines = []
            comps[cur] = cur_lines
            if hm.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, out_text, opcode, rest = im.groups()
            args, attrs = _split_args(rest)
            cur_lines.append(Instr(name, opcode, out_text, args, attrs))
    return comps, entry


def analyze_hlo(hlo: str) -> dict:
    comps, entry = parse_module(hlo)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # Symbol tables: per computation, instruction name -> result shape text.
    symtab = {
        cname: {i.name: i.out_text for i in instrs}
        for cname, instrs in comps.items()
    }

    # Fusion params that are only consumed via (dynamic-)slice/gather inside
    # the fused computation read just the window, not the whole buffer; map
    # computation -> {param_index: effective_bytes}.
    slice_param_bytes: dict[str, dict[int, float]] = {}
    for cname, instrs in comps.items():
        pidx = {}
        uses = defaultdict(list)   # param name -> list of (opcode, out_bytes)
        order = {}
        for i in instrs:
            if i.opcode == "parameter":
                m = re.match(r"\s*(\d+)\s*$", i.args_text)
                if m:
                    order[i.name] = int(m.group(1))
            else:
                for o in _OPERAND_RE.findall(i.args_text):
                    uses[o].append((i.opcode, _shapes_bytes(i.out_text)))
        for pname, idx in order.items():
            if uses[pname] and all(
                u[0] in ("dynamic-slice", "slice", "gather") for u in uses[pname]
            ):
                pidx[idx] = sum(u[1] for u in uses[pname])
        if pidx:
            slice_param_bytes[cname] = pidx
    # Computations reached via fusion/reduce lambdas: not top-level for bytes.
    fused_called = set()
    for instrs in comps.values():
        for i in instrs:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", i.attrs_text):
                fused_called.add(m.group(1))
            m = re.search(r"branch_computations=\{([^}]*)\}", i.attrs_text)
            if m:
                pass  # branches are top-level-ish; leave them out of fused set

    totals = defaultdict(float)
    coll = defaultdict(float)

    def operand_bytes(cname: str, args_text: str) -> float:
        tab = symtab.get(cname, {})
        total = 0.0
        for name in _OPERAND_RE.findall(args_text):
            total += _shapes_bytes(tab.get(name, ""))
        return total

    def operand_elems(cname: str, args_text: str) -> int:
        tab = symtab.get(cname, {})
        return sum(
            _shapes_elems_total(tab.get(n, "")) for n in _OPERAND_RE.findall(args_text)
        )

    def trip_count(cond_name: str) -> int:
        best = 1
        for i in comps.get(cond_name, []):
            if i.opcode == "constant":
                m = re.match(r"\s*(\d+)\s*$", i.args_text)
                if m:
                    best = max(best, int(m.group(1)))
            for m in re.finditer(r"constant\((\d+)\)", i.args_text):
                best = max(best, int(m.group(1)))
        return best

    active: set[str] = set()

    def walk(cname: str, mult: float):
        if cname in active or cname not in comps:
            return
        active.add(cname)
        top_level = cname not in fused_called
        for ins in comps[cname]:
            op = ins.opcode
            if op in _FREE_OPS:
                continue
            out_elems = _shapes_elems_total(ins.out_text)
            if op == "dot":
                contr = 1
                mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs_text)
                lhs_name = (_OPERAND_RE.findall(ins.args_text) or [None])[0]
                lhs_shape = symtab.get(cname, {}).get(lhs_name, "")
                sm = _SHAPE_RE.search(lhs_shape)
                if mm and sm:
                    lhs_dims = sm.group(2).split(",") if sm.group(2) else []
                    for d in mm.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            contr *= int(lhs_dims[int(d)])
                flops = mult * 2 * out_elems * contr
                totals["dot_flops"] += flops
                totals["flops"] += flops
            elif op in _ELEMENTWISE:
                totals["flops"] += mult * max(
                    out_elems, operand_elems(cname, ins.args_text)
                )
            cbase = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if cbase:
                nbytes = mult * operand_bytes(cname, ins.args_text)
                coll[cbase] += nbytes
                totals["collective_bytes"] += nbytes
            if top_level:
                out_bytes = _shapes_bytes(ins.out_text)
                if op in ("while", "conditional", "call", "copy-start", "copy-done"):
                    # Loop/branch carries are aliased; bodies account for
                    # their real reads/writes.
                    io = 0.0
                elif op in ("dynamic-slice", "slice", "gather"):
                    # Reads only the extracted window, not the whole operand.
                    io = 2.0 * out_bytes
                elif op == "dynamic-update-slice":
                    # Reads the update + writes the same-sized region; the
                    # big operand is aliased in place.
                    ops_ = _OPERAND_RE.findall(ins.args_text)
                    upd = ops_[1] if len(ops_) > 1 else None
                    ub = _shapes_bytes(symtab.get(cname, {}).get(upd, ""))
                    io = 2.0 * ub
                elif op == "scatter":
                    ops_ = _OPERAND_RE.findall(ins.args_text)
                    sizes = [
                        _shapes_bytes(symtab.get(cname, {}).get(o, "")) for o in ops_
                    ]
                    io = 2.0 * (min(sizes) if sizes else out_bytes)
                elif op == "broadcast":
                    io = out_bytes + operand_bytes(cname, ins.args_text)
                elif op == "fusion":
                    called = re.search(r"calls=%?([\w\.\-]+)", ins.attrs_text)
                    windows = slice_param_bytes.get(
                        called.group(1) if called else "", {}
                    )
                    io = out_bytes
                    for k2, o in enumerate(_OPERAND_RE.findall(ins.args_text)):
                        full = _shapes_bytes(symtab.get(cname, {}).get(o, ""))
                        io += min(windows.get(k2, full), full)
                else:
                    io = operand_bytes(cname, ins.args_text) + out_bytes
                totals["hbm_bytes"] += mult * io
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs_text)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs_text)
                trips = trip_count(cm.group(1)) if cm else 1
                totals.setdefault("max_trip", 0.0)
                totals["max_trip"] = max(totals["max_trip"], trips)
                if bm:
                    walk(bm.group(1), mult * trips)
            elif op == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs_text)
                if m:
                    for b in m.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult)
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.attrs_text):
                    walk(m.group(1), mult)
        active.discard(cname)

    walk(entry, 1.0)
    out = dict(totals)
    out["collectives"] = dict(coll)
    return out


def analyze_compiled(compiled) -> dict:
    return analyze_hlo(compiled.as_text())
