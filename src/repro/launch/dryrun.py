"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
partitions and compiles, and extract its roofline terms.

MUST set the placeholder device count before ANY other import (jax locks the
device count on first init):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import (  # noqa: E402
    ARCH_IDS,
    cell_supported,
    get_config,
)
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.distributed.sharding import set_mesh  # noqa: E402
from repro.launch.hlo_analysis import analyze_compiled  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm, specs  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim.adamw import AdamW  # noqa: E402
from repro.sparsity.masks import default_prunable  # noqa: E402
from repro.train.step import StepConfig, build_train_step, make_train_state  # noqa: E402

# TPU v5e constants (per assignment).
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def serving_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, param_dtype="bfloat16", remat="none")


def train_opt(cfg: ModelConfig) -> AdamW:
    big = cfg.param_count() > 2e10
    return AdamW(
        learning_rate=1e-4, moment_dtype="bfloat16" if big else None
    )


def abstract_masks(params_shape, m: int = 32):
    """Bool mask SDS tree for prunable weights (None elsewhere)."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)
    leaves = []
    for path, p in flat[0]:
        if default_prunable(path, p, m):
            leaves.append(jax.ShapeDtypeStruct(p.shape, jnp.bool_))
        else:
            leaves.append(None)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def input_specs(
    cfg: ModelConfig, shape, mesh, *, sparse: bool, accum: int,
    mask_mode: str = "fwd", pure_dp: bool = False,
):
    """Abstract, sharded inputs + the function to lower for one cell."""
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt = train_opt(cfg)
        state_shape = jax.eval_shape(
            lambda: make_train_state(cfg, opt, jax.random.PRNGKey(0))
        )
        pspecs = specs.fit_param_specs(cfg, state_shape.params, mesh, pure_dp)
        state_specs = type(state_shape)(
            params=pspecs,
            opt_state=type(state_shape.opt_state)(
                step=jax.sharding.PartitionSpec(),
                mu=pspecs,
                nu=pspecs,
            ),
            step=jax.sharding.PartitionSpec(),
            ef=None,
        )
        state_sds = specs.as_sds(
            state_shape, specs.shardings_of(state_specs, mesh)
        )
        bs = specs.batch_spec(mesh, b, 2, pure_dp)
        bsh = jax.sharding.NamedSharding(mesh, bs)
        if cfg.frontend != "none":
            es = specs.batch_spec(mesh, b, 3, pure_dp)
            batch_sds = {
                "embeds": jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), jnp.bfloat16,
                    sharding=jax.sharding.NamedSharding(mesh, es),
                ),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsh),
            }
        else:
            batch_sds = {
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsh),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsh),
            }
        step_fn = build_train_step(
            cfg, opt, step_cfg=StepConfig(accum=accum, mask_mode=mask_mode),
            masks_as_input=sparse, donate=True,
        )
        if sparse:
            masks_shape = abstract_masks(state_shape.params)
            mask_specs = jax.tree.map(
                lambda m, sp: sp if m is not None else None,
                masks_shape,
                pspecs,
                is_leaf=lambda x: x is None,
            )
            masks_sds = jax.tree.map(
                lambda m, sp: jax.ShapeDtypeStruct(
                    m.shape, m.dtype,
                    sharding=jax.sharding.NamedSharding(mesh, sp),
                )
                if m is not None
                else None,
                masks_shape,
                mask_specs,
                is_leaf=lambda x: x is None,
            )
            return step_fn, (state_sds, batch_sds, masks_sds)
        return step_fn, (state_sds, batch_sds)

    # Serving cells: bf16 params, decode or prefill.
    scfg = serving_cfg(cfg)
    params_shape = jax.eval_shape(lambda: lm.init_params(scfg, jax.random.PRNGKey(0)))
    psh = specs.shardings_of(specs.fit_param_specs(scfg, params_shape, mesh), mesh)
    params_sds = specs.as_sds(params_shape, psh)
    caches_shape = jax.eval_shape(lambda: lm.init_cache(scfg, b, s))
    csh = specs.shardings_of(specs.cache_specs(scfg, caches_shape, mesh), mesh)
    caches_sds = specs.as_sds(caches_shape, csh)

    if shape.kind == "decode":
        tok_sds = jax.ShapeDtypeStruct(
            (b,), jnp.int32,
            sharding=jax.sharding.NamedSharding(mesh, specs.batch_spec(mesh, b, 1)),
        )
        idx_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, token, caches, index):
            return lm.decode_step(params, scfg, token, caches, index)

        fn = jax.jit(serve_step, donate_argnums=(2,))
        return fn, (params_sds, tok_sds, caches_sds, idx_sds)

    # prefill
    if cfg.frontend != "none":
        es = specs.batch_spec(mesh, b, 3)
        inp_sds = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.bfloat16,
            sharding=jax.sharding.NamedSharding(mesh, es),
        )

        def prefill_fn(params, caches, embeds):
            return lm.prefill(params, scfg, caches, embeds=embeds)

    else:
        inp_sds = jax.ShapeDtypeStruct(
            (b, s), jnp.int32,
            sharding=jax.sharding.NamedSharding(mesh, specs.batch_spec(mesh, b, 2)),
        )

        def prefill_fn(params, caches, tokens):
            return lm.prefill(params, scfg, caches, tokens=tokens)

    fn = jax.jit(prefill_fn, donate_argnums=(1,))
    return fn, (params_sds, caches_sds, inp_sds)


def roofline_terms(analysis: dict, chips: int) -> dict:
    """Per the assignment: terms in seconds from the per-device HLO numbers.

    The compiled module is the per-device program, so per-chip work =
    module totals; global = x chips.
    """
    per_chip_flops = analysis.get("flops", 0.0)
    per_chip_dot_flops = analysis.get("dot_flops", 0.0)
    per_chip_bytes = analysis.get("hbm_bytes", 0.0)
    per_chip_coll = analysis.get("collective_bytes", 0.0)
    return {
        "compute_s": per_chip_flops / PEAK_FLOPS,
        "compute_dot_s": per_chip_dot_flops / PEAK_FLOPS,
        "memory_s": per_chip_bytes / HBM_BW,
        "collective_s": per_chip_coll / ICI_BW,
        "hlo_flops_global": per_chip_flops * chips,
        "hlo_dot_flops_global": per_chip_dot_flops * chips,
        "hlo_bytes_global": per_chip_bytes * chips,
        "collective_bytes_global": per_chip_coll * chips,
    }


def model_flops(cfg: ModelConfig, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token/seq."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, sparse: bool, accum: int,
    out_dir: str, overrides: dict | None = None, mask_mode: str = "fwd",
    tag: str = "",
) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    pure_dp = bool(overrides.pop("pure_dp", 0))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    if pure_dp:
        from repro.distributed.sharding import MeshRules, default_rules

        rules = dict(default_rules(mesh).rules)
        rules["act_batch"] = tuple(
            a for a in ("pod", "data", "model") if a in mesh.axis_names
        )
        for k in ("act_heads", "act_vocab", "act_exp", "act_attn_seq"):
            rules[k] = None
        set_mesh(mesh, MeshRules(rules))
    else:
        set_mesh(mesh)
    report = {
        "arch": arch, "shape": shape_name, "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names), "chips": chips, "sparse": sparse,
        "accum": accum, "kind": shape.kind, "overrides": overrides or {},
        "mask_mode": mask_mode, "tag": tag, "pure_dp": pure_dp,
    }
    t0 = time.time()
    try:
        ok, why = cell_supported(arch, shape_name)
        if not ok:
            report.update(status="skipped", reason=why)
            return report
        fn, args = input_specs(
            cfg, shape, mesh, sparse=sparse, accum=accum, mask_mode=mask_mode,
            pure_dp=pure_dp,
        )
        lowered = fn.lower(*args)
        report["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        report["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for field in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                v = getattr(mem, field, None)
                if v is not None:
                    report[field] = int(v)
        ca = compiled.cost_analysis() or {}
        report["xla_cost_flops"] = float(ca.get("flops", 0.0))
        report["xla_cost_bytes"] = float(ca.get("bytes accessed", 0.0))

        analysis = analyze_compiled(compiled)
        report["hlo"] = {
            k: v for k, v in analysis.items() if k != "collectives"
        }
        report["collectives"] = analysis.get("collectives", {})
        report.update(roofline_terms(analysis, chips))
        mf = model_flops(cfg, shape)
        report["model_flops"] = mf
        if report["hlo_dot_flops_global"]:
            report["useful_flops_ratio"] = mf / report["hlo_dot_flops_global"]
        dom = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: report[k]
        )
        report["bottleneck"] = dom
        total = report["compute_s"] + report["memory_s"] + report["collective_s"]
        report["roofline_fraction"] = report[dom] / total if total else 0.0
        report["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record, don't die mid-sweep
        report["status"] = "error"
        report["error"] = f"{type(e).__name__}: {e}"
        report["traceback"] = traceback.format_exc()[-4000:]
    finally:
        report["total_s"] = round(time.time() - t0, 1)
        set_mesh(None)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        mtag = "pod2" if multi_pod else "pod1"
        if tag:
            mtag = f"{mtag}__{tag}"
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mtag}.json")
        with open(path, "w") as f:
            json.dump(report, f, indent=1, default=str)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + ["all"])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dense", action="store_true", help="disable sparse masks")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--mask-mode", default="fwd", choices=["fwd", "post"])
    ap.add_argument("--tag", default="", help="suffix for report files")
    ap.add_argument(
        "--override", action="append", default=[],
        help="model config overrides, e.g. --override ssm_chunk=64",
    )
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = int(v)
        except ValueError:
            try:
                overrides[k] = float(v)
            except ValueError:
                overrides[k] = v

    archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for sh in shapes:
                mtag = ("pod2" if mp else "pod1") + (
                    f"__{args.tag}" if args.tag else ""
                )
                path = os.path.join(args.out, f"{arch}__{sh}__{mtag}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip existing {arch} x {sh} ({mtag})")
                    continue
                print(f"[dryrun] {arch} x {sh} ({mtag}) ...", flush=True)
                r = run_cell(
                    arch, sh, mp, not args.dense, args.accum, args.out,
                    overrides=overrides, mask_mode=args.mask_mode,
                    tag=args.tag,
                )
                status = r["status"]
                extra = ""
                if status == "ok":
                    extra = (
                        f" compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s"
                        f" coll={r['collective_s']:.4f}s -> {r['bottleneck']}"
                    )
                elif status == "error":
                    extra = " " + r["error"][:160]
                print(f"[dryrun]   {status}{extra} ({r['total_s']}s)", flush=True)
                results.append(r)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"[dryrun] done: {n_ok} ok / {n_skip} skipped / {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
