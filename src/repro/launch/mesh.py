"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    data axis.  Axis types Auto: GSPMD partitioning everywhere except where
    shard_map takes the pod axis manual (gradient compression)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, axis_types=compat.auto_axis_types(len(axes)))


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh (tests / elastic restarts with fewer devices)."""
    return compat.make_mesh(shape, axes, axis_types=compat.auto_axis_types(len(axes)))
