"""One-shot pruning launcher (layer-wise, sequential propagation).

    PYTHONPATH=src python -m repro.launch.prune --arch granite_8b --smoke \
        --method alps --nm 8:16 --out /tmp/pruned
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core.solver import SolverConfig
from repro.data import SyntheticLM
from repro.models import lm
from repro.patterns import PatternSpec
from repro.pruning import prune_transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--method", default="alps",
                    choices=["alps", "sparsegpt", "wanda", "magnitude"])
    ap.add_argument("--nm", default="2:4")
    ap.add_argument("--standard", action="store_true")
    ap.add_argument("--calib-tokens", type=int, default=8192)
    ap.add_argument("--restore", default=None, help="checkpoint dir to prune")
    ap.add_argument("--out", default=None, help="save pruned params here")
    ap.add_argument("--emit", default="dense", choices=["dense", "compressed"],
                    help="compressed: return/save SparseParams (NMCompressed "
                         "buffers) ready for sparse fine-tune + serving")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    assert cfg.family in ("dense", "vlm", "audio"), \
        "layer-wise runner covers attention+MLP families"
    base = PatternSpec.parse(args.nm)
    spec = PatternSpec(base.n, base.m, not args.standard)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.restore:
        mgr = CheckpointManager(args.restore)
        step = mgr.latest_step()
        state_like = {"params": params}
        params = mgr.restore(step, state_like)["params"]
        print(f"[prune] restored step {step} from {args.restore}")

    seq = 64
    batch = max(1, args.calib_tokens // seq)
    data = SyntheticLM(cfg.vocab_size, seq, batch)
    calib = jnp.asarray(data.batch(0)["tokens"])

    print(f"[prune] {args.method} -> "
          f"{'standard' if args.standard else 'transposable'} {spec.n}:{spec.m}")
    pruned, masks = prune_transformer(
        params, cfg, tokens=calib, method=args.method, pattern=spec,
        solver=SolverConfig(iters=150), log=print, emit=args.emit,
    )
    nz = float(np.mean([float(jnp.mean(mk)) for mk in jax.tree.leaves(masks)]))
    print(f"[prune] kept fraction {nz:.3f} (target {spec.density:.3f})")
    if args.emit == "compressed":
        from repro.sparsity.params import sparse_param_bytes

        acc = sparse_param_bytes(pruned)
        print(f"[prune] compressed projections: {acc['compressed'] / 1e6:.2f} MB "
              f"({acc['ratio']:.3f}x of their {acc['dense'] / 1e6:.2f} MB dense)")
    if args.out:
        mgr = CheckpointManager(args.out, async_save=False)
        mgr.save(0, {"params": pruned, "masks": masks})
        print(f"[prune] saved to {args.out}")


if __name__ == "__main__":
    main()
