"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
        --steps 100 --nm 8:16 --ckpt-dir /tmp/run1

On a real TPU deployment this binary runs per host under the usual
`jax.distributed.initialize()`; on this container it drives the smoke configs
end to end (full configs are exercised by the dry-run).  Features: mesh
construction, sparse transposable-N:M fine-tuning, gradient accumulation,
int8 cross-pod gradient compression, fault-tolerant checkpointing with
resume, straggler flagging.
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec
from repro.data import SyntheticEmbeds, SyntheticLM
from repro.distributed.sharding import set_mesh
from repro.launch.mesh import make_mesh
from repro.optim import AdamW, warmup_cosine
from repro.sparsity.masks import sparsify_pytree
from repro.train import TrainLoop, TrainLoopConfig, build_train_step, make_train_state
from repro.train.step import StepConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--nm", default=None, help="N:M sparse fine-tune, e.g. 8:16")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4=data,model")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--max-step-seconds", type=float, default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh:
        shape_s, axes_s = args.mesh.split("=")
        shape = tuple(int(x) for x in shape_s.split("x"))
        axes = tuple(axes_s.split(","))
        mesh = make_mesh(shape, axes)
        set_mesh(mesh)

    if cfg.frontend != "none":
        data = SyntheticEmbeds(cfg.d_model, args.seq, args.batch, cfg.vocab_size)
    else:
        data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)

    opt = AdamW(learning_rate=warmup_cosine(args.lr, args.steps // 10, args.steps))
    state = make_train_state(
        cfg, opt, jax.random.PRNGKey(0), compression=args.compress_pods
    )

    masks = None
    if args.nm:
        base = PatternSpec.parse(args.nm)
        spec = PatternSpec(base.n, base.m, True)
        print(f"[train] solving transposable {spec.n}:{spec.m} masks (TSENOR)")
        masks = sparsify_pytree(state.params, spec, config=SolverConfig(iters=150))

    step = build_train_step(
        cfg, opt, masks=masks,
        step_cfg=StepConfig(accum=args.accum, compression=args.compress_pods),
        mesh=mesh,
    )
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    loop = TrainLoop(
        step, data, ckpt,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        log_every=10, max_step_seconds=args.max_step_seconds),
    )
    batch0 = {k: jax.numpy.asarray(v) for k, v in data.batch(0).items()}  # noqa
    state, hist = loop.run(state)
    print(f"[train] done: {len(hist)} steps, final loss "
          f"{hist[-1]['loss']:.4f}" if hist else "[train] resumed-complete")


if __name__ == "__main__":
    main()
