"""Debug tool: per-instruction HBM-byte attribution for one dry-run cell.

    PYTHONPATH=src python -m repro.launch.hlo_top --arch X --shape Y [...]

Prints the top instructions by (trip-count-scaled) traffic — the profile that
drives each §Perf iteration (no wall-clock profiler exists on this CPU
container, so the lowered HLO is the profile; see task brief).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import re  # noqa: E402
from collections import defaultdict  # noqa: E402

from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.distributed.sharding import set_mesh  # noqa: E402
from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch.dryrun import input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def top_contributors(hlo: str, k: int = 20):
    comps, entry = H.parse_module(hlo)
    symtab = {c: {i.name: i.out_text for i in instrs} for c, instrs in comps.items()}
    fused = set()
    for instrs in comps.values():
        for i in instrs:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", i.attrs_text):
                fused.add(m.group(1))

    rows = []

    def walk(cname, mult, seen):
        if cname in seen or cname not in comps:
            return
        seen = seen | {cname}
        top = cname not in fused
        for ins in comps[cname]:
            op = ins.opcode
            if op in H._FREE_OPS or op == "get-tuple-element":
                continue
            if top:
                ob = H._shapes_bytes(ins.out_text)
                ib = sum(
                    H._shapes_bytes(symtab.get(cname, {}).get(o, ""))
                    for o in H._OPERAND_RE.findall(ins.args_text)
                )
                if op in ("while", "conditional", "call"):
                    io = 0.0
                elif op in ("dynamic-slice", "slice", "gather"):
                    io = 2 * ob
                else:
                    io = ib + ob
                if io:
                    meta = re.search(r'op_name="([^"]+)"', ins.attrs_text)
                    rows.append((mult * io, op, ins.out_text[:48],
                                 (meta.group(1) if meta else "")[:80]))
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs_text)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs_text)
                trips = 1
                if cm:
                    for i2 in comps.get(cm.group(1), []):
                        for mm in re.finditer(r"constant\((\d+)\)", i2.args_text):
                            trips = max(trips, int(mm.group(1)))
                        if i2.opcode == "constant":
                            mm = re.match(r"\s*(\d+)\s*$", i2.args_text)
                            if mm:
                                trips = max(trips, int(mm.group(1)))
                if bm:
                    walk(bm.group(1), mult * trips, seen)
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                     ins.attrs_text):
                    walk(m.group(1), mult, seen)

    walk(entry, 1.0, frozenset())
    rows.sort(reverse=True)
    agg = defaultdict(float)
    for b, op, _, meta in rows:
        key = meta.split("/")[-1][:40] if meta else op
        agg[f"{op}:{key}"] += b
    return rows[:k], sorted(agg.items(), key=lambda kv: -kv[1])[:k]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--topk", type=int, default=18)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    for ov in args.override:
        k, v = ov.split("=", 1)
        cfg = dataclasses.replace(cfg, **{k: int(v) if v.isdigit() else v})
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    set_mesh(mesh)
    fn, specs_args = input_specs(
        cfg, SHAPES[args.shape], mesh, sparse=not args.dense, accum=1
    )
    compiled = fn.lower(*specs_args).compile()
    rows, agg = top_contributors(compiled.as_text(), args.topk)
    print("== top instructions (trip-scaled bytes/device) ==")
    for b, op, shape, meta in rows:
        print(f"{b / 2**30:9.2f} GiB  {op:20s} {shape:48s} {meta}")
    print("\n== aggregated by op_name ==")
    for k, b in agg:
        print(f"{b / 2**30:9.2f} GiB  {k}")


if __name__ == "__main__":
    main()
