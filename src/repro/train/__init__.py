"""Training substrate: jitted step builder and fault-tolerant loop."""
from repro.train.step import TrainState, build_train_step, make_train_state
from repro.train.loop import TrainLoop, TrainLoopConfig

__all__ = [
    "TrainState",
    "build_train_step",
    "make_train_state",
    "TrainLoop",
    "TrainLoopConfig",
]
