"""Fault-tolerant training loop.

Responsibilities beyond calling the step function:

  * resume-from-latest on startup (step counter + optimizer state + data
    position all come back; the synthetic pipeline is a pure function of the
    step so no iterator files are needed);
  * periodic async checkpoints + a final synchronous one;
  * emergency checkpoint on any exception or SIGTERM/SIGINT (preemption):
    the loop catches, saves ``step_<N>`` atomically, and re-raises — a
    supervisor restarting the job lands exactly where it left off;
  * a ``failure_injector(step)`` hook that tests use to prove the
    crash/restart path actually works;
  * dynamic-sparse-training persistence: when the step function carries a
    refresh controller (``build_train_step`` with ``StepConfig(refresh=...)``
    exposes it as ``step_fn.refresh``; an explicit ``refresh=`` wins), its
    ``state_dict()`` rides every checkpoint's metadata and is restored on
    resume — a killed DST run comes back mid-schedule, re-arming any
    refresh that was in flight;
  * straggler mitigation knob: ``max_step_seconds`` — when a step exceeds it
    (slow host / bad chip), the loop flags it in metrics so an external
    orchestrator can re-slice; with synchronous SPMD there is no per-step
    work stealing, which is the honest TPU answer (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 100
    log_every: int = 10
    max_step_seconds: Optional[float] = None


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,
        data,
        ckpt: Optional[CheckpointManager],
        config: TrainLoopConfig,
        failure_injector: Optional[Callable[[int], None]] = None,
        log_fn: Callable[[str], None] = print,
        refresh=None,
    ):
        self.step_fn = step_fn
        self.data = data
        self.ckpt = ckpt
        self.config = config
        self.failure_injector = failure_injector
        self.log = log_fn
        # DST controller (duck-typed: state_dict/load_state_dict/events):
        # explicit argument, else the one the step builder attached.
        self.refresh = refresh if refresh is not None \
            else getattr(step_fn, "refresh", None)
        self._interrupted = False

    def _ckpt_metadata(self, extra: dict) -> dict:
        if self.refresh is not None:
            return dict(extra, dst=self.refresh.state_dict())
        return extra

    def _install_signal_handler(self):
        def handler(signum, frame):
            self._interrupted = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def run(self, state, start_step: Optional[int] = None):
        """Run to total_steps; returns (state, history).  Resumes if possible."""
        self._install_signal_handler()
        cfg = self.config
        step = start_step
        if step is None:
            step = int(np.asarray(jax.tree.leaves(state.step)[0]))
            if self.ckpt is not None:
                latest = self.ckpt.latest_step()
                if latest is not None and latest > step:
                    state = self.ckpt.restore(latest, state)
                    step = latest
                    self.log(f"[loop] resumed from checkpoint step {step}")
                    if self.refresh is not None:
                        dst_meta = self.ckpt.user_metadata(latest).get("dst")
                        if dst_meta is not None:
                            self.refresh.load_state_dict(dst_meta)
                            self.log(
                                f"[loop] dst controller resumed "
                                f"({len(self.refresh.events)} refreshes done)"
                            )
        history = []
        try:
            while step < cfg.total_steps:
                if self.failure_injector is not None:
                    self.failure_injector(step)
                batch = self.data.batch(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batch)
                loss = float(np.asarray(metrics["loss"]))
                dt = time.monotonic() - t0
                step += 1
                straggler = bool(
                    cfg.max_step_seconds and dt > cfg.max_step_seconds
                )
                history.append({"step": step, "loss": loss, "sec": dt,
                                "straggler": straggler})
                if straggler:
                    self.log(f"[loop] step {step} straggled: {dt:.2f}s")
                if step % cfg.log_every == 0:
                    self.log(f"[loop] step {step} loss {loss:.4f} ({dt:.2f}s)")
                if self.ckpt is not None and step % cfg.ckpt_every == 0:
                    self.ckpt.save(step, state,
                                   self._ckpt_metadata({"loss": loss}))
                if self._interrupted:
                    raise KeyboardInterrupt("preemption signal")
        except BaseException as e:
            if self.ckpt is not None:
                self.log(f"[loop] emergency checkpoint at step {step} ({e!r})")
                self.ckpt.async_save = False
                self.ckpt.save(step, state,
                               self._ckpt_metadata({"emergency": True}))
            raise
        if self.ckpt is not None:
            self.ckpt.async_save = False
            self.ckpt.save(step, state, self._ckpt_metadata({"final": True}))
        return state, history
