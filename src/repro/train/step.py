"""Jitted train step: grad accumulation, sparse masks, pod-compressed grads.

The step is built once per (arch, mesh) and covers:

  * microbatch gradient accumulation via ``lax.scan`` (constant memory);
  * fixed transposable-N:M masks applied to the weights in the forward pass
    (sparse fine-tuning — gradients are masked by the chain rule, and the
    masked weights are re-projected after the optimizer update so the support
    never drifts);
  * compressed execution (``mask_mode="compressed"``): params whose pruned
    leaves are :class:`~repro.sparsity.params.NMCompressed` train straight
    from the compressed buffers — the model dispatches those matmuls through
    the nm_spmm kernel, gradients flow to ``values`` only (the custom VJP
    restricts dW to the support), and optimizer moments live on the
    compressed shapes (N/M of the dense optimizer HBM);
  * optional int8+error-feedback gradient compression across the "pod" axis:
    the step is shard_mapped with *manual* pod axis (data/model stay GSPMD-
    auto) so the cross-pod all-reduce is ours to quantize;
  * sharding: params follow ``param_specs``; batch is sharded over
    ("pod","data").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compression import compressed_psum
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW, AdamWState

MASK_MODES = ("fwd", "post", "compressed")


class TrainState(NamedTuple):
    params: Any
    opt_state: AdamWState
    step: jnp.ndarray
    ef: Any = None          # error-feedback residuals (compression only)


def _diff_zeros_like(p):
    """f32 accumulator for a differentiable leaf; size-0 placeholder for
    non-differentiable ones (e.g. compressed N:M indices)."""
    if jnp.issubdtype(p.dtype, jnp.inexact):
        return jnp.zeros(p.shape, jnp.float32)
    return jnp.zeros((0,), jnp.float32)


def _strip_float0(grads):
    """Replace ``float0`` cotangents (integer leaves under ``allow_int``)
    with size-0 f32 placeholders that survive scan carries and tree math."""
    return jax.tree.map(
        lambda g: jnp.zeros((0,), jnp.float32)
        if g.dtype == jax.dtypes.float0 else g,
        grads,
    )


def _ef_zeros_like(p):
    """Error-feedback residual buffer: param-shaped for differentiable
    leaves, size-0 placeholder for integer ones (compressed indices)."""
    if jnp.issubdtype(p.dtype, jnp.inexact):
        return jnp.zeros_like(p)
    return jnp.zeros((0,), jnp.float32)


def make_train_state(cfg: ModelConfig, opt: AdamW, key, compression: bool = False,
                     params: Any = None):
    """Fresh TrainState; pass ``params=`` to adopt existing (possibly
    compressed SparseParams) weights instead of initializing dense ones."""
    if params is None:
        params = lm.init_params(cfg, key)
    state = TrainState(
        params=params,
        opt_state=opt.init(params),
        step=jnp.zeros((), jnp.int32),
        ef=jax.tree.map(_ef_zeros_like, params) if compression else None,
    )
    return state


@dataclasses.dataclass(frozen=True)
class StepConfig:
    accum: int = 1                       # gradient accumulation microbatches
    compression: bool = False            # int8 cross-pod grad compression
    pod_axis: str = "pod"
    # "fwd":  paper-faithful — masks multiply weights inside the forward pass
    #         (masks are read fwd+bwd every microbatch).
    # "post": optimized — params are kept masked as an invariant and only
    #         re-projected after the optimizer update; forward touches no
    #         masks.  Identical masked weights after every step (the update
    #         to dead entries is erased either way), ~2x less mask traffic.
    # "compressed": params are SparseParams (NMCompressed leaves); no masks
    #         exist at all — the support is encoded in the indices, updates
    #         touch values only, and the forward/backward matmuls stream the
    #         compressed buffers.  Bit-identical masked weights to "fwd"/
    #         "post" after decompression (property-tested) whenever (a)
    #         global grad-norm clipping never rescales (clip_norm=0, or
    #         gnorm stays below it — "post" gradients carry nonzero
    #         dead-position components, so an engaged clip scales the modes
    #         differently) and (b) projection dims fit one nm_spmm K-tile
    #         (256; larger dims accumulate per tile, tracking dense to f32
    #         roundoff instead of bitwise).
    mask_mode: str = "fwd"
    # Dynamic sparse training: a repro.dst.MaskRefreshController (or any
    # object with ``on_step(step, state) -> state``).  The built step is
    # wrapped so every call routes the pre-step state through the hook,
    # which may swap the SparseParams support (see repro/dst/controller.py).
    # Compressed mode only: the other modes' masks are baked into the trace.
    refresh: Optional[Any] = None
    # Structured-sparse backward: "off" (default — bit-identical to the
    # historical compressed path), or an N:M gradient pattern (PatternSpec /
    # string like "8:16") independent of the weight pattern.  Compressed
    # leaves then sparsify their incoming cotangent dY in-flight (MVU
    # stochastic rounding, seed = step * accum + microbatch) so BOTH backward
    # GEMMs stream compressed operands.  Compressed mode only.
    grad_sparsity: Any = "off"


def _split_microbatches(batch: dict, accum: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])

    return {k: f(v) for k, v in batch.items()}


def build_train_step(
    cfg: ModelConfig,
    opt: AdamW,
    masks: Any = None,
    step_cfg: StepConfig = StepConfig(),
    mesh: Optional[Mesh] = None,
    in_shardings=None,
    donate: bool = True,
    masks_as_input: bool = False,
) -> Callable:
    """Returns jitted ``step(state, batch) -> (state, metrics)``, or with
    ``masks_as_input=True`` ``step(state, batch, masks) -> ...`` (the dry-run
    lowers masks as abstract inputs so nothing is ever allocated)."""
    if step_cfg.mask_mode not in MASK_MODES:
        raise ValueError(
            f"mask_mode must be one of {MASK_MODES}, got {step_cfg.mask_mode!r}"
        )
    if step_cfg.mask_mode == "compressed" and (masks is not None or masks_as_input):
        raise ValueError(
            "mask_mode='compressed' encodes the support in the params "
            "(NMCompressed indices); do not pass masks"
        )
    if step_cfg.refresh is not None and step_cfg.mask_mode != "compressed":
        raise ValueError(
            "StepConfig.refresh (dynamic sparse training) requires "
            "mask_mode='compressed': the refresh swaps NMCompressed support; "
            f"got mask_mode={step_cfg.mask_mode!r}"
        )
    sg_spec = None
    if step_cfg.grad_sparsity != "off":
        if step_cfg.mask_mode != "compressed":
            raise ValueError(
                "StepConfig.grad_sparsity sparsifies the cotangents of "
                "compressed projections; it requires mask_mode='compressed' "
                f"(got mask_mode={step_cfg.mask_mode!r})"
            )
        from repro.patterns import PatternSpec

        sg_spec = PatternSpec.coerce(step_cfg.grad_sparsity)

    def apply_masks(params, mask_tree):
        if mask_tree is None:
            return params
        return jax.tree.map(
            lambda p, m: p if m is None else p * m.astype(p.dtype),
            params,
            mask_tree,
            is_leaf=lambda x: x is None,
        )

    def loss_of(params, microbatch, mask_tree, seed=None):
        if step_cfg.mask_mode in ("post", "compressed"):
            mask_tree = None  # support already enforced by the params
        if sg_spec is None:
            return lm.loss_fn(apply_masks(params, mask_tree), cfg, microbatch)
        from repro.kernels.nm_grad.ops import sparse_grad_context

        with sparse_grad_context(sg_spec, seed):
            return lm.loss_fn(apply_masks(params, mask_tree), cfg, microbatch)

    def grads_of(params, batch, mask_tree, step):
        # allow_int: compressed params carry int8 index leaves; their
        # float0 cotangents are stripped to size-0 placeholders right away.
        vag = jax.value_and_grad(loss_of, allow_int=True)
        # One seed per microbatch: deterministic for a fixed step, distinct
        # across microbatches and steps (only consulted when sg_spec is set).
        base = step.astype(jnp.int32) * step_cfg.accum
        if step_cfg.accum == 1:
            loss, g = vag(params, batch, mask_tree, base)
            return loss, _strip_float0(g)
        micro = _split_microbatches(batch, step_cfg.accum)
        seeds = base + jnp.arange(step_cfg.accum, dtype=jnp.int32)

        def body(carry, xs):
            mb, seed = xs
            loss_acc, grad_acc = carry
            loss, g = vag(params, mb, mask_tree, seed)
            return (
                loss_acc + loss,
                jax.tree.map(jnp.add, grad_acc, _strip_float0(g)),
            ), None

        zeros = jax.tree.map(_diff_zeros_like, params)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (0.0, zeros), (micro, seeds)
        )
        k = float(step_cfg.accum)
        return loss_sum / k, jax.tree.map(lambda g: g / k, grad_sum)

    def core_step(state: TrainState, batch: dict, mask_tree=None):
        if not masks_as_input:
            mask_tree = masks
        if step_cfg.mask_mode == "compressed":
            # Trace-time guard: dense params here would train with no
            # masking AND no re-projection — silent support drift.
            from repro.sparsity.params import is_sparse_params

            if not is_sparse_params(state.params):
                raise ValueError(
                    "mask_mode='compressed' needs SparseParams (NMCompressed "
                    "leaves) — prune with emit='compressed' or call "
                    "compress_params; got an all-dense tree"
                )
        loss, grads = grads_of(state.params, batch, mask_tree, state.step)
        ef = state.ef
        if step_cfg.compression:
            grads, ef = compressed_psum(grads, ef, step_cfg.pod_axis)
            loss = jax.lax.pmean(loss, step_cfg.pod_axis)
        new_params, new_opt, metrics = opt.update(grads, state.opt_state, state.params)
        if step_cfg.mask_mode != "compressed":
            # Compressed updates cannot leave the support (values-only);
            # dense modes re-project so dead entries stay exactly zero.
            new_params = apply_masks(new_params, mask_tree)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt, state.step + 1, ef), metrics

    if step_cfg.compression:
        if mesh is None or step_cfg.pod_axis not in mesh.axis_names:
            raise ValueError("compression requires a mesh with a pod axis")
        # Manual over "pod" (params/state replicated across pods, batch split);
        # inner data/model dims remain GSPMD-auto.
        auto = frozenset(n for n in mesh.axis_names if n != step_cfg.pod_axis)
        state_spec = P()  # replicated across pods
        batch_spec = P(step_cfg.pod_axis)
        from repro.compat import shard_map

        fn = shard_map(
            core_step,
            mesh=mesh,
            in_specs=(state_spec, batch_spec),
            out_specs=(state_spec, state_spec),
            axis_names=frozenset({step_cfg.pod_axis}),
            check_vma=False,
        )
    else:
        fn = core_step

    jitted = jax.jit(fn, donate_argnums=(0,) if donate else (),
                     in_shardings=in_shardings)
    if step_cfg.refresh is None:
        return jitted
    # DST: the refresh hook runs host-side BETWEEN jitted steps.  A swap to
    # a different N changes the compressed leaf shapes, which jit handles by
    # re-tracing — once per schedule stage, not per step.
    from repro.dst.controller import wrap_step_with_refresh

    return wrap_step_with_refresh(jitted, step_cfg.refresh)
