"""Canonical N:M sparsity pattern specification.

:class:`PatternSpec` is the single source of truth for "which sparsity
pattern" across the codebase, replacing the loose ``(n, m, transposable)``
argument triples that used to be threaded through ``core``, ``service``,
``pruning``, ``sparsity`` and ``launch``.  It is a frozen (hashable)
dataclass, so it can key scheduler groups and cache entries directly.

Canonical string form (accepted everywhere a pattern is accepted):

    "t16:32"  transposable 16:32  (both the mask and its transpose are N:M)
    "2:4"     standard row-wise 2:4

The module is dependency-free (no jax/numpy) so every layer can import it.
See ``docs/architecture.md`` for where PatternSpec sits in the layer map and
``docs/solver_math.md`` for what the transposable constraint means.
"""
from __future__ import annotations

import dataclasses
import inspect
import operator
import warnings


@dataclasses.dataclass(frozen=True)
class PatternSpec:
    """An N:M sparsity pattern: keep N of every M, optionally transposable.

    ``transposable=True`` (the TSENOR setting) demands every M x M block of
    the mask have <= N ones per row AND per column, so one compressed buffer
    serves both the forward and backward matmuls.  ``transposable=False`` is
    the standard one-directional N:M constraint.
    """

    n: int
    m: int
    transposable: bool = True

    def __post_init__(self):
        try:
            n = operator.index(self.n)
            m = operator.index(self.m)
        except TypeError:
            raise TypeError(
                f"PatternSpec n and m must be integers, got {self.n!r}:{self.m!r}"
            ) from None
        if isinstance(self.n, bool) or isinstance(self.m, bool):
            raise TypeError("PatternSpec n and m must be integers, not bools")
        if n < 1:
            raise ValueError(f"PatternSpec needs n >= 1, got n={n}")
        if n > m:
            raise ValueError(f"PatternSpec needs n <= m, got {n}:{m}")
        object.__setattr__(self, "n", n)
        object.__setattr__(self, "m", m)
        object.__setattr__(self, "transposable", bool(self.transposable))

    # -- canonical form ------------------------------------------------------

    @property
    def canonical(self) -> str:
        """``"t16:32"`` / ``"2:4"``; ``parse(spec.canonical) == spec``."""
        return f"{'t' if self.transposable else ''}{self.n}:{self.m}"

    def __str__(self) -> str:
        return self.canonical

    @classmethod
    def parse(cls, text: str) -> "PatternSpec":
        """Parse the canonical form: ``"t16:32"`` or ``"2:4"``."""
        s = text.strip()
        transposable = s.startswith("t")
        body = s[1:] if transposable else s
        parts = body.split(":")
        if len(parts) != 2:
            raise ValueError(
                f"cannot parse pattern {text!r}; expected 'N:M' or 'tN:M'"
            )
        try:
            n, m = int(parts[0]), int(parts[1])
        except ValueError:
            raise ValueError(
                f"cannot parse pattern {text!r}; expected 'N:M' or 'tN:M'"
            ) from None
        return cls(n, m, transposable)

    @classmethod
    def coerce(cls, value) -> "PatternSpec":
        """PatternSpec | canonical string | (n, m[, transposable]) tuple."""
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls.parse(value)
        if isinstance(value, (tuple, list)) and len(value) in (2, 3):
            return cls(*value)
        raise TypeError(
            f"cannot interpret {value!r} as a PatternSpec "
            "(pass a PatternSpec, 'tN:M'/'N:M' string, or (n, m[, transposable]))"
        )

    # -- helpers -------------------------------------------------------------

    @property
    def density(self) -> float:
        """Kept fraction N/M."""
        return self.n / self.m

    @property
    def sparsity(self) -> float:
        """Zeroed fraction 1 - N/M."""
        return 1.0 - self.n / self.m

    def pad_amount(self, dim: int) -> int:
        """Zero-padding needed to bring ``dim`` to a multiple of M."""
        return (-dim) % self.m

    def divides(self, shape) -> bool:
        """True when the trailing two dims of ``shape`` divide by M."""
        if len(shape) < 2:
            return False
        return shape[-1] % self.m == 0 and shape[-2] % self.m == 0


def pattern_from_args(
    pattern,
    m=None,
    transposable=None,
    *,
    n=None,
    caller: str,
    default_transposable: bool = True,
    stacklevel: int = 3,
) -> PatternSpec:
    """Resolve a public API's ``pattern`` argument, accepting the deprecated
    ``(n, m[, transposable])`` calling convention with a DeprecationWarning.

    New-style callers pass a :class:`PatternSpec` (or canonical string /
    tuple) as ``pattern``; legacy callers pass ``n`` (positionally, landing
    in ``pattern``, or as the ``n=`` keyword) together with ``m``.
    """
    if pattern is None and n is not None:
        pattern = n
    if pattern is None:
        raise TypeError(f"{caller}: missing required 'pattern' argument")
    if isinstance(pattern, bool):
        raise TypeError(f"{caller}: pattern must not be a bool")
    if isinstance(pattern, int):
        if m is None:
            raise TypeError(
                f"{caller}: bare n={pattern} needs m; pass a PatternSpec "
                f"or canonical string like 't{pattern}:<m>' instead"
            )
        spec = PatternSpec(
            pattern, m,
            default_transposable if transposable is None else bool(transposable),
        )
        warnings.warn(
            f"{caller}: passing (n, m{', transposable' if transposable is not None else ''}) "
            f"is deprecated; pass pattern=PatternSpec({spec.n}, {spec.m}, "
            f"{spec.transposable}) or pattern={spec.canonical!r}",
            DeprecationWarning,
            stacklevel=stacklevel,
        )
        return spec
    if m is not None:
        raise TypeError(f"{caller}: cannot combine a pattern object with m=")
    spec = PatternSpec.coerce(pattern)
    if transposable is not None and bool(transposable) != spec.transposable:
        raise ValueError(
            f"{caller}: transposable={transposable} conflicts with pattern "
            f"{spec.canonical!r}"
        )
    return spec


def call_mask_fn(mask_fn, scores, pattern: PatternSpec, *, caller: str):
    """Invoke a caller-supplied mask override with the new ``(scores,
    pattern)`` contract, shimming the deprecated ``(scores, n, m)`` one.

    A callback whose signature takes three or more positional parameters and
    no ``*args`` is treated as the legacy form and called with
    ``(scores, n, m)`` under a DeprecationWarning.
    """
    try:
        params = list(inspect.signature(mask_fn).parameters.values())
    except (TypeError, ValueError):  # builtins / C callables: assume new form
        return mask_fn(scores, pattern)
    if any(p.kind is inspect.Parameter.VAR_POSITIONAL for p in params):
        return mask_fn(scores, pattern)
    positional = [
        p for p in params
        if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                      inspect.Parameter.POSITIONAL_OR_KEYWORD)
    ]
    if len(positional) >= 3:
        warnings.warn(
            f"{caller}: mask_fn(scores, n, m) callbacks are deprecated; "
            "take (scores, pattern) instead",
            DeprecationWarning,
            stacklevel=3,
        )
        return mask_fn(scores, pattern.n, pattern.m)
    return mask_fn(scores, pattern)
