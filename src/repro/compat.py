"""Version-compat shims for the installed JAX.

The repo targets recent JAX (where ``jax.sharding.AxisType`` exists and
``jax.make_mesh`` accepts ``axis_types``), but must degrade gracefully on
older releases: every mesh in this codebase uses Auto axis types, which is
exactly the default when the argument is unsupported, so dropping it is
semantics-preserving.
"""
from __future__ import annotations

import inspect

import jax

AxisType = getattr(jax.sharding, "AxisType", None)

_MAKE_MESH_TAKES_AXIS_TYPES = (
    hasattr(jax, "make_mesh")
    and "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def auto_axis_types(num_axes: int):
    """(AxisType.Auto,) * num_axes, or None when the installed JAX predates
    explicit axis types (Auto is then the only behaviour anyway)."""
    if AxisType is None:
        return None
    return (AxisType.Auto,) * num_axes


def make_mesh(shape: tuple, axes: tuple, axis_types=None):
    """``jax.make_mesh`` that tolerates JAX versions without ``axis_types``
    (or without ``jax.make_mesh`` at all)."""
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils

    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """``jax.shard_map`` (newer JAX) or ``jax.experimental.shard_map`` with
    the ``axis_names``/``check_vma`` kwargs mapped to ``auto``/``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    # Partial-auto shard_map on old JAX trips XLA's manual-subgroup check at
    # compile time, so the fallback takes EVERY axis manual.  That is
    # numerically identical whenever the wrapped function doesn't rely on
    # GSPMD partitioning over the would-be-auto axes (true for this repo:
    # the model forward contains no sharding constraints).
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_name):
    """``jax.lax.axis_size`` with a psum(1) fallback for older JAX."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def set_mesh_context(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh(mesh)`` on newer
    JAX, the legacy ``with mesh:`` global-mesh context otherwise."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def abstract_mesh(shape: tuple, axes: tuple):
    """``jax.sharding.AbstractMesh(shape, axes)`` across the signature change
    (older JAX takes a single tuple of (name, size) pairs)."""
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
