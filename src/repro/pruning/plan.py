"""Lockstep driver for ``PruneMethod.solve_plan`` generators.

Sequential pruning methods (SparseGPT's column-block sweep, ALPS's ADMM
loop) cannot hand the service their whole mask workload up front: each
solve request depends on the previous solve's result.  What they *can* do
is express the dependency structure as a generator — the ``solve_plan``
protocol (see :mod:`repro.pruning.methods`):

    def my_solve_plan(w, gram, pattern, ctx):
        for step in ...:
            scores = <jitted device work>
            mask = yield scores          # one batched mask-solve request
            <jitted device work using mask>
        return w_pruned, mask

:func:`drive_solve_plans` runs several such generators *in lockstep*
against one :class:`~repro.service.MaskService`: at every sweep it collects
the current request of every live plan, submits them all, flushes the
service ONCE (one bucketed mega-batch, cache consulted per request), and
sends each result back into its generator.  Tensors that share a sweep
structure (e.g. the wq/wk/wv projections of one layer under SparseGPT)
therefore batch their per-step solves even though each tensor's steps are
strictly sequential.

The driver is deliberately dumb: it never inspects the yielded scores and
never reorders sends, so a plan's internal compute chain is identical to
the method's inline implementation — which is what makes the service-routed
masks bit-identical to the inline ones at ``SolverConfig.tol = 0``
(``tests/test_pruning_service.py``).

See ``docs/architecture.md`` ("The solve_plan path") for the full request
lifecycle.
"""
from __future__ import annotations

from typing import Any, Dict, Generator, Mapping

import numpy as np

from repro.patterns import PatternSpec

SolvePlan = Generator[Any, Any, Any]


def drive_solve_plans(
    plans: Mapping[str, SolvePlan],
    service,
    pattern,
) -> Dict[str, Any]:
    """Advance every plan generator in lockstep; one service flush per sweep.

    Args:
      plans: name -> generator following the ``solve_plan`` protocol (yields
        score matrices, receives boolean masks, returns the method's final
        value via ``return`` / ``StopIteration``).
      service: a :class:`repro.service.MaskService`; every yielded request is
        submitted to it and all requests of one sweep are solved by a single
        ``flush()``.
      pattern: the transposable :class:`~repro.patterns.PatternSpec` every
        request is solved under.

    Returns:
      name -> the generator's return value, for every plan.  Plans may run
      different numbers of sweeps; finished plans simply drop out of later
      flushes.
    """
    spec = PatternSpec.coerce(pattern)
    live = dict(plans)
    inbox: Dict[str, Any] = {}
    results: Dict[str, Any] = {}
    step = 0
    while live:
        requests = {}
        for name in list(live):
            gen = live[name]
            try:
                if step == 0:
                    scores = next(gen)
                else:
                    scores = gen.send(inbox[name])
            except StopIteration as stop:
                results[name] = stop.value
                del live[name]
                continue
            requests[name] = scores
        if requests:
            # journal=False: sweep requests are ephemeral — their resume
            # path is the content cache, and a journal record per sweep
            # per tensor would fsync thousands of times per layer.
            handles = {
                name: service.submit(
                    f"{name}/sweep{step:05d}", scores, spec, journal=False
                )
                for name, scores in requests.items()
            }
            service.flush()  # ONE bucketed mega-batch for the whole sweep
            for name, handle in handles.items():
                inbox[name] = np.asarray(handle.result())
        step += 1
    return results
