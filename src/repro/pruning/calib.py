"""Calibration statistics for layer-wise pruning."""
from __future__ import annotations

import jax.numpy as jnp


def gram_matrix(x: jnp.ndarray, damp: float = 1e-2) -> jnp.ndarray:
    """H = XᵀX + λI with relative damping λ = damp * mean(diag XᵀX).

    ``x``: (tokens, in) calibration activations (flattened over batch/seq).
    The relative damping rule matches the SparseGPT/ALPS implementations.
    """
    x = jnp.asarray(x, jnp.float32)
    h = x.T @ x
    lam = damp * jnp.mean(jnp.diag(h))
    return h + lam * jnp.eye(h.shape[0], dtype=h.dtype)


def col_norms(x: jnp.ndarray) -> jnp.ndarray:
    """Per-input-feature activation norms ||X_:,i||_2 (Wanda importance)."""
    return jnp.sqrt(jnp.sum(jnp.asarray(x, jnp.float32) ** 2, axis=0))


def reconstruction_error(
    x: jnp.ndarray, w_hat: jnp.ndarray, w: jnp.ndarray
) -> jnp.ndarray:
    """||X What - X W||_F^2 / ||X What||_F^2 (paper §B.2.3)."""
    ref = x @ w_hat
    diff = ref - x @ w
    return jnp.sum(diff**2) / jnp.maximum(jnp.sum(ref**2), 1e-30)
