"""PruneMethod protocol + registry: one signature for every layer-wise
pruning framework.

Every method — built-in (``magnitude``/``wanda``/``sparsegpt``/``alps``) or
third-party — is a callable

    method(w, gram, pattern, ctx) -> (w_pruned, mask)

where ``w`` is the (in, out) weight matrix, ``gram`` is the damped Gram
``XᵀX + λI`` (``None`` unless the method declares ``needs_gram``),
``pattern`` is a :class:`~repro.patterns.PatternSpec`, and ``ctx`` is a
:class:`PruneContext` carrying calibration activations and solver configs.
``prune_transformer(method="wanda")`` is a registry lookup, so new methods
plug in without touching ``runner.py``::

    from repro.api import register_method

    @register_method("my-method")
    def my_method(w, gram, pattern, ctx):
        ...
        return w_pruned, mask

Two optional hooks let the runner batch a method's transposable mask solves
through the :class:`~repro.service.MaskService` instead of one solve per
tensor (see ``docs/architecture.md``, "The solve request lifecycle"):

* ``importance(w, ctx) -> scores`` — for methods whose mask is a pure
  function of a per-weight importance matrix (Wanda, magnitude).  The
  runner submits every tensor's scores up front and solves the whole
  projection group as ONE bucketed flush.
* ``solve_plan(w, gram, pattern, ctx) -> generator`` — for *sequential*
  methods whose solve requests depend on earlier solve results (SparseGPT's
  column-block sweep, ALPS's ADMM loop).  The generator yields score
  matrices and receives solved masks (see :mod:`repro.pruning.plan`); the
  runner drives all tensors of a projection group in lockstep, flushing the
  service once per sweep, so even sequential methods get mega-batched
  dispatch, the fused backend and content-cache hits.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec
from repro.pruning.alps import AlpsConfig, alps_prune, alps_solve_plan
from repro.pruning.calib import gram_matrix
from repro.pruning.magnitude import magnitude_prune
from repro.pruning.sparsegpt import sparsegpt_prune, sparsegpt_solve_plan
from repro.pruning.wanda import wanda_importance, wanda_prune


@dataclasses.dataclass
class PruneContext:
    """Everything a method may need beyond (w, gram, pattern).

    ``x``: (tokens, in) calibration activations of the layer being pruned.
    ``solver``: TSENOR solver config for mask solves.
    ``alps``: ADMM config for ALPS-style methods.
    ``mask_fn``: optional ``(scores, pattern) -> mask`` override routing
    transposable solves through a service.
    ``service``: optional :class:`~repro.service.MaskService`; methods that
    support service routing (``sparsegpt``/``alps`` ``solve_via``) use it
    for their mask solves so the whole prune run shares one cache, bucket
    ladder and stats counter.
    """

    x: Optional[jnp.ndarray] = None
    solver: SolverConfig = dataclasses.field(
        default_factory=lambda: SolverConfig(iters=150)
    )
    alps: Optional[AlpsConfig] = None
    mask_fn: Optional[Callable] = None
    service: Optional[Any] = None
    _gram: Any = dataclasses.field(default=None, repr=False)

    def gram(self) -> jnp.ndarray:
        """Damped Gram of ``x`` (computed once, cached)."""
        if self._gram is None:
            if self.x is None:
                raise ValueError("PruneContext has no calibration activations")
            self._gram = gram_matrix(self.x)
        return self._gram


@runtime_checkable
class PruneMethod(Protocol):
    """Protocol every registered pruning method implements.

    The two batching hooks (``importance``, ``solve_plan``) are optional
    attributes, surfaced through :func:`method_importance` /
    :func:`method_solve_plan` rather than the protocol itself so plain
    ``(w, gram, pattern, ctx)`` functions keep satisfying it.
    """

    name: str
    needs_gram: bool

    def __call__(
        self, w: jnp.ndarray, gram: Optional[jnp.ndarray],
        pattern: PatternSpec, ctx: PruneContext,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        ...


@dataclasses.dataclass(frozen=True)
class _RegisteredMethod:
    """Wraps a plain function into the PruneMethod protocol."""

    name: str
    fn: Callable
    needs_gram: bool = False
    importance: Optional[Callable] = None  # (w, ctx) -> scores, or None
    solve_plan: Optional[Callable] = None  # (w, gram, pattern, ctx) -> gen

    def __call__(self, w, gram, pattern, ctx):
        return self.fn(w, gram, pattern, ctx)


_REGISTRY: dict[str, PruneMethod] = {}


def register_method(
    name: str,
    method: Optional[Callable] = None,
    *,
    needs_gram: bool = False,
    importance: Optional[Callable] = None,
    solve_plan: Optional[Callable] = None,
    overwrite: bool = False,
):
    """Register a pruning method under ``name``.

    Usable as a decorator on a ``(w, gram, pattern, ctx)`` function, or
    called directly with any object satisfying :class:`PruneMethod`.
    ``importance`` and ``solve_plan`` are the optional service-batching
    hooks (see the module docstring).  Registering an existing name without
    ``overwrite=True`` is an error.
    """

    def _register(obj):
        if hasattr(obj, "needs_gram"):  # already satisfies the protocol
            inst = obj
        elif callable(obj):  # plain (w, gram, pattern, ctx) function
            inst = _RegisteredMethod(
                name, obj, needs_gram=needs_gram, importance=importance,
                solve_plan=solve_plan,
            )
        else:
            raise TypeError(f"cannot register {obj!r} as a pruning method")
        if name in _REGISTRY and not overwrite:
            raise ValueError(
                f"pruning method {name!r} is already registered; "
                "pass overwrite=True to replace it"
            )
        _REGISTRY[name] = inst
        return inst

    if method is None:
        return _register
    return _register(method)


def unregister_method(name: str) -> None:
    """Remove a registered method (no-op if absent); mainly for tests."""
    _REGISTRY.pop(name, None)


def get_method(method) -> PruneMethod:
    """Look up a method by name; PruneMethod objects pass through."""
    if not isinstance(method, str):
        if callable(method) and hasattr(method, "needs_gram"):
            return method
        raise TypeError(f"expected a method name or PruneMethod, got {method!r}")
    try:
        return _REGISTRY[method]
    except KeyError:
        raise ValueError(
            f"unknown pruning method {method!r}; available: "
            f"{', '.join(available_methods())}"
        ) from None


def available_methods() -> tuple[str, ...]:
    """Sorted names of every registered pruning method."""
    return tuple(sorted(_REGISTRY))


def method_importance(method: PruneMethod) -> Optional[Callable]:
    """The method's ``importance(w, ctx)`` hook, or None.

    A non-None hook means the transposable mask is a pure function of the
    importance matrix, so the runner may batch the solve through a
    MaskService and apply ``w * mask`` itself.
    """
    return getattr(method, "importance", None)


def method_solve_plan(method: PruneMethod) -> Optional[Callable]:
    """The method's ``solve_plan(w, gram, pattern, ctx)`` hook, or None.

    A non-None hook means the method can express its sequential mask solves
    as a generator of batched service requests; the runner drives all plans
    of a projection group in lockstep through ONE MaskService
    (:func:`repro.pruning.plan.drive_solve_plans`).
    """
    return getattr(method, "solve_plan", None)


# ---------------------------------------------------------------------------
# Built-in methods.
# ---------------------------------------------------------------------------


@register_method("magnitude", importance=lambda w, ctx: jnp.abs(w))
def _magnitude(w, gram, pattern, ctx):
    return magnitude_prune(w, pattern, config=ctx.solver, mask_fn=ctx.mask_fn)


@register_method("wanda", importance=lambda w, ctx: wanda_importance(w, ctx.x))
def _wanda(w, gram, pattern, ctx):
    return wanda_prune(w, ctx.x, pattern, config=ctx.solver, mask_fn=ctx.mask_fn)


def _sparsegpt_plan(w, gram, pattern, ctx):
    h = gram if gram is not None else ctx.gram()
    return sparsegpt_solve_plan(w, h, pattern)


@register_method("sparsegpt", needs_gram=True, solve_plan=_sparsegpt_plan)
def _sparsegpt(w, gram, pattern, ctx):
    h = gram if gram is not None else ctx.gram()
    return sparsegpt_prune(w, h, pattern, config=ctx.solver,
                           service=ctx.service)


def _alps_plan(w, gram, pattern, ctx):
    h = gram if gram is not None else ctx.gram()
    cfg = ctx.alps if ctx.alps is not None else AlpsConfig(solver=ctx.solver)
    return alps_solve_plan(w, h, pattern, config=cfg)


@register_method("alps", needs_gram=True, solve_plan=_alps_plan)
def _alps(w, gram, pattern, ctx):
    h = gram if gram is not None else ctx.gram()
    cfg = ctx.alps if ctx.alps is not None else AlpsConfig(solver=ctx.solver)
    return alps_prune(w, h, pattern, config=cfg, service=ctx.service)
