"""Wanda [Sun et al. 2023] with TSENOR transposable masks (paper Sec. 4).

Importance score: |W_ij| * ||X_:,i||_2.  The transposable mask is found by
solving problem (1) on the importance matrix; weights outside the mask are
zeroed (Wanda performs no weight update).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.solver import SolverConfig, nm_mask, solve_mask
from repro.patterns import call_mask_fn, pattern_from_args
from repro.pruning.calib import col_norms


def wanda_importance(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """|W_ij| * ||X_:,i||_2 — the matrix the mask problem is solved on."""
    return jnp.abs(w) * col_norms(x)[:, None]


def wanda_prune(
    w: jnp.ndarray,
    x: jnp.ndarray,
    pattern=None,
    m=None,
    transposable=None,
    config: SolverConfig = SolverConfig(),
    mask_fn: Optional[Callable] = None,
    *,
    n=None,
):
    """Returns (pruned W, mask).  ``x``: (tokens, in) calibration inputs.

    ``pattern``: :class:`~repro.patterns.PatternSpec` (or canonical string);
    the deprecated ``(n, m[, transposable])`` argument triple still works.
    ``mask_fn(scores, pattern)`` overrides the transposable solver — pass a
    partially-applied ``repro.service.MaskService.solve`` to route through
    the batched/cached engine.
    """
    spec = pattern_from_args(pattern, m, transposable, n=n, caller="wanda_prune")
    imp = wanda_importance(w, x)
    if spec.transposable:
        mask = (
            call_mask_fn(mask_fn, imp, spec, caller="wanda_prune")
            if mask_fn is not None else solve_mask(imp, spec, config)
        )
    else:
        mask = nm_mask(imp, spec.n, spec.m, axis=0)
    return jnp.where(mask, w, 0), mask
