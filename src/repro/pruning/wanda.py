"""Wanda [Sun et al. 2023] with TSENOR transposable masks (paper Sec. 4).

Importance score: |W_ij| * ||X_:,i||_2.  The transposable mask is found by
solving problem (1) on the importance matrix; weights outside the mask are
zeroed (Wanda performs no weight update).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.solver import SolverConfig, nm_mask, transposable_nm_mask
from repro.pruning.calib import col_norms


def wanda_importance(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """|W_ij| * ||X_:,i||_2 — the matrix the mask problem is solved on."""
    return jnp.abs(w) * col_norms(x)[:, None]


def wanda_prune(
    w: jnp.ndarray,
    x: jnp.ndarray,
    n: int,
    m: int,
    transposable: bool = True,
    config: SolverConfig = SolverConfig(),
    mask_fn: Optional[Callable] = None,
):
    """Returns (pruned W, mask).  ``x``: (tokens, in) calibration inputs.

    ``mask_fn(scores, n, m)`` overrides the transposable solver — pass
    ``repro.service.MaskService.solve`` (partially applied) to route through
    the batched/cached engine.
    """
    imp = wanda_importance(w, x)
    if transposable:
        if mask_fn is not None:
            mask = mask_fn(imp, n, m)
        else:
            mask = transposable_nm_mask(imp, n, m, config)
    else:
        mask = nm_mask(imp, n, m, axis=0)
    return jnp.where(mask, w, 0), mask
