"""Wanda [Sun et al. 2023] with TSENOR transposable masks (paper Sec. 4).

Importance score: |W_ij| * ||X_:,i||_2.  The transposable mask is found by
solving problem (1) on the importance matrix; weights outside the mask are
zeroed (Wanda performs no weight update).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.solver import SolverConfig, nm_mask, transposable_nm_mask
from repro.pruning.calib import col_norms


def wanda_prune(
    w: jnp.ndarray,
    x: jnp.ndarray,
    n: int,
    m: int,
    transposable: bool = True,
    config: SolverConfig = SolverConfig(),
):
    """Returns (pruned W, mask).  ``x``: (tokens, in) calibration inputs."""
    imp = jnp.abs(w) * col_norms(x)[:, None]
    if transposable:
        mask = transposable_nm_mask(imp, n, m, config)
    else:
        mask = nm_mask(imp, n, m, axis=0)
    return jnp.where(mask, w, 0), mask
