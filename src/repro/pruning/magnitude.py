"""Magnitude pruning with (transposable) N:M masks."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.solver import SolverConfig, nm_mask, transposable_nm_mask


def magnitude_prune(
    w: jnp.ndarray,
    n: int,
    m: int,
    transposable: bool = True,
    config: SolverConfig = SolverConfig(),
):
    """TSENOR (or row-wise N:M) mask directly on |W|; zero outside the mask."""
    if transposable:
        mask = transposable_nm_mask(w, n, m, config)
    else:
        mask = nm_mask(w, n, m, axis=0)
    return jnp.where(mask, w, 0), mask
