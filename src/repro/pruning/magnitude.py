"""Magnitude pruning with (transposable) N:M masks."""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.solver import SolverConfig, nm_mask, solve_mask
from repro.patterns import call_mask_fn, pattern_from_args


def magnitude_prune(
    w: jnp.ndarray,
    pattern=None,
    m=None,
    transposable=None,
    config: SolverConfig = SolverConfig(),
    mask_fn: Optional[Callable] = None,
    *,
    n=None,
):
    """TSENOR (or row-wise N:M) mask directly on |W|; zero outside the mask.

    ``pattern``: :class:`~repro.patterns.PatternSpec` (or canonical string);
    the deprecated ``(n, m[, transposable])`` triple still works.
    ``mask_fn(scores, pattern)`` overrides the transposable solver (see
    ``wanda_prune``).
    """
    spec = pattern_from_args(pattern, m, transposable, n=n, caller="magnitude_prune")
    if spec.transposable:
        mask = (
            call_mask_fn(mask_fn, jnp.abs(w), spec, caller="magnitude_prune")
            if mask_fn is not None else solve_mask(w, spec, config)
        )
    else:
        mask = nm_mask(w, spec.n, spec.m, axis=0)
    return jnp.where(mask, w, 0), mask
