"""Magnitude pruning with (transposable) N:M masks."""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.solver import SolverConfig, nm_mask, transposable_nm_mask


def magnitude_prune(
    w: jnp.ndarray,
    n: int,
    m: int,
    transposable: bool = True,
    config: SolverConfig = SolverConfig(),
    mask_fn: Optional[Callable] = None,
):
    """TSENOR (or row-wise N:M) mask directly on |W|; zero outside the mask.

    ``mask_fn(scores, n, m)`` overrides the transposable solver (see
    ``wanda_prune``).
    """
    if transposable:
        if mask_fn is not None:
            mask = mask_fn(jnp.abs(w), n, m)
        else:
            mask = transposable_nm_mask(w, n, m, config)
    else:
        mask = nm_mask(w, n, m, axis=0)
    return jnp.where(mask, w, 0), mask
