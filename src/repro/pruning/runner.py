"""Sequential layer-wise LM pruning (SparseGPT-style propagation).

Walks a dense-family transformer layer by layer: capture each projection's
*true* input activations (with earlier layers already pruned), prune it with
the chosen method + TSENOR transposable masks, and propagate the pruned
activations forward — exactly how the paper applies Wanda/SparseGPT/ALPS to
LLaMA.  Covers the attention (wq/wk/wv/wo) and MLP (gate/up/down) projections
of the "dense"/"vlm"/"audio" families; MoE expert matrices and SSM in/out
projections use the same per-matrix APIs directly (see examples/prune_llm.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.solver import SolverConfig
from repro.models.attention import attention
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, embed_tokens
from repro.pruning.alps import AlpsConfig, alps_prune
from repro.pruning.calib import gram_matrix
from repro.pruning.sparsegpt import sparsegpt_prune
from repro.pruning.wanda import wanda_prune


def _prune_one(w, x_flat, method, n, m, transposable, solver, alps_cfg):
    if method == "wanda":
        return wanda_prune(w, x_flat, n, m, transposable, solver)
    if method == "sparsegpt":
        return sparsegpt_prune(w, gram_matrix(x_flat), n, m, transposable, solver)
    if method == "alps":
        return alps_prune(w, gram_matrix(x_flat), n, m, transposable, alps_cfg)
    if method == "magnitude":
        from repro.pruning.magnitude import magnitude_prune

        return magnitude_prune(w, n, m, transposable, solver)
    raise ValueError(method)


def prune_transformer(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
    method: str = "alps",
    n: int = 2,
    m: int = 4,
    transposable: bool = True,
    solver: SolverConfig = SolverConfig(iters=150),
    alps_cfg: Optional[AlpsConfig] = None,
    log=lambda s: None,
):
    """Returns (pruned params, {proj_name: stacked masks}).

    ``tokens``/``embeds``: calibration batch (B, S)/(B, S, d).
    """
    assert cfg.family in ("dense", "vlm", "audio"), cfg.family
    alps_cfg = alps_cfg or AlpsConfig(iters=50, solver=solver)
    dtype = jnp.float32
    if embeds is None:
        x = embed_tokens(params["embed"], tokens, dtype)
    else:
        x = embeds.astype(dtype)
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    blocks = params["blocks"]
    new_attn = {k: [] for k in ("wq", "wk", "wv", "wo")}
    new_mlp = {k: [] for k in ("gate", "up", "down")}
    masks_attn = {k: [] for k in ("wq", "wk", "wv", "wo")}
    masks_mlp = {k: [] for k in ("gate", "up", "down")}

    def pr(w, x_act, name, l):
        wp, mask = _prune_one(
            w.astype(jnp.float32), x_act.reshape(-1, x_act.shape[-1]),
            method, n, m, transposable, solver, alps_cfg,
        )
        log(f"[prune] layer {l} {name}: done")
        return wp.astype(w.dtype), mask

    for l in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[l], blocks)
        h1 = rms_norm(x, lp["ln1"])
        ap = dict(lp["attn"])
        for nm_ in ("wq", "wk", "wv"):
            ap[nm_], mk = pr(ap[nm_], h1, nm_, l)
            new_attn[nm_].append(ap[nm_])
            masks_attn[nm_].append(mk)
        cap = {}
        attn_out, _ = attention(ap, h1, cfg, positions, capture=cap)
        ap["wo"], mk = pr(ap["wo"], cap["pre_out"], "wo", l)
        masks_attn["wo"].append(mk)
        new_attn["wo"].append(ap["wo"])
        attn_out = cap["pre_out"] @ ap["wo"].astype(h1.dtype)
        x = x + attn_out

        h2 = rms_norm(x, lp["ln2"])
        mp = dict(lp["mlp"])
        for nm_ in ("gate", "up"):
            mp[nm_], mk = pr(mp[nm_], h2, nm_, l)
            new_mlp[nm_].append(mp[nm_])
            masks_mlp[nm_].append(mk)
        hidden = jax.nn.silu(h2 @ mp["gate"].astype(h2.dtype)) * (
            h2 @ mp["up"].astype(h2.dtype)
        )
        mp["down"], mk = pr(mp["down"], hidden, "down", l)
        masks_mlp["down"].append(mk)
        new_mlp["down"].append(mp["down"])
        x = x + hidden @ mp["down"].astype(h2.dtype)

    new_blocks = dict(blocks)
    new_blocks["attn"] = {k: jnp.stack(v) for k, v in new_attn.items()}
    new_blocks["mlp"] = {k: jnp.stack(v) for k, v in new_mlp.items()}
    masks = {
        "attn": {k: jnp.stack(v) for k, v in masks_attn.items()},
        "mlp": {k: jnp.stack(v) for k, v in masks_mlp.items()},
    }
    return dict(params, blocks=new_blocks), masks
