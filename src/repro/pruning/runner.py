"""Sequential layer-wise LM pruning (SparseGPT-style propagation).

Walks a dense-family transformer layer by layer: capture each projection's
*true* input activations (with earlier layers already pruned), prune it with
the chosen method + TSENOR transposable masks, and propagate the pruned
activations forward — exactly how the paper applies Wanda/SparseGPT/ALPS to
LLaMA.  Covers the attention (wq/wk/wv/wo) and MLP (gate/up/down) projections
of the "dense"/"vlm"/"audio" families; MoE expert matrices and SSM in/out
projections use the same per-matrix APIs directly (see examples/prune_llm.py).

Pruning methods come from the :mod:`repro.pruning.methods` registry — any
registered :class:`~repro.pruning.methods.PruneMethod` works, built-in or
third-party; there is no per-method dispatch here.  Mask generation routes
through :class:`repro.service.MaskService`:

  * methods exposing an ``importance`` hook (Wanda/magnitude) have the
    masks of projections sharing an input (wq/wk/wv; gate/up) submitted
    together and solved as one bucketed batch (the sequential calibration
    dependency forbids batching across layers — each layer's activations
    need the previous layers already pruned);
  * methods exposing a ``solve_plan`` hook (SparseGPT/ALPS) have the plans
    of all projections in a group driven in lockstep
    (:func:`repro.pruning.plan.drive_solve_plans`): every sweep's solve
    requests across the group go through ONE service flush, so even
    sequential methods get mega-batched dispatch, the fused backend,
    bit-packed transport and content-cache hits;
  * with ``journal_dir`` set, every pruned tensor is persisted to a
    content-addressed store and journaled, so a killed run resumes
    mid-model: completed tensors restore from disk (the cheap forward
    recompute reproduces identical activations, hence identical content
    keys) and only the remainder is solved.
"""
from __future__ import annotations

import hashlib
import logging
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import ContentStore
from repro.core.solver import SolverConfig
from repro.models.attention import attention
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, embed_tokens
from repro.patterns import PatternSpec, pattern_from_args
from repro.pruning.alps import AlpsConfig
from repro.pruning.methods import (
    PruneContext,
    get_method,
    method_importance,
    method_solve_plan,
)
from repro.pruning.plan import drive_solve_plans
from repro.service.cache import solver_fingerprint
from repro.service.engine import MaskService
from repro.service.journal import Journal

_logger = logging.getLogger(__name__)


def _digest(arr) -> bytes:
    a = np.ascontiguousarray(np.asarray(arr, np.float32))
    h = hashlib.sha256()
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.digest()


def _tensor_key(w, x_digest, method_name, spec: PatternSpec, solver, alps_cfg) -> str:
    """Content hash identifying one layer-wise pruning problem end to end:
    weights, calibration activations (pre-digested — shared by the group),
    method, and every knob of the solver config that actually produces the
    mask."""
    h = hashlib.sha256()
    h.update(b"tsenor-prune-v1|")
    h.update(
        f"method={method_name}|n={spec.n}|m={spec.m}|t={spec.transposable}|"
        f"{solver_fingerprint(solver)}|".encode()
    )
    if method_name == "alps":
        h.update(
            f"alps:iters={alps_cfg.iters};rho0={alps_cfg.rho0_rel!r};"
            f"growth={alps_cfg.rho_growth!r};{solver_fingerprint(alps_cfg.solver)}|".encode()
        )
    h.update(_digest(w))
    h.update(x_digest)
    return h.hexdigest()


def prune_transformer(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
    method="alps",
    pattern=None,
    *,
    n: Optional[int] = None,
    m: Optional[int] = None,
    transposable: Optional[bool] = None,
    solver: SolverConfig = SolverConfig(iters=150),
    alps_cfg: Optional[AlpsConfig] = None,
    log=lambda s: None,
    service: Optional[MaskService] = None,
    journal_dir: Optional[str] = None,
    emit: str = "dense",
):
    """Returns (pruned params, {proj_name: stacked masks}).

    ``tokens``/``embeds``: calibration batch (B, S)/(B, S, d).
    ``method``: registered method name (or a PruneMethod object).
    ``pattern``: :class:`PatternSpec` or canonical string like ``"t2:4"``;
    the deprecated ``n=``/``m=``/``transposable=`` keywords still work.
    ``service``: MaskService for transposable mask solves (a per-call
    in-memory one is created by default).  A
    :class:`repro.service.net.MaskClient` connected to a ``serve-masks``
    server is a drop-in here — masks then solve remotely, bit-identical,
    and two jobs pruning the same checkpoint share the server's cache.
    ``journal_dir``: persist every pruned (W, mask) pair content-addressed
    under this directory and journal completions; re-running with the same
    inputs resumes after an interruption without re-solving finished tensors.
    ``emit``: ``"dense"`` returns masked dense weights (historical);
    ``"compressed"`` returns a SparseParams tree — each pruned projection a
    scan-stacked :class:`~repro.sparsity.params.NMCompressed` buffer, ready
    to hand straight to ``build_train_step(mask_mode="compressed")`` /
    ``ServeEngine`` with no dense masked weights in the returned tree.
    """
    assert cfg.family in ("dense", "vlm", "audio"), cfg.family
    if emit not in ("dense", "compressed"):
        raise ValueError(f"emit must be 'dense' or 'compressed', got {emit!r}")
    spec = pattern_from_args(pattern, m, transposable, n=n,
                             caller="prune_transformer")
    if emit == "compressed" and not spec.transposable:
        raise ValueError(
            "emit='compressed' needs a transposable pattern: the compressed "
            "buffer must serve both W and W^T"
        )
    if emit == "compressed":
        # Fail BEFORE solving, not after a model-scale prune: the dense
        # path pads non-multiple dims, but the (values, indices) layout
        # has no partial groups.
        blk = params["blocks"]
        for grp, names in (("attn", ("wq", "wk", "wv", "wo")),
                           ("mlp", ("gate", "up", "down"))):
            for name in names:
                k_dim = blk[grp][name].shape[-2]
                if k_dim % spec.m != 0:
                    raise ValueError(
                        f"emit='compressed': {grp}/{name} reduction dim "
                        f"{k_dim} is not a multiple of M={spec.m}; "
                        "compressed storage cannot crop partial groups "
                        "(use emit='dense' or a divisible pattern)"
                    )
    meth = get_method(method)
    importance = method_importance(meth)
    alps_cfg = alps_cfg or AlpsConfig(iters=50, solver=solver)
    svc = service if service is not None else MaskService(solver, directory=journal_dir)
    journal = store = None
    if journal_dir is not None:
        store = ContentStore(os.path.join(journal_dir, "pruned"))
        journal = Journal(os.path.join(journal_dir, "prune_journal.jsonl"))
    dtype = jnp.float32
    if embeds is None:
        x = embed_tokens(params["embed"], tokens, dtype)
    else:
        x = embeds.astype(dtype)
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    blocks = params["blocks"]
    new_attn = {k: [] for k in ("wq", "wk", "wv", "wo")}
    new_mlp = {k: [] for k in ("gate", "up", "down")}
    masks_attn = {k: [] for k in ("wq", "wk", "wv", "wo")}
    masks_mlp = {k: [] for k in ("gate", "up", "down")}

    # Importance-scored methods' masks depend only on (W, X): they ride the
    # one-shot batched service path.  Sequential methods (SparseGPT/ALPS)
    # expose solve_plan generators instead and are driven in lockstep, so
    # their per-sweep solves also dispatch through the service.
    plan_fn = method_solve_plan(meth)
    group_batched = spec.transposable and importance is not None
    plan_routed = spec.transposable and not group_batched and plan_fn is not None

    def restore(tname, key):
        if journal is None or key is None:
            return None
        rec = journal.lookup(tname)
        if rec and rec.get("key") == key:
            # get_or_none: a concurrent process (shared cache volume) may
            # evict the entry mid-read; that is a re-prune, not a crash.
            data = store.get_or_none(key)
            if data is not None:
                return jnp.asarray(data["w"]), jnp.asarray(data["mask"])
        return None

    def persist(tname, key, wp, mask):
        if journal is not None:
            store.put(key, w=np.asarray(wp), mask=np.asarray(mask))
            journal.record(tname, key)

    def pr_group(ws: dict, x_act, l: int, grp: str):
        """Prune projections sharing input ``x_act``; returns name -> (wp, mask).

        For importance-scored methods every cache-miss in the group is
        submitted to the service first and solved in ONE bucketed flush.
        """
        x_flat = x_act.reshape(-1, x_act.shape[-1])
        # Gram-based methods pull ctx.gram() lazily (cached per group), so a
        # fully-journaled resume never pays the O(tokens * d^2) matmul.
        ctx = PruneContext(x=x_flat, solver=solver, alps=alps_cfg, service=svc)
        results, todo = {}, {}
        # Hashing is journal-only work; the batched/plan-routed methods'
        # masks come from the service, so the key must fingerprint ITS
        # config, not ``solver``.
        x_digest = _digest(x_flat) if journal is not None else b""
        mask_cfg = svc.config if (group_batched or plan_routed) else solver
        for name, w in ws.items():
            tname = f"layer{l:03d}/{grp}/{name}"
            w32 = w.astype(jnp.float32)
            key = None
            if journal is not None:
                key = _tensor_key(w32, x_digest, meth.name, spec, mask_cfg, alps_cfg)
            prior = restore(tname, key)
            if prior is not None:
                results[name] = prior
                log(f"[prune] layer {l} {name}: restored from journal")
            else:
                todo[name] = (tname, key, w32)
        if group_batched and todo:
            handles = dict(zip(todo, svc.submit_many(
                ((tname, importance(w32, ctx))
                 for tname, _key, w32 in todo.values()), spec,
            )))
            svc.flush()  # one bucketed solve for the whole group
            for name, (tname, key, w32) in todo.items():
                mask = handles[name].result()
                wp = jnp.where(mask, w32, 0)
                persist(tname, key, wp, mask)
                results[name] = (wp, mask)
                log(f"[prune] layer {l} {name}: done")
        elif plan_routed and todo:
            # Drive every projection's solve plan in lockstep: the group's
            # step-k requests are solved by ONE flush before any step k+1.
            plans = {
                tname: plan_fn(w32, None, spec, ctx)
                for tname, _key, w32 in todo.values()
            }
            solved = drive_solve_plans(plans, svc, spec)
            for name, (tname, key, _w32) in todo.items():
                wp, mask = solved[tname]
                persist(tname, key, wp, mask)
                results[name] = (wp, mask)
                log(f"[prune] layer {l} {name}: done")
        else:
            for name, (tname, key, w32) in todo.items():
                wp, mask = meth(w32, None, spec, ctx)
                persist(tname, key, wp, mask)
                results[name] = (wp, mask)
                log(f"[prune] layer {l} {name}: done")
        return {
            name: (wp.astype(ws[name].dtype), mask)
            for name, (wp, mask) in results.items()
        }

    for l in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[l], blocks)
        h1 = rms_norm(x, lp["ln1"])
        ap = dict(lp["attn"])
        qkv = pr_group({k: ap[k] for k in ("wq", "wk", "wv")}, h1, l, "attn")
        for nm_ in ("wq", "wk", "wv"):
            ap[nm_], mk = qkv[nm_]
            new_attn[nm_].append(ap[nm_])
            masks_attn[nm_].append(mk)
        cap = {}
        attn_out, _ = attention(ap, h1, cfg, positions, capture=cap)
        (ap["wo"], mk), = pr_group({"wo": ap["wo"]}, cap["pre_out"], l, "attn").values()
        masks_attn["wo"].append(mk)
        new_attn["wo"].append(ap["wo"])
        attn_out = cap["pre_out"] @ ap["wo"].astype(h1.dtype)
        x = x + attn_out

        h2 = rms_norm(x, lp["ln2"])
        mp = dict(lp["mlp"])
        gu = pr_group({k: mp[k] for k in ("gate", "up")}, h2, l, "mlp")
        for nm_ in ("gate", "up"):
            mp[nm_], mk = gu[nm_]
            new_mlp[nm_].append(mp[nm_])
            masks_mlp[nm_].append(mk)
        hidden = jax.nn.silu(h2 @ mp["gate"].astype(h2.dtype)) * (
            h2 @ mp["up"].astype(h2.dtype)
        )
        (mp["down"], mk), = pr_group({"down": mp["down"]}, hidden, l, "mlp").values()
        masks_mlp["down"].append(mk)
        new_mlp["down"].append(mp["down"])
        x = x + hidden @ mp["down"].astype(h2.dtype)

    # The one-per-run padding/waste report (ServiceStats.summary embeds
    # StreamStats.summary; per-stream figures stay at DEBUG in solve_stream).
    _logger.info("mask service: %s", svc.stats.summary())
    log(f"[prune] mask service: {svc.stats.summary()}")

    new_blocks = dict(blocks)
    masks = {
        "attn": {k: jnp.stack(v) for k, v in masks_attn.items()},
        "mlp": {k: jnp.stack(v) for k, v in masks_mlp.items()},
    }
    if emit == "compressed":
        # Hand back SparseParams: each projection's per-layer (wp, mask)
        # pairs collapse into one scan-stacked compressed buffer — the
        # returned tree holds no dense masked weights at all.
        from repro.sparsity.params import compress_leaf

        new_blocks["attn"] = {
            k: compress_leaf(jnp.stack(v), masks["attn"][k], spec)
            for k, v in new_attn.items()
        }
        new_blocks["mlp"] = {
            k: compress_leaf(jnp.stack(v), masks["mlp"][k], spec)
            for k, v in new_mlp.items()
        }
    else:
        new_blocks["attn"] = {k: jnp.stack(v) for k, v in new_attn.items()}
        new_blocks["mlp"] = {k: jnp.stack(v) for k, v in new_mlp.items()}
    return dict(params, blocks=new_blocks), masks
