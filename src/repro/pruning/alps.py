"""ALPS [Meng et al. 2024] + TSENOR: ADMM layer-wise pruning with
transposable N:M constraints (paper Sec. 4, Prop. 1, Thm. 1).

Updates (Eq. 30), with eigendecomposition H = QΛQᵀ so every W-update under a
changing penalty ρ_t is two dense matmuls:

    W   = Q diag(1/(Λ+ρ)) Qᵀ (H·What − V + ρD)
    S   = TSENOR mask of (W + V/ρ)²          (problem (10))
    D   = (W + V/ρ) ⊙ S
    V  += ρ (W − D)

The Assumption-1 safeguard keeps the previous mask whenever the new one would
*decrease* the D-subproblem objective — this is what makes Theorem 1
(convergence of W(t), D(t) to a common limit) hold with an inexact mask
solver.  ρ_t grows geometrically so Σ 1/ρ_t < ∞.

Like SparseGPT (see ``repro.pruning.sparsegpt``), three solve routes share
the same per-iteration compute chain (``solve_via=``): ``"service"``
(default) drives the ADMM loop from the host with the W/D/V updates jitted
(:func:`_alps_w_step` / :func:`_alps_apply_mask`) and every projection-step
mask solve routed through a batched :class:`~repro.service.MaskService` —
:func:`alps_solve_plan` exposes the same structure to the lockstep driver in
:mod:`repro.pruning.plan`; ``"callback"`` keeps ONE jitted ``lax.scan`` and
escapes to the service via ``io_callback``; ``"inline"`` is the historical
single-jit ``fori_loop`` with the TSENOR solve inlined, kept as the
bit-identity reference.  All three match bit for bit at
``SolverConfig.tol = 0`` (``tests/test_pruning_service.py``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import blocks as blk
from repro.core.dykstra import dykstra_log
from repro.core.rounding import round_blocks
from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec, pattern_from_args


@dataclasses.dataclass(frozen=True)
class AlpsConfig:
    """ADMM hyper-parameters for :func:`alps_prune`.

    ``rho0_rel`` scales the initial penalty by ``mean(diag H)``;
    ``rho_growth`` is the geometric growth factor (Σ 1/ρ_t < ∞ ⇒ Thm. 1
    applies); ``solver`` configures the per-iteration TSENOR mask solves
    (on the ``"service"`` route the service's own :class:`SolverConfig`
    governs them instead — pass the same config to both, as
    ``prune_transformer`` does).
    """

    iters: int = 80
    rho0_rel: float = 0.03       # rho0 = rho0_rel * mean(diag H)
    rho_growth: float = 1.05
    solver: SolverConfig = SolverConfig(iters=150)


def _mask_for(scores, n, m, transposable, iters, ls_steps, tau_scale):
    if transposable:
        blocks = blk.to_blocks(scores, m)
        scale = jnp.max(blocks, axis=(1, 2), keepdims=True)
        tau = tau_scale / jnp.maximum(scale, 1e-30)
        s_approx = dykstra_log(blocks, n, iters, tau=tau)
        mask = round_blocks(s_approx, blocks, n, ls_steps)
        return blk.from_blocks(mask, scores.shape)
    r, c = scores.shape
    g = scores.reshape(r // m, m, c)
    rank = jnp.argsort(jnp.argsort(-g, axis=1), axis=1)
    return (rank < n).reshape(r, c)


@functools.partial(jax.jit, static_argnames=("n", "m"))
def _topn_mask(scores, n, m):
    """Standard (non-transposable) N:M mask along the input groups."""
    r, c = scores.shape
    g = scores.reshape(r // m, m, c)
    rank = jnp.argsort(jnp.argsort(-g, axis=1), axis=1)
    return (rank < n).reshape(r, c)


@jax.jit
def _alps_prep(w_hat, h):
    """One-time ADMM setup: eigendecomposition and the fixed H·What term."""
    evals, q = jnp.linalg.eigh(h)
    return evals, q, h @ w_hat


@jax.jit
def _alps_obj(w_hat, h, d):
    """Layer-wise objective 0.5 ||X(D - What)||² expressed through H."""
    diff = d - w_hat
    return 0.5 * jnp.sum(diff * (h @ diff))


@jax.jit
def _alps_w_step(q, evals, hw, v, d, rho):
    """W-update + projection target (the solve request of one iteration)."""
    w = q @ ((q.T @ (hw - v + rho * d)) / (evals + rho)[:, None])
    target = w + v / rho
    return w, target, target**2


@functools.partial(jax.jit, static_argnames=("rho_growth",))
def _alps_apply_mask(
    w_hat, h, mask, scores, new_mask, target, w, v, rho, rho_growth,
    best_d, best_mask, best_obj,
):
    """Post-solve half of one ADMM iteration: Assumption-1 safeguard, D/V
    updates, penalty growth and best-iterate tracking."""
    keep_new = jnp.sum(scores * new_mask) >= jnp.sum(scores * mask)
    mask = jnp.where(keep_new, new_mask, mask)
    d = jnp.where(mask, target, 0.0)
    v = v + rho * (w - d)
    rho = rho * rho_growth
    diff = d - w_hat
    obj = 0.5 * jnp.sum(diff * (h @ diff))
    better = obj < best_obj
    best_d = jnp.where(better, d, best_d)
    best_mask = jnp.where(better, mask, best_mask)
    best_obj = jnp.where(better, obj, best_obj)
    return mask, d, v, rho, best_d, best_mask, best_obj


def alps_solve_plan(
    w_hat: jnp.ndarray,
    h: jnp.ndarray,
    pattern,
    config: AlpsConfig = AlpsConfig(),
):
    """The ``solve_plan`` generator for ALPS (see ``repro.pruning.plan``).

    Yields the projection-step score matrix of every ADMM iteration (plus
    the |What| init solve) and expects the solved boolean mask back via
    ``send``; returns ``(best ADMM D iterate, mask)``.  Everything between
    yields — W-update, safeguard, D/V updates, best tracking — is jitted.

    For non-transposable patterns no request is yielded; the cheap top-N
    mask replaces every solve and the generator returns after zero sweeps
    of service traffic.
    """
    spec = PatternSpec.coerce(pattern)
    w_hat = jnp.asarray(w_hat, jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    rho0 = float(config.rho0_rel) * float(jnp.mean(jnp.diag(h)))
    evals, q, hw = _alps_prep(w_hat, h)

    def solve(scores):
        if spec.transposable:
            mask = yield scores
            return jnp.asarray(mask, bool)
        return _topn_mask(scores, spec.n, spec.m)

    mask = yield from solve(jnp.abs(w_hat))
    d = jnp.where(mask, w_hat, 0.0)
    v = jnp.zeros_like(w_hat)
    rho = jnp.float32(rho0)
    best_d, best_mask, best_obj = d, mask, _alps_obj(w_hat, h, d)
    for _ in range(config.iters):
        w, target, scores = _alps_w_step(q, evals, hw, v, d, rho)
        new_mask = yield from solve(scores)
        mask, d, v, rho, best_d, best_mask, best_obj = _alps_apply_mask(
            w_hat, h, mask, scores, new_mask, target, w, v, rho,
            float(config.rho_growth), best_d, best_mask, best_obj,
        )
    return best_d, best_mask


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "m", "transposable", "iters", "rho_growth",
        "solver_iters", "ls_steps", "tau_scale",
    ),
)
def _alps_jit(
    w_hat, h, n, m, transposable, iters, rho0, rho_growth,
    solver_iters, ls_steps, tau_scale,
):
    evals, q = jnp.linalg.eigh(h)
    hw = h @ w_hat

    def layer_obj(d):
        diff = d - w_hat
        return 0.5 * jnp.sum(diff * (h @ diff))

    mask0 = _mask_for(
        jnp.abs(w_hat), n, m, transposable, solver_iters, ls_steps, tau_scale
    )
    d0 = jnp.where(mask0, w_hat, 0.0)
    v0 = jnp.zeros_like(w_hat)

    def body(t, carry):
        mask, d, v, rho, best_d, best_mask, best_obj = carry
        w = q @ ((q.T @ (hw - v + rho * d)) / (evals + rho)[:, None])
        target = w + v / rho
        scores = target**2
        new_mask = _mask_for(
            scores, n, m, transposable, solver_iters, ls_steps, tau_scale
        )
        # Assumption-1 safeguard (never decrease the D-subproblem objective).
        keep_new = jnp.sum(scores * new_mask) >= jnp.sum(scores * mask)
        mask = jnp.where(keep_new, new_mask, mask)
        d = jnp.where(mask, target, 0.0)
        v = v + rho * (w - d)
        rho = rho * rho_growth
        obj = layer_obj(d)
        better = obj < best_obj
        best_d = jnp.where(better, d, best_d)
        best_mask = jnp.where(better, mask, best_mask)
        best_obj = jnp.where(better, obj, best_obj)
        return mask, d, v, rho, best_d, best_mask, best_obj

    init = (mask0, d0, v0, jnp.float32(rho0), d0, mask0, layer_obj(d0))
    out = jax.lax.fori_loop(0, iters, body, init)
    _, _, _, _, best_d, best_mask, _ = out
    return best_d, best_mask


def _callback_admm(service, spec: PatternSpec, iters: int, rho_growth: float):
    """One jitted ADMM loop whose projection solves escape to ``service``
    through ``io_callback`` — the ``solve_via="callback"`` program.

    Uses ``lax.scan`` over iterations (same carry chain as the inline
    ``fori_loop``) because ordered host callbacks thread a token that scan
    handles natively.  The compiled program is cached on the service
    instance (see ``sparsegpt._service_program_cache``), so pass a
    persistent service for cross-call reuse.
    """
    from repro.pruning.sparsegpt import _service_program_cache

    cache = _service_program_cache(service)
    key = ("alps", spec, iters, rho_growth)
    if key in cache:
        return cache[key]

    from jax.experimental import io_callback

    def host_solve(scores):
        return jax.device_get(service.solve(scores, spec)).astype(bool)

    @jax.jit
    def run(w_hat, h, rho0):
        evals, q = jnp.linalg.eigh(h)
        hw = h @ w_hat
        shape = jax.ShapeDtypeStruct(w_hat.shape, bool)

        def layer_obj(d):
            diff = d - w_hat
            return 0.5 * jnp.sum(diff * (h @ diff))

        mask0 = io_callback(host_solve, shape, jnp.abs(w_hat), ordered=True)
        d0 = jnp.where(mask0, w_hat, 0.0)
        v0 = jnp.zeros_like(w_hat)

        def body(carry, _):
            mask, d, v, rho, best_d, best_mask, best_obj = carry
            w = q @ ((q.T @ (hw - v + rho * d)) / (evals + rho)[:, None])
            target = w + v / rho
            scores = target**2
            new_mask = io_callback(host_solve, shape, scores, ordered=True)
            keep_new = jnp.sum(scores * new_mask) >= jnp.sum(scores * mask)
            mask = jnp.where(keep_new, new_mask, mask)
            d = jnp.where(mask, target, 0.0)
            v = v + rho * (w - d)
            rho = rho * rho_growth
            obj = layer_obj(d)
            better = obj < best_obj
            best_d = jnp.where(better, d, best_d)
            best_mask = jnp.where(better, mask, best_mask)
            best_obj = jnp.where(better, obj, best_obj)
            return (mask, d, v, rho, best_d, best_mask, best_obj), None

        init = (mask0, d0, v0, rho0, d0, mask0, layer_obj(d0))
        (_, _, _, _, best_d, best_mask, _), _ = jax.lax.scan(
            body, init, None, length=iters
        )
        return best_d, best_mask

    cache[key] = run
    return run


def alps_prune(
    w_hat: jnp.ndarray,
    h: jnp.ndarray,
    pattern=None,
    m=None,
    transposable=None,
    config: AlpsConfig = AlpsConfig(),
    *,
    n=None,
    solve_via: str = "service",
    service=None,
):
    """Returns (pruned W = best ADMM D iterate, mask).

    Args:
      w_hat: (in, out) dense weights; ``h``: damped Gram (in, in).
      pattern: :class:`~repro.patterns.PatternSpec` (or canonical string);
        the deprecated ``(n, m[, transposable])`` triple still works.
      config: :class:`AlpsConfig` ADMM hyper-parameters.
      solve_via: ``"service"`` (default) routes every ADMM projection solve
        through a batched :class:`~repro.service.MaskService`;
        ``"callback"`` keeps one jitted loop and escapes via
        ``io_callback``; ``"inline"`` is the historical single-jit path.
        All three are bit-identical at ``tol = 0``.
      service: the :class:`~repro.service.MaskService` to route through;
        a per-call in-memory one built from ``config.solver`` by default.

    See ``docs/architecture.md`` ("which route when") for guidance.
    """
    spec = pattern_from_args(pattern, m, transposable, n=n, caller="alps_prune")
    w_hat = jnp.asarray(w_hat, jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    rho0 = float(config.rho0_rel) * float(jnp.mean(jnp.diag(h)))
    if solve_via not in ("service", "callback", "inline"):
        raise ValueError(
            f"alps_prune: unknown solve_via {solve_via!r} "
            "(expected 'service', 'callback' or 'inline')"
        )
    if solve_via == "inline" or not spec.transposable:
        return _alps_jit(
            w_hat,
            h,
            spec.n,
            spec.m,
            spec.transposable,
            config.iters,
            rho0,
            config.rho_growth,
            config.solver.iters,
            config.solver.ls_steps,
            config.solver.tau_scale,
        )
    if service is None:
        from repro.service.engine import MaskService

        service = MaskService(config.solver)
    if solve_via == "callback":
        return _callback_admm(
            service, spec, config.iters, float(config.rho_growth)
        )(w_hat, h, jnp.float32(rho0))
    from repro.pruning.plan import drive_solve_plans

    plan = alps_solve_plan(w_hat, h, spec, config)
    return drive_solve_plans({"alps": plan}, service, spec)["alps"]
