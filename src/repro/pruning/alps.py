"""ALPS [Meng et al. 2024] + TSENOR: ADMM layer-wise pruning with
transposable N:M constraints (paper Sec. 4, Prop. 1, Thm. 1).

Updates (Eq. 30), with eigendecomposition H = QΛQᵀ so every W-update under a
changing penalty ρ_t is two dense matmuls:

    W   = Q diag(1/(Λ+ρ)) Qᵀ (H·What − V + ρD)
    S   = TSENOR mask of (W + V/ρ)²          (problem (10))
    D   = (W + V/ρ) ⊙ S
    V  += ρ (W − D)

The Assumption-1 safeguard keeps the previous mask whenever the new one would
*decrease* the D-subproblem objective — this is what makes Theorem 1
(convergence of W(t), D(t) to a common limit) hold with an inexact mask
solver.  ρ_t grows geometrically so Σ 1/ρ_t < ∞.  The whole ADMM loop is one
jitted ``lax.fori_loop`` with the TSENOR solve inlined.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import blocks as blk
from repro.core.dykstra import dykstra_log
from repro.core.rounding import round_blocks
from repro.core.solver import SolverConfig
from repro.patterns import pattern_from_args


@dataclasses.dataclass(frozen=True)
class AlpsConfig:
    iters: int = 80
    rho0_rel: float = 0.03       # rho0 = rho0_rel * mean(diag H)
    rho_growth: float = 1.05
    solver: SolverConfig = SolverConfig(iters=150)


def _mask_for(scores, n, m, transposable, iters, ls_steps, tau_scale):
    if transposable:
        blocks = blk.to_blocks(scores, m)
        scale = jnp.max(blocks, axis=(1, 2), keepdims=True)
        tau = tau_scale / jnp.maximum(scale, 1e-30)
        s_approx = dykstra_log(blocks, n, iters, tau=tau)
        mask = round_blocks(s_approx, blocks, n, ls_steps)
        return blk.from_blocks(mask, scores.shape)
    r, c = scores.shape
    g = scores.reshape(r // m, m, c)
    rank = jnp.argsort(jnp.argsort(-g, axis=1), axis=1)
    return (rank < n).reshape(r, c)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "m", "transposable", "iters", "rho_growth",
        "solver_iters", "ls_steps", "tau_scale",
    ),
)
def _alps_jit(
    w_hat, h, n, m, transposable, iters, rho0, rho_growth,
    solver_iters, ls_steps, tau_scale,
):
    evals, q = jnp.linalg.eigh(h)
    hw = h @ w_hat

    def layer_obj(d):
        diff = d - w_hat
        return 0.5 * jnp.sum(diff * (h @ diff))

    mask0 = _mask_for(
        jnp.abs(w_hat), n, m, transposable, solver_iters, ls_steps, tau_scale
    )
    d0 = jnp.where(mask0, w_hat, 0.0)
    v0 = jnp.zeros_like(w_hat)

    def body(t, carry):
        mask, d, v, rho, best_d, best_mask, best_obj = carry
        w = q @ ((q.T @ (hw - v + rho * d)) / (evals + rho)[:, None])
        target = w + v / rho
        scores = target**2
        new_mask = _mask_for(
            scores, n, m, transposable, solver_iters, ls_steps, tau_scale
        )
        # Assumption-1 safeguard (never decrease the D-subproblem objective).
        keep_new = jnp.sum(scores * new_mask) >= jnp.sum(scores * mask)
        mask = jnp.where(keep_new, new_mask, mask)
        d = jnp.where(mask, target, 0.0)
        v = v + rho * (w - d)
        rho = rho * rho_growth
        obj = layer_obj(d)
        better = obj < best_obj
        best_d = jnp.where(better, d, best_d)
        best_mask = jnp.where(better, mask, best_mask)
        best_obj = jnp.where(better, obj, best_obj)
        return mask, d, v, rho, best_d, best_mask, best_obj

    init = (mask0, d0, v0, jnp.float32(rho0), d0, mask0, layer_obj(d0))
    out = jax.lax.fori_loop(0, iters, body, init)
    _, _, _, _, best_d, best_mask, _ = out
    return best_d, best_mask


def alps_prune(
    w_hat: jnp.ndarray,
    h: jnp.ndarray,
    pattern=None,
    m=None,
    transposable=None,
    config: AlpsConfig = AlpsConfig(),
    *,
    n=None,
):
    """Returns (pruned W = best ADMM D iterate, mask).

    ``pattern``: :class:`~repro.patterns.PatternSpec` (or canonical string);
    the deprecated ``(n, m[, transposable])`` triple still works.
    """
    spec = pattern_from_args(pattern, m, transposable, n=n, caller="alps_prune")
    w_hat = jnp.asarray(w_hat, jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    rho0 = float(config.rho0_rel) * float(jnp.mean(jnp.diag(h)))
    return _alps_jit(
        w_hat,
        h,
        spec.n,
        spec.m,
        spec.transposable,
        config.iters,
        rho0,
        config.rho_growth,
        config.solver.iters,
        config.solver.ls_steps,
        config.solver.tau_scale,
    )
