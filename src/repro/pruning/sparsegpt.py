"""SparseGPT [Frantar & Alistarh 2023] with TSENOR transposable masks.

OBS-based one-shot pruning in the (in, out) convention: input dimensions are
processed in groups of M; each group's mask comes from TSENOR on the OBS
scores (W_ij / [H^-1]_ii)^2 (paper Sec. 4, "Integration with SparseGPT"), and
the remaining rows receive the standard OBS compensation update through the
upper Cholesky factor of H^{-1}.

The whole pass — group scan, TSENOR solve, within-group OBS recursion — is a
single jitted ``lax.scan``; the sequential row update exploits the upper-
triangular structure of the Cholesky factor (hinv[i, :i] = 0) to stay
shape-static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.core import blocks as blk
from repro.core.rounding import round_blocks
from repro.core.dykstra import dykstra_log
from repro.core.solver import SolverConfig
from repro.patterns import pattern_from_args


def upper_chol_of_inverse(h: jnp.ndarray) -> jnp.ndarray:
    """Upper Cholesky factor U of H⁻¹ (H⁻¹ = UᵀU), as in SparseGPT."""
    h = jnp.asarray(h, jnp.float32)
    eye = jnp.eye(h.shape[0], dtype=h.dtype)
    c = jsl.cholesky(h, lower=True)
    h_inv = jsl.cho_solve((c, True), eye)
    return jnp.linalg.cholesky(h_inv, upper=True)


def _tsenor_group_mask(scores, n, m, iters, ls_steps, tau_scale):
    """Transposable mask for an (M, out) score group via the block batch."""
    blocks = blk.to_blocks(scores, m)  # (out/m, m, m)
    scale = jnp.max(blocks, axis=(1, 2), keepdims=True)
    tau = tau_scale / jnp.maximum(scale, 1e-30)
    s_approx = dykstra_log(blocks, n, iters, tau=tau)
    mask = round_blocks(s_approx, blocks, n, ls_steps)
    return blk.from_blocks(mask, scores.shape)


@functools.partial(
    jax.jit, static_argnames=("n", "m", "transposable", "iters", "ls_steps", "tau_scale")
)
def _sparsegpt_jit(w_hat, h, n, m, transposable, iters, ls_steps, tau_scale):
    in_dim, out_dim = w_hat.shape
    hinv = upper_chol_of_inverse(h)
    diag = jnp.diag(hinv)
    row_gt = jnp.arange(in_dim)

    def group_step(w, s):
        dslice = jax.lax.dynamic_slice_in_dim(diag, s, m)
        wg = jax.lax.dynamic_slice_in_dim(w, s, m, axis=0)
        scores = (wg / dslice[:, None]) ** 2
        if transposable:
            gmask = _tsenor_group_mask(scores, n, m, iters, ls_steps, tau_scale)
        else:
            rank = jnp.argsort(jnp.argsort(-scores, axis=0), axis=0)
            gmask = rank < n

        def row_step(r, w):
            i = s + r
            row = jax.lax.dynamic_index_in_dim(w, i, 0, keepdims=False)
            q = jnp.where(gmask[r], row, 0.0)
            hrow = jax.lax.dynamic_index_in_dim(hinv, i, 0, keepdims=False)
            d = jax.lax.dynamic_index_in_dim(dslice, r, 0, keepdims=False)
            err = (row - q) / d
            w = jax.lax.dynamic_update_index_in_dim(w, q, i, 0)
            # hinv is upper-triangular, so masking j > i reproduces hinv[i, i+1:].
            return w - jnp.outer(jnp.where(row_gt > i, hrow, 0.0), err)

        w = jax.lax.fori_loop(0, m, row_step, w)
        return w, gmask

    starts = jnp.arange(0, in_dim, m)
    w, gmasks = jax.lax.scan(group_step, jnp.asarray(w_hat, jnp.float32), starts)
    mask = gmasks.reshape(in_dim, out_dim)
    return jnp.where(mask, w, 0.0), mask


def sparsegpt_prune(
    w_hat: jnp.ndarray,
    h: jnp.ndarray,
    pattern=None,
    m=None,
    transposable=None,
    config: SolverConfig = SolverConfig(iters=150),
    *,
    n=None,
):
    """Returns (pruned + OBS-updated W, mask).

    ``w_hat``: (in, out) dense weights; ``h``: damped Gram XᵀX + λI (in, in).
    ``pattern``: :class:`~repro.patterns.PatternSpec` (or canonical string);
    the deprecated ``(n, m[, transposable])`` triple still works.  The mask
    solve is inlined in the jitted group scan (dense Dykstra path; see
    ROADMAP for service routing).
    """
    spec = pattern_from_args(pattern, m, transposable, n=n, caller="sparsegpt_prune")
    in_dim, out_dim = w_hat.shape
    assert in_dim % spec.m == 0 and out_dim % spec.m == 0, (w_hat.shape, spec.m)
    return _sparsegpt_jit(
        jnp.asarray(w_hat, jnp.float32),
        jnp.asarray(h, jnp.float32),
        spec.n,
        spec.m,
        spec.transposable,
        config.iters,
        config.ls_steps,
        config.tau_scale,
    )
