"""SparseGPT [Frantar & Alistarh 2023] with TSENOR transposable masks.

OBS-based one-shot pruning in the (in, out) convention: input dimensions are
processed in groups of M; each group's mask comes from TSENOR on the OBS
scores (W_ij / [H^-1]_ii)^2 (paper Sec. 4, "Integration with SparseGPT"), and
the remaining rows receive the standard OBS compensation update through the
upper Cholesky factor of H^{-1}.

Three solve routes share the exact same per-group compute chain
(``solve_via=``):

* ``"service"`` (default) — the column-block sweep is restructured around
  the batched :class:`~repro.service.MaskService`: each group's OBS score
  slice is a solve *request*, the jit boundary sits at the per-group score
  computation and error-propagation update (:func:`_group_scores` /
  :func:`_obs_group_update`), and the host drives the sweep.  Through
  :func:`sparsegpt_solve_plan` the same structure batches across tensors
  (see :func:`repro.pruning.plan.drive_solve_plans`), so the fused backend,
  bit-packed transport and content cache of the service apply to SparseGPT.
* ``"callback"`` — for callers who must keep ONE jitted loop (e.g. an
  enclosing ``lax.scan`` over layers): the sweep stays a single jitted
  ``lax.scan`` and each group's solve escapes to the service through
  ``jax.experimental.io_callback``.
* ``"inline"`` — the historical fully-jitted path with the Dykstra solve
  inlined in the group scan; kept as the bit-identity reference.

All three produce bit-identical masks at ``SolverConfig.tol = 0``
(``tests/test_pruning_service.py``).  See ``docs/architecture.md`` for the
request lifecycle and ``docs/solver_math.md`` for the solver itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.core import blocks as blk
from repro.core.rounding import round_blocks
from repro.core.dykstra import dykstra_log
from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec, pattern_from_args


def upper_chol_of_inverse(h: jnp.ndarray) -> jnp.ndarray:
    """Upper Cholesky factor U of H⁻¹ (H⁻¹ = UᵀU), as in SparseGPT."""
    h = jnp.asarray(h, jnp.float32)
    eye = jnp.eye(h.shape[0], dtype=h.dtype)
    c = jsl.cholesky(h, lower=True)
    h_inv = jsl.cho_solve((c, True), eye)
    return jnp.linalg.cholesky(h_inv, upper=True)


def _tsenor_group_mask(scores, n, m, iters, ls_steps, tau_scale):
    """Transposable mask for an (M, out) score group via the block batch."""
    blocks = blk.to_blocks(scores, m)  # (out/m, m, m)
    scale = jnp.max(blocks, axis=(1, 2), keepdims=True)
    tau = tau_scale / jnp.maximum(scale, 1e-30)
    s_approx = dykstra_log(blocks, n, iters, tau=tau)
    mask = round_blocks(s_approx, blocks, n, ls_steps)
    return blk.from_blocks(mask, scores.shape)


@functools.partial(jax.jit, static_argnames=("n",))
def _topn_group_mask(scores, n):
    """Standard (non-transposable) per-group top-N mask along axis 0."""
    rank = jnp.argsort(jnp.argsort(-scores, axis=0), axis=0)
    return rank < n


@functools.partial(jax.jit, static_argnames=("m",))
def _group_scores(w, diag, s, m):
    """OBS scores (W_ij / [H^-1]_ii)^2 of the M-row group starting at ``s``."""
    dslice = jax.lax.dynamic_slice_in_dim(diag, s, m)
    wg = jax.lax.dynamic_slice_in_dim(w, s, m, axis=0)
    return (wg / dslice[:, None]) ** 2


@functools.partial(jax.jit, static_argnames=("m",))
def _obs_group_update(w, hinv, diag, gmask, s, m):
    """OBS error propagation for one masked group (jit boundary of the
    service-routed sweep): prune the group's rows to ``gmask`` and push each
    row's error into the not-yet-processed rows through the upper Cholesky
    factor of H^-1 — the same recursion the inline scan runs."""
    in_dim = w.shape[0]
    row_gt = jnp.arange(in_dim)
    dslice = jax.lax.dynamic_slice_in_dim(diag, s, m)

    def row_step(r, w):
        i = s + r
        row = jax.lax.dynamic_index_in_dim(w, i, 0, keepdims=False)
        q = jnp.where(gmask[r], row, 0.0)
        hrow = jax.lax.dynamic_index_in_dim(hinv, i, 0, keepdims=False)
        d = jax.lax.dynamic_index_in_dim(dslice, r, 0, keepdims=False)
        err = (row - q) / d
        w = jax.lax.dynamic_update_index_in_dim(w, q, i, 0)
        # hinv is upper-triangular, so masking j > i reproduces hinv[i, i+1:].
        return w - jnp.outer(jnp.where(row_gt > i, hrow, 0.0), err)

    return jax.lax.fori_loop(0, m, row_step, w)


def sparsegpt_solve_plan(
    w_hat: jnp.ndarray,
    h: jnp.ndarray,
    pattern,
):
    """The ``solve_plan`` generator for SparseGPT (see ``repro.pruning.plan``).

    Yields one (M, out) OBS score matrix per column-block sweep step and
    expects the solved boolean mask of the same shape back via ``send``;
    returns ``(pruned + OBS-updated W, mask)``.  All device work between
    yields is jitted.  There is deliberately no solver-config parameter:
    every knob that shapes the masks lives in the driving
    :class:`~repro.service.MaskService`'s :class:`SolverConfig` (the
    generator only produces scores and consumes masks).

    For non-transposable patterns no request is ever yielded — the cheap
    top-N group mask is computed inline and the generator returns after
    zero sweeps of service traffic.
    """
    spec = PatternSpec.coerce(pattern)
    w = jnp.asarray(w_hat, jnp.float32)
    in_dim, out_dim = w.shape
    assert in_dim % spec.m == 0 and out_dim % spec.m == 0, (w.shape, spec.m)
    hinv = upper_chol_of_inverse(jnp.asarray(h, jnp.float32))
    diag = jnp.diag(hinv)
    gmasks = []
    for s in range(0, in_dim, spec.m):
        scores = _group_scores(w, diag, s, spec.m)
        if spec.transposable:
            gmask = yield scores
            gmask = jnp.asarray(gmask, bool)
        else:
            gmask = _topn_group_mask(scores, spec.n)
        w = _obs_group_update(w, hinv, diag, gmask, s, spec.m)
        gmasks.append(gmask)
    mask = jnp.concatenate(gmasks, axis=0)
    return jnp.where(mask, w, 0.0), mask


@functools.partial(
    jax.jit, static_argnames=("n", "m", "transposable", "iters", "ls_steps", "tau_scale")
)
def _sparsegpt_jit(w_hat, h, n, m, transposable, iters, ls_steps, tau_scale):
    in_dim, out_dim = w_hat.shape
    hinv = upper_chol_of_inverse(h)
    diag = jnp.diag(hinv)
    row_gt = jnp.arange(in_dim)

    def group_step(w, s):
        dslice = jax.lax.dynamic_slice_in_dim(diag, s, m)
        wg = jax.lax.dynamic_slice_in_dim(w, s, m, axis=0)
        scores = (wg / dslice[:, None]) ** 2
        if transposable:
            gmask = _tsenor_group_mask(scores, n, m, iters, ls_steps, tau_scale)
        else:
            rank = jnp.argsort(jnp.argsort(-scores, axis=0), axis=0)
            gmask = rank < n

        def row_step(r, w):
            i = s + r
            row = jax.lax.dynamic_index_in_dim(w, i, 0, keepdims=False)
            q = jnp.where(gmask[r], row, 0.0)
            hrow = jax.lax.dynamic_index_in_dim(hinv, i, 0, keepdims=False)
            d = jax.lax.dynamic_index_in_dim(dslice, r, 0, keepdims=False)
            err = (row - q) / d
            w = jax.lax.dynamic_update_index_in_dim(w, q, i, 0)
            # hinv is upper-triangular, so masking j > i reproduces hinv[i, i+1:].
            return w - jnp.outer(jnp.where(row_gt > i, hrow, 0.0), err)

        w = jax.lax.fori_loop(0, m, row_step, w)
        return w, gmask

    starts = jnp.arange(0, in_dim, m)
    w, gmasks = jax.lax.scan(group_step, jnp.asarray(w_hat, jnp.float32), starts)
    mask = gmasks.reshape(in_dim, out_dim)
    return jnp.where(mask, w, 0.0), mask


def _service_program_cache(service) -> dict:
    """Compiled-program cache living ON the service instance, so program
    lifetime is tied to the service (no global registry pinning dead
    services and their mask caches) and repeat calls with the same service
    reuse the traced closure.  Callers who want cross-call program reuse on
    the callback route should therefore pass a persistent ``service``."""
    return service.__dict__.setdefault("_callback_programs", {})


def _callback_sweep(service, spec: PatternSpec, m: int):
    """One jitted SparseGPT sweep whose group solves escape to ``service``
    through ``io_callback`` — the ``solve_via="callback"`` program."""
    cache = _service_program_cache(service)
    key = ("sparsegpt", spec, m)
    if key in cache:
        return cache[key]

    from jax.experimental import io_callback

    def host_solve(scores):
        return jax.device_get(service.solve(scores, spec)).astype(bool)

    @jax.jit
    def run(w_hat, h):
        in_dim, out_dim = w_hat.shape
        hinv = upper_chol_of_inverse(h)
        diag = jnp.diag(hinv)
        row_gt = jnp.arange(in_dim)

        def group_step(w, s):
            dslice = jax.lax.dynamic_slice_in_dim(diag, s, m)
            wg = jax.lax.dynamic_slice_in_dim(w, s, m, axis=0)
            scores = (wg / dslice[:, None]) ** 2
            gmask = io_callback(
                host_solve,
                jax.ShapeDtypeStruct((m, out_dim), bool),
                scores,
                ordered=True,
            )

            def row_step(r, w):
                i = s + r
                row = jax.lax.dynamic_index_in_dim(w, i, 0, keepdims=False)
                q = jnp.where(gmask[r], row, 0.0)
                hrow = jax.lax.dynamic_index_in_dim(hinv, i, 0, keepdims=False)
                d = jax.lax.dynamic_index_in_dim(dslice, r, 0, keepdims=False)
                err = (row - q) / d
                w = jax.lax.dynamic_update_index_in_dim(w, q, i, 0)
                return w - jnp.outer(jnp.where(row_gt > i, hrow, 0.0), err)

            w = jax.lax.fori_loop(0, m, row_step, w)
            return w, gmask

        starts = jnp.arange(0, in_dim, m)
        w, gmasks = jax.lax.scan(
            group_step, jnp.asarray(w_hat, jnp.float32), starts
        )
        mask = gmasks.reshape(in_dim, out_dim)
        return jnp.where(mask, w, 0.0), mask

    cache[key] = run
    return run


def sparsegpt_prune(
    w_hat: jnp.ndarray,
    h: jnp.ndarray,
    pattern=None,
    m=None,
    transposable=None,
    config: SolverConfig = SolverConfig(iters=150),
    *,
    n=None,
    solve_via: str = "service",
    service=None,
):
    """Returns (pruned + OBS-updated W, mask).

    Args:
      w_hat: (in, out) dense weights.
      h: damped Gram ``XᵀX + λI`` of shape (in, in).
      pattern: :class:`~repro.patterns.PatternSpec` (or canonical string);
        the deprecated ``(n, m[, transposable])`` triple still works.
      config: TSENOR solver hyper-parameters for the mask solves.
      solve_via: ``"service"`` (default) routes every column-block solve
        through a batched :class:`~repro.service.MaskService` (cache + fused
        backend active); ``"callback"`` keeps one jitted ``lax.scan`` and
        escapes to the service via ``io_callback``; ``"inline"`` is the
        historical fully-jitted path.  All three are bit-identical at
        ``config.tol = 0``.
      service: the :class:`~repro.service.MaskService` to route through
        (``"service"``/``"callback"`` only); a per-call in-memory one built
        from ``config`` is used by default.

    See ``docs/architecture.md`` ("which route when") for guidance.
    """
    spec = pattern_from_args(pattern, m, transposable, n=n, caller="sparsegpt_prune")
    in_dim, out_dim = w_hat.shape
    assert in_dim % spec.m == 0 and out_dim % spec.m == 0, (w_hat.shape, spec.m)
    if solve_via not in ("service", "callback", "inline"):
        raise ValueError(
            f"sparsegpt_prune: unknown solve_via {solve_via!r} "
            "(expected 'service', 'callback' or 'inline')"
        )
    if solve_via == "inline" or not spec.transposable:
        return _sparsegpt_jit(
            jnp.asarray(w_hat, jnp.float32),
            jnp.asarray(h, jnp.float32),
            spec.n,
            spec.m,
            spec.transposable,
            config.iters,
            config.ls_steps,
            config.tau_scale,
        )
    if service is None:
        from repro.service.engine import MaskService

        service = MaskService(config)
    if solve_via == "callback":
        return _callback_sweep(service, spec, spec.m)(
            jnp.asarray(w_hat, jnp.float32), jnp.asarray(h, jnp.float32)
        )
    from repro.pruning.plan import drive_solve_plans

    plan = sparsegpt_solve_plan(w_hat, h, spec)
    return drive_solve_plans({"sparsegpt": plan}, service, spec)["sparsegpt"]
