"""Layer-wise pruning frameworks with TSENOR integration (paper Sec. 4).

Conventions: weights are (in, out) with ``y = x @ W``; calibration
activations are X with shape (tokens, in); the layer-wise objective is

    min_W  1/2 ||X (W - What)||_F^2 + lambda/2 ||W - What||_F^2
    s.t.   W in T (transposable N:M support)       (paper Eq. 7)

Every method returns ``(w_pruned, mask)`` and accepts a
:class:`repro.patterns.PatternSpec` (deprecated ``(n, m, transposable)``
triples still work).  Methods are registered in the
:mod:`repro.pruning.methods` registry; ``prune_transformer(method=...)`` is
a registry lookup.
"""
from repro.pruning.calib import gram_matrix, reconstruction_error
from repro.pruning.magnitude import magnitude_prune
from repro.pruning.wanda import wanda_prune
from repro.pruning.sparsegpt import sparsegpt_prune
from repro.pruning.alps import alps_prune
from repro.pruning.methods import (
    PruneContext,
    PruneMethod,
    available_methods,
    get_method,
    register_method,
)
from repro.pruning.runner import prune_transformer

__all__ = [
    "PruneContext",
    "PruneMethod",
    "available_methods",
    "get_method",
    "register_method",
    "gram_matrix",
    "magnitude_prune",
    "wanda_prune",
    "sparsegpt_prune",
    "alps_prune",
    "prune_transformer",
    "reconstruction_error",
]
