"""TSENOR reproduction — transposable N:M sparse masks at production scale.

``repro.api`` is the unified front door; its names are re-exported here
lazily (PEP 562), so ``import repro`` stays light and launcher modules can
keep setting XLA flags before any heavyweight (jax) import runs::

    from repro import MaskService, PatternSpec, SolverConfig
    mask = MaskService().solve(w, PatternSpec(2, 4))
"""

# Static mirror of repro.api.__all__ (tests assert they stay in sync);
# importing repro.api here would pull jax on ``import repro``.
_API_NAMES = (
    "PatternSpec",
    "pattern_from_args",
    "SolverBackend",
    "SolverConfig",
    "available_backends",
    "get_backend",
    "register_backend",
    "unregister_backend",
    "solve_mask",
    "solve_blocks",
    "nm_mask",
    "transposable_nm_mask",
    "is_transposable_nm",
    "objective",
    "relative_error",
    "BucketPolicy",
    "MaskCache",
    "MaskClient",
    "MaskHandle",
    "MaskServer",
    "MaskService",
    "ServiceStats",
    "StreamStats",
    "TenantConfig",
    "AlpsConfig",
    "PruneContext",
    "PruneMethod",
    "available_methods",
    "get_method",
    "register_method",
    "unregister_method",
    "prune_transformer",
    "apply_mask",
    "mask_sparsity",
    "sparsify_pytree",
    "NMCompressed",
    "compress_params",
    "decompress_params",
    "is_sparse_params",
    "masks_from_params",
    "sparse_param_bytes",
)

__all__ = list(_API_NAMES) + ["api", "compat"]


def __getattr__(name):
    if name in _API_NAMES or name == "api":
        import repro.api as api

        return api if name == "api" else getattr(api, name)
    if name == "compat":
        import repro.compat as compat

        return compat
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
