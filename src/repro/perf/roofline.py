"""Roofline cost model for the compressed hot-path kernels.

The nm_spmm kernel's fixed ``(bt, kt, ft) = (256, 256, 256)`` tiles are a
good fit for prefill GEMMs and a terrible one for decode GEMVs: at ``B = 8``
decode rows, a 256-row batch tile pads 8 real rows to 256 — 31 wasted rows
of MXU work and X traffic for every real one.  This module prices candidate
tiles *analytically* (bytes moved from HBM, MXU flops, VPU decompress ops,
per-grid-step overhead) against a per-device roofline
(:class:`DeviceProfile`), so the autotuner only has to *measure* the handful
of candidates the model says are worth measuring.

The model is deliberately simple — it ranks candidates, it does not predict
wall-clock.  Measurement (``repro.perf.autotune``) always has the final
word, and the measured winner is what lands in the tuning table.

VMEM feasibility is priced with the same accounting style as
:func:`repro.kernels.vmem.vmem_plan` (live buffer bytes vs a fraction of the
device's VMEM); the fused-solve candidate ladder is seeded directly from
``vmem_plan``'s tile choice.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.kernels.vmem import (
    _BUDGET_FRACTION,
    VPU_ALIGN,
    device_vmem_bytes,
    vmem_plan,
)

__all__ = [
    "DeviceProfile",
    "TileCost",
    "profile_for",
    "nm_spmm_cost",
    "nm_spmm_candidates",
    "nm_sparsify_cost",
    "nm_sparsify_candidates",
    "nm_spmm_cc_cost",
    "nm_spmm_cc_candidates",
    "nm_grad_cost",
    "fused_solve_candidates",
    "DEFAULT_TILES",
    "CC_DEFAULT_TILES",
]

# The historic fixed tiles — always a member of every candidate set, so the
# measured winner can never be slower than the default on the same run.
DEFAULT_TILES = (256, 256, 256)

# nm_spmm_cc's fallback: both operands compressed -> the live tile set is a
# fraction of nm_spmm's, so the default row tile is 4x taller (divides the
# W-operand revisit count; mirrored in kernels.nm_grad._resolve_cc_tiles).
CC_DEFAULT_TILES = (1024, 256, 256)


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Peak numbers one roofline is drawn against.

    Conservative spec-sheet figures; the model only *ranks* tiles, so what
    matters is the bandwidth/compute ratio, not absolute accuracy.
    """

    kind: str
    hbm_bytes_per_s: float
    mxu_flops_per_s: float   # f32-accumulated matmul throughput
    vpu_ops_per_s: float     # element-wise f32 throughput (decompress select)
    grid_step_overhead_s: float  # fixed cost per grid step (dispatch, DMA setup)


# Keyed by device_kind prefix (same convention as kernels.vmem).
_PROFILES = (
    DeviceProfile("TPU v6", 1.6e12, 4.6e14, 1.5e13, 1e-6),
    DeviceProfile("TPU v5p", 2.7e12, 2.3e14, 1.2e13, 1e-6),
    DeviceProfile("TPU v5", 8.0e11, 1.0e14, 8.0e12, 1e-6),
    DeviceProfile("TPU v4", 1.2e12, 1.4e14, 8.0e12, 1e-6),
)
# CPU / interpret-mode fallback.  Interpret mode pays per-element python/XLA
# cost, which behaves like a very low-flop device with high per-step
# overhead — the ratios below make the model prefer exactly what measurement
# confirms there: tiles that minimize *total padded work* and grid steps.
_FALLBACK = DeviceProfile("cpu", 4.0e10, 1.0e11, 5.0e10, 5e-5)


def profile_for(device=None) -> DeviceProfile:
    """Roofline profile for ``device`` (default: first local jax device)."""
    kind = getattr(device, "device_kind", None)
    if kind is None:
        import jax

        devices = jax.local_devices()
        kind = devices[0].device_kind if devices else "cpu"
    for prof in _PROFILES:
        if str(kind).startswith(prof.kind):
            return prof
    return dataclasses.replace(_FALLBACK, kind=str(kind))


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class TileCost:
    """Analytic cost of one ``(bt, kt, ft)`` candidate at a concrete shape."""

    bt: int
    kt: int
    ft: int
    grid_steps: int
    hbm_bytes: int       # X + compressed-W streamed + output written
    mxu_flops: int       # 2 * padded B*K*F
    vpu_ops: int         # one-hot decompress selects
    vmem_bytes: int      # live tile set (x, vals, idx, dense, out)

    @property
    def tiles(self) -> tuple[int, int, int]:
        return (self.bt, self.kt, self.ft)

    def arithmetic_intensity(self) -> float:
        return self.mxu_flops / max(self.hbm_bytes, 1)

    def model_seconds(self, profile: DeviceProfile) -> float:
        """Roofline time: bound by traffic OR compute, plus grid overhead."""
        t_mem = self.hbm_bytes / profile.hbm_bytes_per_s
        t_mxu = self.mxu_flops / profile.mxu_flops_per_s
        t_vpu = self.vpu_ops / profile.vpu_ops_per_s
        return max(t_mem, t_mxu + t_vpu) + self.grid_steps * profile.grid_step_overhead_s


def nm_spmm_cost(
    rows: int,
    k: int,
    f: int,
    n: int,
    m: int,
    bt: int,
    kt: int,
    ft: int,
    *,
    x_bytes: int = 4,
    val_bytes: int = 4,
    idx_bytes: int = 1,
) -> TileCost:
    """Cost of ``nm_spmm`` at shape ``(rows, K) x compressed(K/M, N, F)``.

    Mirrors the kernel's actual padding and BlockSpec revisit pattern
    (forward grid ``(B/bt, F/ft, K/kt)``; the transposed product has the
    same totals with K and F exchanging the reduction role, so one cost
    function serves both ops).
    """
    if kt % m:
        raise ValueError(f"kt must be a multiple of m, got kt={kt} m={m}")
    pb = _round_up(rows, bt)
    pk = _round_up(k, kt)
    pf = _round_up(f, ft)
    grid = (pb // bt) * (pf // ft) * (pk // kt)
    # X tile is re-read once per output-column tile (index map ignores j's
    # sibling); compressed W is re-read once per batch tile.
    x_read = (pf // ft) * pb * pk * x_bytes
    w_read = (pb // bt) * (pk // m) * n * pf * (val_bytes + idx_bytes)
    out_write = pb * pf * 4  # f32 accumulator, resident across the k loop
    mxu = 2 * pb * pk * pf
    # Decompress: one select over (kt/m, m, n, ft) per (i, j, kk) step.
    vpu = grid * kt * n * ft
    g_tile = kt // m
    vmem = (
        bt * kt * x_bytes            # x tile
        + g_tile * n * ft * (val_bytes + idx_bytes)  # vals + idx tiles
        + kt * ft * 4                # decompressed dense tile
        + bt * ft * 4                # output accumulator
    )
    return TileCost(
        bt=bt, kt=kt, ft=ft, grid_steps=grid,
        hbm_bytes=x_read + w_read + out_write,
        mxu_flops=mxu, vpu_ops=vpu, vmem_bytes=vmem,
    )


def _pow2_range(lo: int, hi: int) -> list[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def nm_spmm_candidates(
    rows: int,
    k: int,
    f: int,
    n: int,
    m: int,
    device=None,
    *,
    max_candidates: int = 8,
) -> list[TileCost]:
    """Legal tile candidates at a shape, best-first by the roofline model.

    Constraints enforced:
      * ``kt % m == 0`` (compressed groups never split a tile) and ``kt``
        a multiple of the f32 sublane when possible;
      * ``bt`` never exceeds the VPU-aligned padded row count (the decode
        clamp — a 256-row tile at 8 decode rows is 31/32 padding);
      * the live tile set fits the same VMEM budget ``vmem_plan`` uses.

    The historic default ``(256, 256, 256)`` is always included (clamped to
    legality), so a measured argmin over the returned list can never lose
    to the default.
    """
    budget = int(device_vmem_bytes(device) * _BUDGET_FRACTION)
    row_cap = max(VPU_ALIGN, _round_up(rows, VPU_ALIGN))
    bts = [bt for bt in _pow2_range(VPU_ALIGN, 256) if bt <= row_cap]
    if not bts:
        bts = [VPU_ALIGN]
    kt_step = max(m, VPU_ALIGN)
    kts = sorted({
        kt for kt in (128, 256, _round_up(min(k, 256), kt_step))
        if kt % m == 0 and kt >= m
    })
    fts = sorted({ft for ft in (128, 256, 512) if ft <= _round_up(f, 128)} | {
        min(_round_up(f, 128), 512)
    })
    seen: dict[tuple[int, int, int], TileCost] = {}
    for bt in bts:
        for kt in kts:
            for ft in fts:
                c = nm_spmm_cost(rows, k, f, n, m, bt, kt, ft)
                if c.vmem_bytes <= budget:
                    seen[c.tiles] = c
    # The default tiles, clamped only where the kernel would reject them.
    dbt, dkt, dft = DEFAULT_TILES
    dkt = dkt if dkt % m == 0 else _round_up(dkt, m)
    default = nm_spmm_cost(rows, k, f, n, m, dbt, dkt, dft)
    seen.setdefault(default.tiles, default)
    profile = profile_for(device)
    ranked = sorted(seen.values(), key=lambda c: c.model_seconds(profile))
    out = ranked[:max_candidates]
    if default.tiles not in [c.tiles for c in out]:
        out.append(default)
    return out


# ---------------------------------------------------------------------------
# Structured-sparse backward (repro.kernels.nm_grad).
# ---------------------------------------------------------------------------


def nm_sparsify_cost(
    rows: int,
    f: int,
    n: int,
    m: int,
    bt: int,
    ft: int,
    *,
    val_bytes: int = 2,
    idx_bytes: int = 1,
) -> TileCost:
    """Cost of ``nm_sparsify`` at ``dY`` shape ``(rows, F)``.

    One pass: each ``(bt, ft)`` tile is read once and its ``(bt/m, n, ft)``
    compressed slice written once — no revisits.  ``val_bytes`` defaults to
    the bf16 stochastic-rounding output (the ratio-carrying configuration).
    ``TileCost.kt`` carries ``m`` (there is no reduction tile).
    """
    if bt % m:
        raise ValueError(f"bt must be a multiple of m, got bt={bt} m={m}")
    pr = _round_up(rows, bt)
    pf = _round_up(f, ft)
    grid = (pr // bt) * (pf // ft)
    read = pr * pf * 4
    write = (pr // m) * n * pf * (val_bytes + idx_bytes)
    # Rank is m^2 pairwise compares per (block, col) -> m per element; the
    # cumsum/select/pack passes add a handful more.
    vpu = pr * pf * (m + 8)
    vmem = (
        bt * ft * 4                    # dy tile
        + bt * ft                      # pairwise-rank bool stack (m x bt/m rows)
        + bt * ft * 4                  # survivor values
        + (bt // m) * n * ft * (val_bytes + idx_bytes)
    )
    return TileCost(
        bt=bt, kt=m, ft=ft, grid_steps=grid,
        hbm_bytes=read + write, mxu_flops=0, vpu_ops=vpu, vmem_bytes=vmem,
    )


def nm_sparsify_candidates(
    rows: int,
    f: int,
    n: int,
    m: int,
    device=None,
    *,
    max_candidates: int = 6,
) -> list[TileCost]:
    """Legal ``(bt, ft)`` candidates for ``nm_sparsify``, best-first.

    ``bt`` must hold whole M-blocks; the default ``(256, 256)`` is always in
    the set (clamped to legality) so a measured argmin can never lose to it.
    """
    budget = int(device_vmem_bytes(device) * _BUDGET_FRACTION)
    row_cap = _round_up(max(rows, 1), max(m, VPU_ALIGN))
    bts = sorted({
        bt for bt in (128, 256, 512, 1024)
        if bt % m == 0 and bt <= max(row_cap, 256)
    } | {max(m, min(256 // m * m, row_cap))})
    fts = sorted({ft for ft in (128, 256, 512) if ft <= _round_up(f, 128)})
    seen: dict[tuple[int, int, int], TileCost] = {}
    for bt in bts:
        for ft in fts:
            c = nm_sparsify_cost(rows, f, n, m, bt, ft)
            if c.vmem_bytes <= budget:
                seen[c.tiles] = c
    dbt = max(m, (DEFAULT_TILES[0] // m) * m)
    default = nm_sparsify_cost(rows, f, n, m, dbt, min(256, _round_up(f, 128)))
    seen.setdefault(default.tiles, default)
    profile = profile_for(device)
    ranked = sorted(seen.values(), key=lambda c: c.model_seconds(profile))
    out = ranked[:max_candidates]
    if default.tiles not in [c.tiles for c in out]:
        out.append(default)
    return out


def nm_spmm_cc_cost(
    b: int,
    k: int,
    f: int,
    n_g: int,
    m_g: int,
    n_w: int,
    m_w: int,
    bt: int,
    kt: int,
    ft: int,
    *,
    g_val_bytes: int = 2,
    w_val_bytes: int = 4,
    idx_bytes: int = 1,
) -> TileCost:
    """Cost of ``nm_spmm_cc`` (dX = dY_sparse · Wᵀ, both operands compressed)
    at output shape ``(B, K)`` reducing over ``F``.

    Mirrors the kernel's grid ``(B/bt, K/kt, F/ft)``: the compressed dY tile
    is re-read once per K tile, the compressed W tile once per B tile — a
    taller ``bt`` divides W traffic, which is why ``CC_DEFAULT_TILES`` rows
    are 4x nm_spmm's.
    """
    if bt % m_g or kt % m_w:
        raise ValueError(f"bt%m_g and kt%m_w must be 0: {(bt, m_g, kt, m_w)}")
    pb = _round_up(b, bt)
    pk = _round_up(k, kt)
    pf = _round_up(f, ft)
    grid = (pb // bt) * (pk // kt) * (pf // ft)
    g_bytes = (pb // m_g) * n_g * pf * (g_val_bytes + idx_bytes)
    w_bytes = (pk // m_w) * n_w * pf * (w_val_bytes + idx_bytes)
    g_read = (pk // kt) * g_bytes
    w_read = (pb // bt) * w_bytes
    out_write = pb * pk * 4
    mxu = 2 * pb * pk * pf
    vpu = grid * ft * (bt * n_g + kt * n_w)  # two one-hot decompress passes
    vmem = (
        (bt // m_g) * n_g * ft * (g_val_bytes + idx_bytes)
        + bt * ft * 4                  # decompressed dY tile
        + (kt // m_w) * n_w * ft * (w_val_bytes + idx_bytes)
        + kt * ft * 4                  # decompressed W tile
        + bt * kt * 4                  # output accumulator
    )
    return TileCost(
        bt=bt, kt=kt, ft=ft, grid_steps=grid,
        hbm_bytes=g_read + w_read + out_write,
        mxu_flops=mxu, vpu_ops=vpu, vmem_bytes=vmem,
    )


def nm_spmm_cc_candidates(
    b: int,
    k: int,
    f: int,
    n_g: int,
    m_g: int,
    n_w: int,
    m_w: int,
    device=None,
    *,
    max_candidates: int = 8,
) -> list[TileCost]:
    """Legal tile candidates for ``nm_spmm_cc``, best-first by the model.

    ``bt`` ranges up to 1024 (compressed operands keep even the tallest tile
    set within VMEM); ``CC_DEFAULT_TILES`` is always included, clamped."""
    budget = int(device_vmem_bytes(device) * _BUDGET_FRACTION)
    row_cap = _round_up(max(b, 1), max(m_g, VPU_ALIGN))
    bts = sorted({
        bt for bt in (128, 256, 512, 1024)
        if bt % m_g == 0 and bt <= max(row_cap, 256)
    })
    kts = sorted({
        kt for kt in (128, 256, 512)
        if kt % m_w == 0 and kt >= m_w
    } | {max(m_w, _round_up(min(k, 256), m_w))})
    fts = sorted({ft for ft in (128, 256, 512) if ft <= _round_up(f, 128)})
    seen: dict[tuple[int, int, int], TileCost] = {}
    for bt in bts:
        for kt in kts:
            for ft in fts:
                c = nm_spmm_cc_cost(b, k, f, n_g, m_g, n_w, m_w, bt, kt, ft)
                if c.vmem_bytes <= budget:
                    seen[c.tiles] = c
    dbt, dkt, dft = CC_DEFAULT_TILES
    dbt = max(m_g, (min(dbt, row_cap) // m_g) * m_g)
    dkt = max(m_w, (dkt // m_w) * m_w)
    default = nm_spmm_cc_cost(b, k, f, n_g, m_g, n_w, m_w, dbt, dkt, dft)
    seen.setdefault(default.tiles, default)
    profile = profile_for(device)
    ranked = sorted(seen.values(), key=lambda c: c.model_seconds(profile))
    out = ranked[:max_candidates]
    if default.tiles not in [c.tiles for c in out]:
        out.append(default)
    return out


def nm_grad_cost(
    rows: int,
    k: int,
    f: int,
    n_g: int,
    m_g: int,
    n_w: int,
    m_w: int,
    *,
    g_val_bytes: int = 2,
    w_val_bytes: int = 4,
    sparsify_tiles: Optional[tuple[int, int]] = None,
    cc_tiles: Optional[tuple[int, int, int]] = None,
    spmm_tiles: Optional[tuple[int, int, int]] = None,
    tr_tiles: Optional[tuple[int, int, int]] = None,
) -> dict:
    """Backward HBM bytes for ONE compressed projection ``(K, F)`` at ``rows``
    tokens: the structured-sparse path vs the dense-cotangent path.

    Sparse path (``grad_sparsity`` on): ``dY`` is read ONCE (sparsify), and
    both backward GEMMs stream its ``(values, int8)`` buffer —
    ``g_val_bytes + 1`` per kept element instead of 4 per dense element, per
    *revisit*.  Dense path (the PR-9 baseline): dX re-reads dense ``dY`` once
    per K tile (``nm_spmm`` transpose) and dW once per output-row tile.
    Weight traffic, X traffic, and output writes are common structure priced
    identically on both sides.  Returns component maps plus
    ``ratio = sparse_bytes / dense_bytes`` — the BENCH_backward gate.
    """
    bt, kt, ft = spmm_tiles if spmm_tiles else DEFAULT_TILES
    kt = max(m_w, (kt // m_w) * m_w)
    cbt, ckt, cft = cc_tiles if cc_tiles else CC_DEFAULT_TILES
    cbt = max(m_g, (min(cbt, _round_up(rows, m_g)) // m_g) * m_g)
    ckt = max(m_w, (ckt // m_w) * m_w)
    sbt, sft = sparsify_tiles if sparsify_tiles else (
        max(m_g, (256 // m_g) * m_g), 256
    )

    gb = g_val_bytes + 1  # compressed-dY bytes per kept element (+int8 idx)
    wb = w_val_bytes + 1

    # -- sparse path --------------------------------------------------------
    sp = nm_sparsify_cost(rows, f, n_g, m_g, sbt, sft, val_bytes=g_val_bytes)
    cc = nm_spmm_cc_cost(rows, k, f, n_g, m_g, n_w, m_w, cbt, ckt, cft,
                         g_val_bytes=g_val_bytes, w_val_bytes=w_val_bytes)
    # dW = Xᵀ·compressed-dY through nm_spmm: streamed operand is Xᵀ (K rows),
    # reduction over the padded token rows, output (K, F).
    rp = _round_up(rows, m_g)
    pkw = _round_up(k, bt)          # streamed-row padding
    prw = _round_up(rp, kt)         # reduction padding
    pfw = _round_up(f, ft)
    x_dw = (pfw // ft) * pkw * prw * 4
    g_dw = (pkw // bt) * (prw // m_g) * n_g * pfw * gb
    out_dw = pkw * pfw * 4
    gather = k * f * 4 + (k // m_w) * n_w * f * 4  # support gather, both paths
    sparse = {
        "sparsify": sp.hbm_bytes,
        "dx": cc.hbm_bytes,
        "dw": x_dw + g_dw + out_dw,
        "gather": gather,
    }

    # -- dense-cotangent path (nm_linear's backward) ------------------------
    tbt, tkt, tft = tr_tiles if tr_tiles else (bt, kt, ft)
    tkt = max(m_w, (tkt // m_w) * m_w)
    pb = _round_up(rows, tbt)
    pk = _round_up(k, tkt)
    pf = _round_up(f, tft)
    dy_dx = (pk // tkt) * pb * pf * 4         # dY re-read per K tile
    w_dx = (pb // tbt) * (pk // m_w) * n_w * pf * wb
    out_dx = pb * pk * 4
    # dW = Xᵀ·dY as a dense GEMM at the same tiling.
    dy_dw = (pkw // bt) * prw * pfw * 4       # dY re-read per output-row tile
    dense = {
        "dx": dy_dx + w_dx + out_dx,
        "dw": x_dw + dy_dw + out_dw,
        "gather": gather,
    }

    sparse_bytes = sum(sparse.values())
    dense_bytes = sum(dense.values())
    return {
        "sparse": sparse,
        "dense": dense,
        "sparse_bytes": sparse_bytes,
        "dense_bytes": dense_bytes,
        "ratio": sparse_bytes / max(dense_bytes, 1),
    }


def fused_solve_candidates(m: int, device=None, *, live_buffers: int = 6) -> list[int]:
    """Candidate ``block_b`` tiles for the fused solve kernel, seeded from
    :func:`repro.kernels.vmem.vmem_plan` — the plan's tile is the ceiling;
    smaller powers of two trade VMEM residency for scheduling granularity."""
    top = vmem_plan(m, device, live_buffers=live_buffers).block_b
    return list(reversed(_pow2_range(VPU_ALIGN, top)))
