"""Versioned tuning table: measured tile winners, consulted at trace time.

The autotuner (``repro.perf.autotune`` / ``benchmarks/kernel_autotune.py``)
measures candidate tiles on the live device and persists the winners here.
At trace time, ``nm_spmm_pallas`` (via ``models.layers.proj`` →
``nm_linear_nd``) and the fused solver backend look their shapes up and use
the measured tiles when an entry matches; otherwise they fall back to the
clamped defaults — an empty or missing table is always safe.

Entries are keyed by ``(op, device_kind, m, shape_class)``:

* ``op`` — ``"nm_spmm_fwd"``, ``"nm_spmm_tr"``, ``"nm_sparsify"``,
  ``"nm_spmm_cc"`` (gradient sparsification, see ``repro.kernels.nm_grad``)
  or ``"fused_solve"``;
* ``device_kind`` — ``jax.Device.device_kind`` of the measuring device
  (tiles tuned on this container's ``cpu`` interpret mode never leak onto a
  TPU and vice versa);
* ``m`` — the pattern's group size (tile legality depends on it);
* ``shape_class`` — :func:`shape_class` string: ``gemv``/``gemm`` by row
  count (decode GEMV vs prefill GEMM) plus power-of-two K and F buckets, so
  an entry only ever applies to operand shapes of the size it was measured
  at.  The fused solve uses the single class ``"solve"`` (block batches are
  padded server-side; only ``m`` changes the kernel).

The JSON document carries a ``version`` field; loading a newer major
version than this module understands raises instead of silently
misapplying tiles.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib
import threading
from typing import Iterable, Optional

__all__ = [
    "TABLE_VERSION",
    "GEMV_MAX_ROWS",
    "TableEntry",
    "TuningTable",
    "shape_class",
    "device_kind_of",
    "get_tuning_table",
    "set_tuning_table",
    "default_table_path",
]

TABLE_VERSION = 1

# Row count at or below which a matmul is a "decode GEMV" for tuning
# purposes: a handful of in-flight decode slots, far below one MXU tile.
GEMV_MAX_ROWS = 32

_DEFAULT_TABLE_FILE = "default_table.json"
_ENV_OVERRIDE = "REPRO_TUNING_TABLE"


def _pow2_bucket(x: int) -> int:
    """Smallest power of two >= x (shape bucketing for entry keys)."""
    return 1 << max(int(x) - 1, 0).bit_length()


def shape_class(rows: int, k: int, f: int) -> str:
    """Shape-class key for an ``(rows, K) x (K, F)`` matmul."""
    kind = "gemv" if rows <= GEMV_MAX_ROWS else "gemm"
    return f"{kind}/k{_pow2_bucket(k)}/f{_pow2_bucket(f)}"


def device_kind_of(device=None) -> str:
    """``device_kind`` of ``device`` (default: first local jax device)."""
    kind = getattr(device, "device_kind", None)
    if kind is None:
        import jax

        devices = jax.local_devices()
        kind = devices[0].device_kind if devices else "cpu"
    return str(kind)


@dataclasses.dataclass(frozen=True)
class TableEntry:
    """One measured winner.  ``tiles`` is ``(bt, kt, ft)`` for the nm_spmm
    ops and ``(block_b,)`` for the fused solve."""

    op: str
    device_kind: str
    m: int
    shape_class: str
    tiles: tuple[int, ...]
    measured_s: float = 0.0
    default_s: float = 0.0
    speedup_vs_default: float = 1.0
    shape: tuple[int, ...] = ()   # the concrete shape the entry was tuned at

    @property
    def key(self) -> tuple[str, str, int, str]:
        return (self.op, self.device_kind, self.m, self.shape_class)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["tiles"] = list(self.tiles)
        d["shape"] = list(self.shape)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TableEntry":
        return cls(
            op=d["op"],
            device_kind=d["device_kind"],
            m=int(d["m"]),
            shape_class=d["shape_class"],
            tiles=tuple(int(t) for t in d["tiles"]),
            measured_s=float(d.get("measured_s", 0.0)),
            default_s=float(d.get("default_s", 0.0)),
            speedup_vs_default=float(d.get("speedup_vs_default", 1.0)),
            shape=tuple(int(s) for s in d.get("shape", ())),
        )


class TuningTable:
    """In-memory view of the tuning table; load/save round-trips JSON."""

    def __init__(self, entries: Iterable[TableEntry] = ()):
        self._entries: dict[tuple, TableEntry] = {}
        for e in entries:
            self._entries[e.key] = e

    def __len__(self) -> int:
        return len(self._entries)

    def entries(self) -> list[TableEntry]:
        return sorted(self._entries.values(), key=lambda e: e.key)

    def put(self, entry: TableEntry) -> None:
        self._entries[entry.key] = entry

    def lookup(
        self, op: str, device_kind: str, m: int, shape_cls: str
    ) -> Optional[TableEntry]:
        return self._entries.get((op, device_kind, m, shape_cls))

    # -- persistence --------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "version": TABLE_VERSION,
            "entries": [e.to_json() for e in self.entries()],
        }

    def save(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(json.dumps(self.to_json(), indent=1) + "\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path) -> "TuningTable":
        doc = json.loads(pathlib.Path(path).read_text())
        version = int(doc.get("version", -1))
        if version > TABLE_VERSION or version < 1:
            raise ValueError(
                f"tuning table {path} has version {version}; this build "
                f"understands <= {TABLE_VERSION} — regenerate it with "
                "benchmarks/kernel_autotune.py"
            )
        return cls(TableEntry.from_json(e) for e in doc.get("entries", ()))


def default_table_path() -> pathlib.Path:
    """The packaged default table (committed winners from the autotune bench)."""
    return pathlib.Path(__file__).resolve().parent / _DEFAULT_TABLE_FILE


_lock = threading.Lock()
_active: Optional[TuningTable] = None
_loaded = False


def get_tuning_table() -> TuningTable:
    """The process-wide active table.

    Resolution order: a table installed via :func:`set_tuning_table`; a path
    named by ``$REPRO_TUNING_TABLE``; the packaged default table; otherwise
    an empty table (all lookups miss — callers fall back to defaults).
    """
    global _active, _loaded
    with _lock:
        if _loaded:
            return _active  # type: ignore[return-value]
        path = os.environ.get(_ENV_OVERRIDE) or default_table_path()
        try:
            _active = TuningTable.load(path)
        except FileNotFoundError:
            _active = TuningTable()
        _loaded = True
        return _active


def set_tuning_table(table) -> None:
    """Install ``table`` (a :class:`TuningTable`, a path, or ``None``).

    ``None`` re-arms the lazy default resolution (env var / packaged file).
    Installing any table bumps the memo generation, so every cached tile
    resolution (:func:`nm_spmm_tiles` / :func:`nm_grad_tiles`) re-resolves
    against the new entries.
    """
    global _active, _loaded, _generation
    with _lock:
        _generation += 1
        if table is None:
            _active, _loaded = None, False
        elif isinstance(table, TuningTable):
            _active, _loaded = table, True
        else:
            _active, _loaded = TuningTable.load(table), True


# -- trace-time helpers consulted by the kernels ----------------------------
#
# Kernels resolve tiles on EVERY trace (shape_class string + device query +
# table dict probe).  Traces are frequent — each distinct jit shape, each
# bench rep — so the resolution is memoized per (op, device, m, shape class)
# with the table generation in the key: ``set_tuning_table`` invalidates by
# bumping ``_generation``, never by flushing (regression-tested in
# tests/test_perf.py: one ``TuningTable.lookup`` per distinct shape class).

_generation = 0


@functools.lru_cache(maxsize=8192)
def _class_of(rows: int, k: int, f: int) -> str:
    return shape_class(rows, k, f)


@functools.lru_cache(maxsize=8)
def _default_device_kind() -> str:
    return device_kind_of(None)


@functools.lru_cache(maxsize=4096)
def _tiles_cached(
    op: str, device_kind: str, m: int, shape_cls: str, generation: int
) -> Optional[tuple[int, ...]]:
    del generation  # cache-key only: stale generations never hit again
    entry = get_tuning_table().lookup(op, device_kind, m, shape_cls)
    return None if entry is None else entry.tiles


def _resolve_cached(op, rows, k, f, m, device):
    kind = _default_device_kind() if device is None else device_kind_of(device)
    return _tiles_cached(op, kind, m, _class_of(rows, k, f), _generation)


def nm_spmm_tiles(
    rows: int, k: int, f: int, m: int, transpose: bool, device=None
) -> Optional[tuple[int, int, int]]:
    """Measured ``(bt, kt, ft)`` for an nm_spmm shape, or ``None`` on miss."""
    op = "nm_spmm_tr" if transpose else "nm_spmm_fwd"
    tiles = _resolve_cached(op, rows, k, f, m, device)
    if tiles is None or len(tiles) != 3:
        return None
    return tiles  # type: ignore[return-value]


def nm_grad_tiles(
    op: str, rows: int, k: int, f: int, m: int, device=None
) -> Optional[tuple[int, int, int]]:
    """Measured ``(bt, kt, ft)`` for a gradient-sparsification op
    (``"nm_sparsify"`` — kt unused — or ``"nm_spmm_cc"``), None on miss."""
    tiles = _resolve_cached(op, rows, k, f, m, device)
    if tiles is None or len(tiles) != 3:
        return None
    return tiles  # type: ignore[return-value]


def fused_solve_block_b(m: int, device=None) -> Optional[int]:
    """Measured fused-solve ``block_b`` for group size ``m`` (None on miss)."""
    entry = get_tuning_table().lookup(
        "fused_solve", device_kind_of(device), m, "solve"
    )
    if entry is None or len(entry.tiles) != 1:
        return None
    return int(entry.tiles[0])
