"""Measurement-driven tile autotuner for the compressed hot-path kernels.

The roofline model (:mod:`repro.perf.roofline`) enumerates and ranks legal
tile candidates; this module *measures* the short-listed candidates on the
live device with real kernel invocations and returns the winner, plus a
:class:`~repro.perf.table.TableEntry` ready to persist.  The historic
default tiles are always in the measured set, so the winner's speedup over
the default is >= 1 by construction on the run that produced it.

``benchmarks/kernel_autotune.py`` drives this over the benched shape
classes and writes both ``BENCH_kernels.json`` and the tuning table that
``nm_spmm_pallas`` / the fused solver backend consult at trace time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.perf import roofline
from repro.perf.table import TableEntry, device_kind_of, shape_class

__all__ = [
    "CandidateTiming",
    "AutotuneResult",
    "autotune_nm_spmm",
    "autotune_nm_sparsify",
    "autotune_nm_spmm_cc",
    "autotune_fused_solve",
]


@dataclasses.dataclass(frozen=True)
class CandidateTiming:
    tiles: tuple[int, ...]
    seconds: float
    model_seconds: float = 0.0


@dataclasses.dataclass(frozen=True)
class AutotuneResult:
    """Outcome of tuning one (op, shape) cell."""

    op: str
    m: int
    shape: tuple[int, ...]
    shape_class: str
    device_kind: str
    default_tiles: tuple[int, ...]
    best_tiles: tuple[int, ...]
    default_seconds: float
    best_seconds: float
    candidates: tuple[CandidateTiming, ...]

    @property
    def speedup_vs_default(self) -> float:
        return self.default_seconds / max(self.best_seconds, 1e-12)

    def table_entry(self) -> TableEntry:
        return TableEntry(
            op=self.op,
            device_kind=self.device_kind,
            m=self.m,
            shape_class=self.shape_class,
            tiles=self.best_tiles,
            measured_s=self.best_seconds,
            default_s=self.default_seconds,
            speedup_vs_default=self.speedup_vs_default,
            shape=self.shape,
        )

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["speedup_vs_default"] = self.speedup_vs_default
        return d


def _median_seconds(fn, *, warmup: int = 1, reps: int = 3) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _synth_compressed(k: int, f: int, n: int, m: int, seed: int = 0):
    """Synthetic compressed operands: dense-N:M values + valid indices."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    g = k // m
    vals = rng.normal(size=(g, n, f)).astype(np.float32)
    # Sorted distinct positions per group/column — a legal N:M support.
    idx = np.empty((g, n, f), np.int8)
    base = np.stack([
        np.sort(rng.choice(m, size=n, replace=False)) for _ in range(g * f)
    ])
    idx[...] = base.reshape(g, f, n).transpose(0, 2, 1)
    return jnp.asarray(vals), jnp.asarray(idx)


def autotune_nm_spmm(
    rows: int,
    k: int,
    f: int,
    n: int,
    m: int,
    *,
    transpose: bool = False,
    device=None,
    max_candidates: int = 6,
    reps: int = 3,
    seed: int = 0,
) -> AutotuneResult:
    """Tune ``nm_spmm`` tiles at one concrete operand shape.

    ``rows`` is the leading dim of the streamed operand (activations forward,
    cotangents for the transposed product) — the axis that separates decode
    GEMV from prefill GEMM.
    """
    import jax.numpy as jnp

    from repro.kernels.nm_spmm.kernel import nm_spmm_pallas

    if k % m:
        raise ValueError(f"K must be a multiple of m, got K={k} m={m}")
    vals, idx = _synth_compressed(k, f, n, m, seed)
    rng = np.random.default_rng(seed + 1)
    width = f if transpose else k
    x = jnp.asarray(rng.normal(size=(rows, width)).astype(np.float32))

    cands = roofline.nm_spmm_candidates(
        rows, k, f, n, m, device, max_candidates=max_candidates
    )
    profile = roofline.profile_for(device)
    timings: list[CandidateTiming] = []
    for c in cands:
        sec = _median_seconds(
            lambda c=c: nm_spmm_pallas(
                x, vals, idx, m, transpose=transpose, bt=c.bt, kt=c.kt, ft=c.ft
            ),
            reps=reps,
        )
        timings.append(CandidateTiming(c.tiles, sec, c.model_seconds(profile)))

    dbt, dkt, dft = roofline.DEFAULT_TILES
    dkt = dkt if dkt % m == 0 else -(-dkt // m) * m
    default_tiles = (dbt, dkt, dft)
    default_sec = next(t.seconds for t in timings if t.tiles == default_tiles)
    best = min(timings, key=lambda t: t.seconds)
    return AutotuneResult(
        op="nm_spmm_tr" if transpose else "nm_spmm_fwd",
        m=m,
        shape=(rows, k, f, n),
        shape_class=shape_class(rows, k, f),
        device_kind=device_kind_of(device),
        default_tiles=default_tiles,
        best_tiles=best.tiles,
        default_seconds=default_sec,
        best_seconds=best.seconds,
        candidates=tuple(timings),
    )


def autotune_nm_sparsify(
    rows: int,
    f: int,
    n: int,
    m: int,
    *,
    out_dtype="bfloat16",
    device=None,
    max_candidates: int = 5,
    reps: int = 3,
    seed: int = 0,
) -> AutotuneResult:
    """Tune ``nm_sparsify`` tiles at one cotangent shape ``(rows, F)``."""
    import jax.numpy as jnp

    from repro.kernels.nm_grad.kernel import nm_sparsify_pallas

    rng = np.random.default_rng(seed)
    dy = jnp.asarray(rng.normal(size=(rows, f)).astype(np.float32))

    cands = roofline.nm_sparsify_candidates(
        rows, f, n, m, device, max_candidates=max_candidates
    )
    profile = roofline.profile_for(device)
    timings: list[CandidateTiming] = []
    for c in cands:
        sec = _median_seconds(
            lambda c=c: nm_sparsify_pallas(
                dy, n, m, seed, out_dtype=jnp.dtype(out_dtype),
                bt=c.bt, ft=c.ft,
            )[0],
            reps=reps,
        )
        timings.append(CandidateTiming(c.tiles, sec, c.model_seconds(profile)))

    dbt = max(m, (roofline.DEFAULT_TILES[0] // m) * m)
    dft = min(256, -(-f // 128) * 128)
    default_tiles = (dbt, m, dft)
    default_sec = next(
        (t.seconds for t in timings if t.tiles == default_tiles),
        min(t.seconds for t in timings),
    )
    best = min(timings, key=lambda t: t.seconds)
    return AutotuneResult(
        op="nm_sparsify",
        m=m,
        shape=(rows, f, n),
        shape_class=shape_class(rows, f, f),
        device_kind=device_kind_of(device),
        default_tiles=default_tiles,
        best_tiles=best.tiles,
        default_seconds=default_sec,
        best_seconds=best.seconds,
        candidates=tuple(timings),
    )


def autotune_nm_spmm_cc(
    rows: int,
    k: int,
    f: int,
    n_g: int,
    m_g: int,
    n_w: int,
    m_w: int,
    *,
    g_dtype="bfloat16",
    device=None,
    max_candidates: int = 6,
    reps: int = 3,
    seed: int = 0,
) -> AutotuneResult:
    """Tune ``nm_spmm_cc`` tiles at one dX shape (``(rows, K)`` over ``F``)."""
    import jax.numpy as jnp

    from repro.kernels.nm_grad.kernel import nm_spmm_cc_pallas

    if rows % m_g or k % m_w:
        raise ValueError(f"rows%m_g and K%m_w must be 0: {(rows, m_g, k, m_w)}")
    gvals, gidx = _synth_compressed(rows, f, n_g, m_g, seed)
    gvals = gvals.astype(jnp.dtype(g_dtype))
    wvals, widx = _synth_compressed(k, f, n_w, m_w, seed + 1)

    cands = roofline.nm_spmm_cc_candidates(
        rows, k, f, n_g, m_g, n_w, m_w, device, max_candidates=max_candidates
    )
    profile = roofline.profile_for(device)
    timings: list[CandidateTiming] = []
    for c in cands:
        sec = _median_seconds(
            lambda c=c: nm_spmm_cc_pallas(
                gvals, gidx, wvals, widx, m_g, m_w, bt=c.bt, kt=c.kt, ft=c.ft
            ),
            reps=reps,
        )
        timings.append(CandidateTiming(c.tiles, sec, c.model_seconds(profile)))

    dbt, dkt, dft = roofline.CC_DEFAULT_TILES
    row_cap = -(-rows // m_g) * m_g
    dbt = max(m_g, (min(dbt, row_cap) // m_g) * m_g)
    dkt = max(m_w, (dkt // m_w) * m_w)
    default_tiles = (dbt, dkt, dft)
    default_sec = next(
        (t.seconds for t in timings if t.tiles == default_tiles),
        min(t.seconds for t in timings),
    )
    best = min(timings, key=lambda t: t.seconds)
    m_key = max(m_g, m_w)
    return AutotuneResult(
        op="nm_spmm_cc",
        m=m_key,
        shape=(rows, k, f, n_g, n_w),
        shape_class=shape_class(rows, k, f),
        device_kind=device_kind_of(device),
        default_tiles=default_tiles,
        best_tiles=best.tiles,
        default_seconds=default_sec,
        best_seconds=best.seconds,
        candidates=tuple(timings),
    )


def autotune_fused_solve(
    m: int,
    n: int,
    *,
    batch: int = 256,
    iters: int = 40,
    device=None,
    reps: int = 3,
    seed: int = 0,
    max_candidates: Optional[int] = 4,
) -> AutotuneResult:
    """Tune the fused solve kernel's block-batch tile for group size ``m``."""
    import jax.numpy as jnp

    from repro.kernels.fused_solve.kernel import LIVE_BUFFERS, fused_solve_pallas

    rng = np.random.default_rng(seed)
    w = jnp.asarray(np.abs(rng.normal(size=(batch, m, m))).astype(np.float32))

    cands = roofline.fused_solve_candidates(m, device, live_buffers=LIVE_BUFFERS)
    # The vmem_plan tile IS the default (what fused_block_b returns today).
    default_bb = cands[0]
    if max_candidates:
        cands = cands[:max_candidates]
    timings = []
    for bb in cands:
        sec = _median_seconds(
            lambda bb=bb: fused_solve_pallas(w, n, iters=iters, block_b=bb)[0],
            reps=reps,
        )
        timings.append(CandidateTiming((bb,), sec))
    default_sec = next(t.seconds for t in timings if t.tiles == (default_bb,))
    best = min(timings, key=lambda t: t.seconds)
    return AutotuneResult(
        op="fused_solve",
        m=m,
        shape=(batch, m, m),
        shape_class="solve",
        device_kind=device_kind_of(device),
        default_tiles=(default_bb,),
        best_tiles=best.tiles,
        default_seconds=default_sec,
        best_seconds=best.seconds,
        candidates=tuple(timings),
    )
