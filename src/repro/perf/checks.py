"""Declarative perf-regression checks over the committed ``BENCH_*.json``.

The reframe idiom, minus the framework: each :class:`PerfCheck` names a
benchmark document, *extraction expressions* that pull named values out of
it, *sanity conditions* (invariants that must hold for the run to be
meaningful at all — e.g. the measured traffic ratio matching the analytic
model, or the bit-identity flag), and *trend references* — values compared
against the committed baseline document within a tolerance band, gating or
warning on regression.

``tools/perfcheck.py`` is the CLI driver: it evaluates every check in
:data:`CHECKS` against a "current" directory of bench JSONs and a
"baseline" directory (the repo's committed files), and fails CI on any
sanity failure or gated trend regression.

Extraction expressions are dotted paths into the JSON document with two
extras::

    headline.tokens_per_sec.compressed     # plain nested lookup
    headline.*.speedup_vs_pallas           # fan out over dict values / lists
    results[mode=compressed].tokens_per_sec  # select from a list of dicts

A ``*`` segment turns the result into a list (later segments map over it),
which the sanity/trend expressions consume with ``min``/``max``/``all``.

Trend comparisons only run when the two documents are *comparable*: every
``compare_keys`` expression (typically ``meta.model``, ``meta.pattern``,
shape fields) must extract equal values from both.  A CI smoke run is
therefore sanity-checked against its own gates but never trend-diffed
against the committed full-size baseline.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
from typing import Any, Mapping, Optional

__all__ = [
    "Extractor",
    "Trend",
    "PerfCheck",
    "CheckResult",
    "CHECKS",
    "extract",
    "evaluate_check",
    "evaluate_all",
]

_SELECT_RE = re.compile(r"^(?P<name>[^\[\]]*)\[(?P<key>[^=\]]+)=(?P<val>[^\]]+)\]$")


class ExtractionError(KeyError):
    """An extraction expression did not resolve against the document."""


def _descend(node: Any, seg: str):
    if seg == "*":
        if isinstance(node, Mapping):
            return list(node.values()), True
        if isinstance(node, list):
            return list(node), True
        raise ExtractionError(f"'*' needs a dict or list, got {type(node).__name__}")
    sel = _SELECT_RE.match(seg)
    if sel:
        name, key, val = sel.group("name"), sel.group("key"), sel.group("val")
        items = node[name] if name else node
        if not isinstance(items, list):
            raise ExtractionError(f"selector [{key}={val}] needs a list")
        for item in items:
            if str(item.get(key)) == val:
                return item, False
        raise ExtractionError(f"no item with {key}={val} under {name or '<root>'}")
    if isinstance(node, Mapping):
        if seg not in node:
            raise ExtractionError(seg)
        return node[seg], False
    raise ExtractionError(f"cannot index {type(node).__name__} with {seg!r}")


def extract(doc: Any, expr: str):
    """Evaluate an extraction expression against a parsed JSON document."""
    nodes, fanned = [doc], False
    for seg in expr.split("."):
        out = []
        for node in nodes:
            val, fan = _descend(node, seg)
            if fan:
                fanned = True
                out.extend(val)
            else:
                out.append(val)
        nodes = out
    return nodes if fanned else nodes[0]


# Helper namespace available to sanity expressions (no builtins beyond these).
_SAFE_FUNCS = {
    "abs": abs, "min": min, "max": max, "all": all, "any": any,
    "len": len, "sum": sum, "sorted": sorted, "round": round,
    "approx": lambda a, b, rel=0.1: abs(a - b) <= rel * abs(b),
}


def _eval_condition(cond: str, variables: Mapping[str, Any]) -> bool:
    ns = dict(_SAFE_FUNCS)
    ns.update(variables)
    return bool(eval(cond, {"__builtins__": {}}, ns))  # noqa: S307 - declarative DSL


@dataclasses.dataclass(frozen=True)
class Extractor:
    """Named extraction: ``var`` becomes available to sanity/trend exprs."""

    var: str
    expr: str


@dataclasses.dataclass(frozen=True)
class Trend:
    """Trend reference: current vs baseline value of ``var`` within a band.

    ``direction`` is the *good* direction ("higher" for throughput, "lower"
    for latency/loss); a move beyond ``tolerance`` (fractional) in the bad
    direction is a regression.  ``mode="gate"`` fails the run, ``"warn"``
    only reports.
    """

    var: str
    direction: str = "higher"
    tolerance: float = 0.10
    mode: str = "gate"

    def verdict(self, current: float, baseline: float) -> str:
        if baseline == 0:
            return "ok"
        delta = (current - baseline) / abs(baseline)
        bad = -delta if self.direction == "higher" else delta
        if bad > self.tolerance:
            return "regressed"
        if bad < -self.tolerance:
            return "improved"
        return "ok"

    def worst_delta(self, current, baseline) -> Optional[float]:
        """Signed fractional delta, worst element first for list-valued vars
        (a fanned-out extraction, e.g. per-M throughputs); None if the
        shapes do not line up."""
        if isinstance(current, (int, float)) and isinstance(baseline, (int, float)):
            pairs = [(float(current), float(baseline))]
        elif (
            isinstance(current, list) and isinstance(baseline, list)
            and len(current) == len(baseline) and current
            and all(isinstance(v, (int, float)) for v in current + baseline)
        ):
            pairs = [(float(c), float(b)) for c, b in zip(current, baseline)]
        else:
            return None
        deltas = [(c - b) / abs(b) for c, b in pairs if b]
        if not deltas:
            return 0.0
        return min(deltas) if self.direction == "higher" else max(deltas)


@dataclasses.dataclass(frozen=True)
class PerfCheck:
    """One declarative check bound to one ``BENCH_*.json`` document."""

    name: str
    bench: str                                  # file name, e.g. BENCH_train.json
    extract: tuple[Extractor, ...] = ()
    sanity: tuple[str, ...] = ()
    trends: tuple[Trend, ...] = ()
    compare_keys: tuple[str, ...] = ()          # comparability fingerprint
    required: bool = True                       # missing baseline file is an error


@dataclasses.dataclass
class CheckResult:
    check: str
    bench: str
    status: str                     # ok | sanity_failed | regressed | skipped | missing
    sanity_failures: list[str] = dataclasses.field(default_factory=list)
    trend_rows: list[dict] = dataclasses.field(default_factory=list)
    notes: list[str] = dataclasses.field(default_factory=list)
    values: dict = dataclasses.field(default_factory=dict)

    @property
    def gating_failure(self) -> bool:
        return self.status in ("sanity_failed", "regressed", "missing")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _extract_all(doc, extractors) -> tuple[dict, list[str]]:
    values, problems = {}, []
    for ex in extractors:
        try:
            values[ex.var] = extract(doc, ex.expr)
        except ExtractionError as e:
            problems.append(f"extract {ex.var} = {ex.expr}: {e}")
    return values, problems


def evaluate_check(
    check: PerfCheck,
    current_doc,
    baseline_doc=None,
) -> CheckResult:
    """Evaluate sanity on ``current_doc`` and trends vs ``baseline_doc``."""
    res = CheckResult(check=check.name, bench=check.bench, status="ok")
    values, problems = _extract_all(current_doc, check.extract)
    res.values = {
        k: v for k, v in values.items()
        if isinstance(v, (int, float, bool, str, list))
    }
    if problems:
        res.status = "sanity_failed"
        res.sanity_failures.extend(problems)
        return res

    for cond in check.sanity:
        try:
            ok = _eval_condition(cond, values)
        except Exception as e:
            ok = False
            res.sanity_failures.append(f"{cond!r} raised {type(e).__name__}: {e}")
            continue
        if not ok:
            res.sanity_failures.append(cond)
    if res.sanity_failures:
        res.status = "sanity_failed"
        return res

    if baseline_doc is None or not check.trends:
        return res

    if baseline_doc is current_doc:
        comparable = True
    else:
        comparable = True
        for key_expr in check.compare_keys:
            try:
                cur = extract(current_doc, key_expr)
                base = extract(baseline_doc, key_expr)
            except ExtractionError:
                comparable = False
                break
            if cur != base:
                comparable = False
                res.notes.append(
                    f"baseline not comparable: {key_expr} differs "
                    f"({cur!r} vs {base!r}) — trends skipped"
                )
                break
    if not comparable:
        return res

    base_values, base_problems = _extract_all(baseline_doc, check.extract)
    if base_problems:
        res.notes.append(f"baseline extraction failed: {base_problems} — trends skipped")
        return res

    regressed = False
    for trend in check.trends:
        cur, base = values.get(trend.var), base_values.get(trend.var)
        delta = trend.worst_delta(cur, base)
        if delta is None:
            res.notes.append(f"trend {trend.var}: non-numeric or "
                             "mismatched shapes — skipped")
            continue
        bad = -delta if trend.direction == "higher" else delta
        verdict = ("regressed" if bad > trend.tolerance
                   else "improved" if bad < -trend.tolerance else "ok")
        res.trend_rows.append({
            "var": trend.var,
            "current": cur,
            "baseline": base,
            "delta_frac": delta,
            "tolerance": trend.tolerance,
            "direction": trend.direction,
            "mode": trend.mode,
            "verdict": verdict,
        })
        if verdict == "regressed" and trend.mode == "gate":
            regressed = True
    if regressed:
        res.status = "regressed"
    return res


def evaluate_all(
    current_dir,
    baseline_dir=None,
    *,
    checks=None,
    require_all: bool = False,
    only: Optional[str] = None,
) -> list[CheckResult]:
    """Run every check against ``current_dir`` (trend vs ``baseline_dir``).

    A check whose bench file is missing from ``current_dir`` is *skipped*
    (a smoke run does not produce every document) unless ``require_all`` —
    then a missing ``required`` check is a gating failure.
    """
    current_dir = pathlib.Path(current_dir)
    baseline_dir = pathlib.Path(baseline_dir) if baseline_dir else None
    results = []
    for check in checks if checks is not None else CHECKS:
        if only and check.name != only:
            continue
        cur_path = current_dir / check.bench
        if not cur_path.exists():
            status = "missing" if (require_all and check.required) else "skipped"
            results.append(CheckResult(
                check=check.name, bench=check.bench, status=status,
                notes=[f"{cur_path} not found"],
            ))
            continue
        current_doc = json.loads(cur_path.read_text())
        baseline_doc = None
        if baseline_dir is not None:
            base_path = baseline_dir / check.bench
            if base_path == cur_path:
                baseline_doc = current_doc
            elif base_path.exists():
                baseline_doc = json.loads(base_path.read_text())
        results.append(evaluate_check(check, current_doc, baseline_doc))
    return results


# ---------------------------------------------------------------------------
# The committed check suite — one check per BENCH document family.
# ---------------------------------------------------------------------------

# meta.accounting fences the bytes-accounting schema: "train-v2" added the
# activation-gradient traffic terms, and a v1 baseline extracts differently —
# documents across the bump are not trend-comparable.
_TRAIN_KEYS = ("meta.model", "meta.pattern", "meta.seq_len", "meta.batch",
               "meta.device", "meta.accounting")

CHECKS: tuple[PerfCheck, ...] = (
    PerfCheck(
        name="train_compressed_exec",
        bench="BENCH_train.json",
        extract=(
            Extractor("bytes_ratio_bench", "headline.bytes_ratio_bench"),
            Extractor("bytes_ratio_analytic", "headline.bytes_ratio_analytic"),
            Extractor("bytes_ratio_total", "headline.bytes_ratio_total"),
            Extractor("loss_bit_identity", "headline.loss_bit_identity"),
            Extractor("loss_abs_delta", "headline.loss_abs_delta"),
            Extractor("tok_s_dense", "headline.tokens_per_sec.dense"),
            Extractor("tok_s_compressed", "headline.tokens_per_sec.compressed"),
            Extractor("footprint_ratio", "headline.param_footprint_ratio"),
        ),
        sanity=(
            # The measured traffic must track the analytic compressed_bytes
            # model — if it drifts, the bench is measuring the wrong thing.
            "approx(bytes_ratio_bench, bytes_ratio_analytic, rel=0.1)",
            # Actgrad traffic is mode-invariant: the weight+actgrad total
            # ratio sits strictly between the weights-only ratio and 1.
            "bytes_ratio_bench < bytes_ratio_total < 1.0",
            # Compressed execution must stay numerically the dense path.
            "loss_bit_identity or loss_abs_delta < 1e-4",
            "footprint_ratio < 1.0",
        ),
        trends=(
            Trend("tok_s_compressed", direction="higher", tolerance=0.15),
            Trend("tok_s_dense", direction="higher", tolerance=0.15, mode="warn"),
        ),
        compare_keys=_TRAIN_KEYS,
    ),
    PerfCheck(
        name="solver_fused_speedup",
        bench="BENCH_solver.json",
        extract=(
            Extractor("objective_ratios", "headline.*.fused_best_objective_ratio"),
            Extractor("speedups_vs_pallas", "headline.*.speedup_vs_pallas"),
            Extractor("blocks_per_sec", "headline.*.fused_best_blocks_per_sec"),
        ),
        sanity=(
            # Early-exit may trade a sliver of objective for speed, bounded.
            "min(objective_ratios) >= 0.99",
            # The fused kernel must never lose to the split pipeline.
            "min(speedups_vs_pallas) >= 1.0",
        ),
        trends=(
            Trend("blocks_per_sec", direction="higher", tolerance=0.15, mode="warn"),
        ),
        compare_keys=("meta.iters", "meta.reps", "meta.device"),
    ),
    PerfCheck(
        name="dst_refresh_overhead",
        bench="BENCH_dst.json",
        extract=(
            Extractor("step_overhead_frac", "headline.step_overhead_frac"),
            Extractor("stall_frac", "headline.stall_frac_of_step"),
            Extractor("quality_delta", "headline.quality_delta"),
            Extractor("dst_final_loss", "headline.dst_final_loss"),
        ),
        sanity=(
            "step_overhead_frac < 0.05",
            "stall_frac < 0.10",
            # Decaying DST must end no worse than one-shot (small slack for
            # seed-level noise).
            "quality_delta <= 0.05",
        ),
        trends=(
            Trend("dst_final_loss", direction="lower", tolerance=0.10),
            Trend("step_overhead_frac", direction="lower", tolerance=0.5, mode="warn"),
        ),
        compare_keys=("meta.model", "meta.steps", "meta.refresh_every",
                      "meta.device"),
    ),
    PerfCheck(
        name="chaos_zero_loss",
        bench="BENCH_chaos.json",
        extract=(
            Extractor("requests_lost_total", "headline.requests_lost_total"),
            Extractor("bit_identical", "headline.bit_identical_everywhere"),
            Extractor("flaky_lost", "scenarios.flaky_network.requests_lost"),
            Extractor("restart_lost", "scenarios.kill_restart.requests_lost"),
            Extractor("degraded_lost", "scenarios.degraded.requests_lost"),
            Extractor("refresh_landed", "scenarios.dst_refresh.refresh_landed"),
        ),
        sanity=(
            "requests_lost_total == 0",
            "bit_identical",
            "max(flaky_lost, restart_lost, degraded_lost) == 0",
            "refresh_landed",
        ),
        compare_keys=("meta.tensors", "meta.solver_iters"),
    ),
    PerfCheck(
        name="service_fairness",
        bench="BENCH_service.json",
        extract=(
            Extractor("meta_bench", "meta.benchmark"),
        ),
        sanity=(
            "meta_bench == 'service_load'",
        ),
        required=False,  # produced by the CI service job, not committed
        compare_keys=("meta.benchmark",),
    ),
    PerfCheck(
        name="backward_sparse",
        bench="BENCH_backward.json",
        extract=(
            Extractor("bytes_ratio_model", "headline.bytes_ratio_model"),
            Extractor("model_measured_err", "headline.model_measured_err"),
            Extractor("forward_bit_identity", "headline.forward_bit_identity"),
            Extractor("grad_rel_err_max", "headline.grad_rel_err_max"),
            Extractor("tok_s_sparse", "headline.tokens_per_sec.sparse-grad"),
            Extractor("tok_s_dense_grad", "headline.tokens_per_sec.dense-grad"),
            Extractor("sparse_vs_pr9", "headline.sparse_vs_pr9"),
            Extractor("meta_model", "meta.model"),
        ),
        sanity=(
            # The traffic re-accounted from the kernels' actual launch
            # configuration must track the roofline nm_grad_cost model.
            "model_measured_err <= 0.05",
            # Gradient sparsification must not touch the forward pass.
            "forward_bit_identity",
            # MVU noise at its analytic scale (~2x per sparsification for
            # near-uniform block magnitudes at 8:16, cascading a few-fold
            # across the layer stack), not exploded.
            "grad_rel_err_max < 10.0",
            # The full bench-30m document must clear the 8:16 bytes gate and
            # the committed PR-9 compressed-throughput floor; the CI smoke
            # document (tiny, padding-bound shapes) skips both.
            "meta_model != 'bench-30m' or bytes_ratio_model <= 0.8",
            "meta_model != 'bench-30m' or sparse_vs_pr9 >= 1.0",
        ),
        trends=(
            Trend("tok_s_sparse", direction="higher", tolerance=0.15),
            Trend("tok_s_dense_grad", direction="higher", tolerance=0.15,
                  mode="warn"),
            Trend("bytes_ratio_model", direction="lower", tolerance=0.05),
        ),
        compare_keys=_TRAIN_KEYS + ("meta.grad_pattern", "meta.grad_dtype"),
    ),
    PerfCheck(
        name="kernel_autotune",
        bench="BENCH_kernels.json",
        extract=(
            Extractor("speedups", "headline.*.speedup_vs_default"),
            Extractor("decode_speedup",
                      "headline.nm_spmm_fwd_gemv.speedup_vs_default"),
        ),
        sanity=(
            # Autotuned tiles must be at least as fast as the fixed default
            # on every shape class (the default is in the candidate set, so
            # this can only fail if the table was written by a broken run).
            "min(speedups) >= 1.0",
            # ...and the decode GEMV — the shape the fixed tiles waste 31/32
            # of their rows on — must be strictly faster.
            "decode_speedup > 1.0",
        ),
        trends=(
            Trend("decode_speedup", direction="higher", tolerance=0.25),
        ),
        compare_keys=("meta.device", "meta.shape_set"),
    ),
)
