"""repro.perf — roofline autotuning + declarative perf-regression checks.

Two halves (ROADMAP item 3):

* **Autotune** — :mod:`repro.perf.roofline` prices candidate tiles for the
  compressed hot-path kernels (bytes moved / flops per tile, VMEM-feasible
  per :func:`repro.kernels.vmem.vmem_plan`); :mod:`repro.perf.autotune`
  measures the short list on the live device; :mod:`repro.perf.table`
  persists the winners in a versioned table keyed by device kind, group
  size and operand shape class, which ``nm_spmm_pallas`` (behind
  ``models.layers.proj``) and the fused solver backend consult at trace
  time.  ``benchmarks/kernel_autotune.py`` drives it and writes
  ``BENCH_kernels.json``.

* **Perf gates** — :mod:`repro.perf.checks` declares reframe-style checks
  (extraction expressions, sanity conditions, trend references with
  tolerance bands) over every committed ``BENCH_*.json``;
  ``tools/perfcheck.py`` evaluates them in CI and fails on regression.

Submodules import lazily (PEP 562) so ``import repro.perf`` never pulls
jax — ``tools/perfcheck.py`` parses JSON only.
"""
from __future__ import annotations

_LAZY = {
    "roofline": ".roofline",
    "autotune": ".autotune",
    "table": ".table",
    "checks": ".checks",
    # Promoted names.
    "TuningTable": ".table",
    "TableEntry": ".table",
    "get_tuning_table": ".table",
    "set_tuning_table": ".table",
    "shape_class": ".table",
    "PerfCheck": ".checks",
    "Trend": ".checks",
    "Extractor": ".checks",
    "CHECKS": ".checks",
    "evaluate_all": ".checks",
    "autotune_nm_spmm": ".autotune",
    "autotune_fused_solve": ".autotune",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    if name not in _LAZY:
        raise AttributeError(f"module 'repro.perf' has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(_LAZY[name], __name__)
    if _LAZY[name].lstrip(".") == name:
        return mod
    return getattr(mod, name)
