"""N:M sparsity substrate: mask application, compressed storage formats."""
from repro.sparsity.compressed import compress_nm, decompress_nm, compressed_bytes
from repro.sparsity.masks import apply_mask, mask_sparsity, sparsify_pytree

__all__ = [
    "compress_nm",
    "decompress_nm",
    "compressed_bytes",
    "apply_mask",
    "mask_sparsity",
    "sparsify_pytree",
]
