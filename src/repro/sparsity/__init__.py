"""N:M sparsity substrate: mask application, compressed storage formats,
bit-packed mask rows.

Re-exports are lazy (PEP 562): ``repro.sparsity.bitpack`` is imported by
the mask-service cache, which is itself imported by ``sparsity.masks`` —
eager re-exports here would close that cycle.
"""

_EXPORTS = {
    "compress_nm": "repro.sparsity.compressed",
    "decompress_nm": "repro.sparsity.compressed",
    "compressed_bytes": "repro.sparsity.compressed",
    "apply_mask": "repro.sparsity.masks",
    "mask_sparsity": "repro.sparsity.masks",
    "sparsify_pytree": "repro.sparsity.masks",
    "NMCompressed": "repro.sparsity.params",
    "compress_params": "repro.sparsity.params",
    "decompress_params": "repro.sparsity.params",
    "is_sparse_params": "repro.sparsity.params",
    "masks_from_params": "repro.sparsity.params",
    "recompress": "repro.sparsity.params",
    "remap_slots": "repro.sparsity.params",
    "remap_tree": "repro.sparsity.params",
    "sparse_param_bytes": "repro.sparsity.params",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
