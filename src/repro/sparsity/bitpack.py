"""Bit-packed mask rows: the wire/cache format of the fused solve pipeline.

A solved (B, M, M) boolean block mask is stored as ``uint32`` row words,
bit ``j`` (LSB-first) of word ``k`` = column ``32k + j``:

* M <= 32 (every pattern the paper evaluates, and the only layout the
  ``pallas-fused`` kernel emits): one word per row — shape (B, M), a 32x
  cut in mask write bandwidth at M=32;
* M > 32 (service generality): ``W = ceil(M/32)`` words per row — shape
  (B, M, W).

The service cache stores these words verbatim (``cache_format=3``), so a
fused solve feeds the cache without any host-side repacking round-trip.

Both jnp (device, traceable) and numpy (host) variants are provided; they
are bit-for-bit interchangeable.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

MAX_M = 32  # single-word rows; the fused kernel's (and row-word) fast path


def words_per_row(m: int) -> int:
    if m < 1:
        raise ValueError(f"mask rows need m >= 1, got {m}")
    return -(-m // 32)


def pack_rows(mask_blocks: jnp.ndarray) -> jnp.ndarray:
    """(..., M, M) bool -> (..., M) uint32 (M <= 32) or (..., M, W) uint32.

    Bit j (LSB-first) of word k = column 32k + j.  Traceable.
    """
    m = mask_blocks.shape[-1]
    w = words_per_row(m)
    segs = []
    for k in range(w):
        seg = mask_blocks[..., 32 * k : min(32 * (k + 1), m)]
        weights = jnp.left_shift(
            jnp.uint32(1), jnp.arange(seg.shape[-1], dtype=jnp.uint32)
        )
        segs.append(
            jnp.sum(jnp.where(seg, weights, jnp.uint32(0)), axis=-1,
                    dtype=jnp.uint32)
        )
    return segs[0] if w == 1 else jnp.stack(segs, axis=-1)


def unpack_rows(words: jnp.ndarray, m: int) -> jnp.ndarray:
    """Inverse of :func:`pack_rows`: row words -> (..., M, M) bool."""
    w = words_per_row(m)
    if w == 1:
        shifts = jnp.arange(m, dtype=jnp.uint32)
        return (
            jnp.right_shift(words[..., None], shifts) & jnp.uint32(1)
        ).astype(bool)
    cols = []
    for k in range(w):
        width = min(32, m - 32 * k)
        shifts = jnp.arange(width, dtype=jnp.uint32)
        cols.append(
            (jnp.right_shift(words[..., k, None], shifts) & jnp.uint32(1))
            .astype(bool)
        )
    return jnp.concatenate(cols, axis=-1)


def pack_rows_np(mask_blocks: np.ndarray) -> np.ndarray:
    """Host-side twin of :func:`pack_rows`."""
    mask_blocks = np.asarray(mask_blocks, bool)
    m = mask_blocks.shape[-1]
    w = words_per_row(m)
    segs = []
    for k in range(w):
        seg = mask_blocks[..., 32 * k : min(32 * (k + 1), m)]
        weights = np.left_shift(
            np.uint32(1), np.arange(seg.shape[-1], dtype=np.uint32)
        )
        segs.append(
            np.sum(np.where(seg, weights, np.uint32(0)), axis=-1,
                   dtype=np.uint32)
        )
    return segs[0] if w == 1 else np.stack(segs, axis=-1)


def unpack_rows_np(words: np.ndarray, m: int) -> np.ndarray:
    """Host-side twin of :func:`unpack_rows`."""
    words = np.asarray(words, np.uint32)
    w = words_per_row(m)
    if w == 1:
        shifts = np.arange(m, dtype=np.uint32)
        return ((words[..., None] >> shifts) & np.uint32(1)).astype(bool)
    cols = []
    for k in range(w):
        width = min(32, m - 32 * k)
        shifts = np.arange(width, dtype=np.uint32)
        cols.append(
            ((words[..., k, None] >> shifts) & np.uint32(1)).astype(bool)
        )
    return np.concatenate(cols, axis=-1)
