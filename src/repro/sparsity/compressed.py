"""Compressed storage for (transposable) N:M sparse weights on TPU.

Layout: a dense weight W of shape (K, F) with N:M sparsity along K (each
column keeps at most N of every M consecutive rows) is stored as

    values  : (K/M, N, F)  weight dtype (bf16/f32)
    indices : (K/M, N, F)  int8 — position of each kept value inside its
                            M-group (0..M-1); slots beyond the group's
                            nonzero count hold index -1 with value 0, so
                            dead slots are never scattered on decompress
                            and never gather gradient on the backward pass.

HBM traffic ratio vs dense: (N*bytes_w + N) / (M*bytes_w) — e.g. 0.375x for
8:32 bf16, 0.75x for 16:32 bf16.  With a *transposable* mask the same buffer
serves both W·x and Wᵀ·g (the Pallas kernel decompresses the transposed tile),
which is the paper's training-time benefit restated for TPU (DESIGN.md §2).
"""
from __future__ import annotations

import jax.numpy as jnp


def compress_nm(
    w: jnp.ndarray, mask: jnp.ndarray, n: int, m: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compress masked weights to (values, indices).

    Requires every (M-group, column) to contain at most N mask entries.
    """
    k, f = w.shape
    assert k % m == 0, (k, m)
    g = k // m
    wm = jnp.where(mask, w, 0).reshape(g, m, f)
    mk = mask.reshape(g, m, f)
    # Stable order: selected positions first (ascending), then the rest.
    order = jnp.argsort(jnp.where(mk, 0, 1), axis=1, stable=True)  # (g, m, f)
    idx = order[:, :n, :].astype(jnp.int8)
    vals = jnp.take_along_axis(wm, idx.astype(jnp.int32), axis=1)
    # Zero out slots that exceeded the group's nonzero count.
    counts = mk.sum(axis=1, keepdims=True)  # (g, 1, f)
    slot = jnp.arange(n)[None, :, None]
    live = slot < counts
    vals = jnp.where(live, vals, 0).astype(w.dtype)
    idx = jnp.where(live, idx, -1).astype(jnp.int8)
    return vals, idx


def decompress_nm(vals: jnp.ndarray, idx: jnp.ndarray, m: int) -> jnp.ndarray:
    """(values, indices) -> dense (K, F).  Pure-jnp oracle used by tests."""
    g, n, f = vals.shape
    p = jnp.arange(m, dtype=jnp.int32)[None, :, None, None]  # (1, m, 1, 1)
    eq = idx.astype(jnp.int32)[:, None, :, :] == p  # (g, m, n, f)
    dense = jnp.sum(jnp.where(eq, vals[:, None, :, :].astype(jnp.float32), 0.0), axis=2)
    return dense.reshape(g * m, f).astype(vals.dtype)


def compressed_bytes(k: int, f: int, n: int, m: int, bytes_w: int = 2) -> dict:
    """HBM footprint accounting used by the roofline benchmark."""
    dense = k * f * bytes_w
    vals = (k // m) * n * f * bytes_w
    idx = (k // m) * n * f  # int8
    return {
        "dense": dense,
        "values": vals,
        "indices": idx,
        "compressed": vals + idx,
        "ratio": (vals + idx) / dense,
    }
