"""Mask application utilities for sparse training / fine-tuning.

Masks are fixed after pruning; sparse fine-tuning multiplies weights by their
mask in the forward pass (and therefore gradients are masked by the chain
rule).  ``sparsify_pytree`` walks a parameter tree and attaches N:M masks to
every 2-D weight whose both dims divide by M (embedding tables and norm/bias
vectors are exempt — paper prunes linear projections only).

Transposable mask generation routes through
:class:`repro.service.MaskService`: the whole tree is submitted first
(stacked (L, in, out) weights as ONE submission) and solved in a handful of
shape-bucketed mega-batches, instead of one dispatch per tensor per layer.
Results are bit-identical to the per-tensor ``solve_mask`` path.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.solver import SolverConfig, nm_mask
from repro.patterns import pattern_from_args
from repro.service.engine import MaskService
from repro.treepath import path_str


def apply_mask(params, masks):
    """Elementwise multiply params by masks where a mask exists (None skips)."""

    def f(p, m):
        return p if m is None else p * m.astype(p.dtype)

    return jax.tree.map(f, params, masks, is_leaf=lambda x: x is None)


def mask_sparsity(masks) -> float:
    """Fraction of zeros across all non-None masks."""
    leaves = [m for m in jax.tree.leaves(masks) if m is not None]
    total = sum(m.size for m in leaves)
    nnz = sum(int(jnp.sum(m)) for m in leaves)
    return 1.0 - nnz / max(total, 1)


def default_prunable(path: tuple, p: jnp.ndarray, m: int) -> bool:
    """Prune projection weights whose matmul dims divide M.

    Any leading stack dims are allowed: plain 2-D ``(in, out)``, scan-stacked
    3-D ``(L, in, out)``, and stacked MoE expert weights ``(L, E, in, out)``
    all qualify — only the trailing matmul dims carry the N:M constraint.
    """
    if p.ndim < 2:
        return False
    return p.shape[-2] % m == 0 and p.shape[-1] % m == 0


def sparsify_pytree(
    params,
    pattern=None,
    m=None,
    config: SolverConfig = SolverConfig(),
    *,
    n: Optional[int] = None,
    prunable: Callable = default_prunable,
    service: Optional[MaskService] = None,
):
    """Compute N:M masks for every prunable weight in a pytree.

    ``pattern`` is a :class:`~repro.patterns.PatternSpec` (or canonical
    string like ``"t2:4"``); the deprecated ``(n, m)`` argument pair still
    works.  Returns a mask pytree with ``None`` at exempt leaves.  Stacked
    (L, in, out) weights are one submission each (block batches concatenate
    across layers — TSENOR's block-batch formulation doesn't care).

    ``service``: reuse an existing :class:`MaskService` — e.g. one built with
    ``directory=`` for disk caching + journaled resume; its config takes
    precedence over ``config``.  By default an in-memory service is created
    per call.  Standard (non-transposable) patterns reduce to cheap top-N
    masks and skip the service entirely.
    """
    spec = pattern_from_args(pattern, m, None, n=n, caller="sparsify_pytree")
    flat = jax.tree_util.tree_flatten_with_path(params)

    if not spec.transposable:
        masks = []
        for path, p in flat[0]:
            if not prunable(path, p, spec.m):
                masks.append(None)
            elif p.ndim >= 3:  # stacked: (L, R, C), (L, E, R, C), ...
                flat_p = p.reshape(-1, *p.shape[-2:])
                stacked = jnp.stack([
                    nm_mask(flat_p[i], spec.n, spec.m, axis=0)
                    for i in range(flat_p.shape[0])
                ])
                masks.append(stacked.reshape(p.shape))
            else:
                masks.append(nm_mask(p, spec.n, spec.m, axis=0))
        return jax.tree_util.tree_unflatten(flat[1], masks)

    svc = service if service is not None else MaskService(config)
    handles = []
    for path, p in flat[0]:
        if not prunable(path, p, spec.m):
            handles.append(None)
            continue
        handles.append(svc.submit(path_str(path), p, spec))
    svc.flush()  # everything dispatches as shape-bucketed mega-batches
    masks = [None if h is None else h.result() for h in handles]
    return jax.tree_util.tree_unflatten(flat[1], masks)
