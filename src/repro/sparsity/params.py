"""SparseParams: compressed transposable-N:M parameters as first-class pytrees.

:class:`NMCompressed` wraps one pruned projection in the ``(values, indices)``
layout of :mod:`repro.sparsity.compressed` and registers it as a JAX pytree
node, so a parameter tree whose pruned leaves are ``NMCompressed`` — a
*SparseParams* tree — flows through ``jit``/``grad``/``lax.scan``/checkpoint
flattening exactly like a dense tree:

* ``values``/``indices`` are the pytree children; the group size ``m`` is
  static aux data, so per-layer slicing (``tree.map(lambda a: a[l], blocks)``)
  and ``lax.scan`` over scan-stacked ``(L, G, N, F)`` buffers both work.
* ``jax.grad(..., allow_int=True)`` produces cotangents for ``values`` only
  (``indices`` come back as size-0 ``float0`` placeholders), which is what
  makes optimizer state land on the compressed shapes — N/M of the dense
  moment memory.
* model layers dispatch per-leaf (:func:`repro.models.layers.proj`): a dense
  leaf hits the MXU as a plain matmul, an ``NMCompressed`` leaf goes through
  :func:`repro.kernels.nm_spmm.ops.nm_linear_nd` — ONE compressed buffer
  serving both ``X·W`` and the transposed backward ``dY·Wᵀ`` (the
  transposable-mask training claim, DESIGN.md §2).

``compress_params`` converts ``(params, masks)`` into a SparseParams tree;
``decompress_params`` is the exact inverse (bit-identical dense weights — the
oracle the train/serve bit-identity tests rely on).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.tree_util import GetAttrKey, register_pytree_with_keys_class

from repro.patterns import PatternSpec
from repro.sparsity.compressed import compress_nm, decompress_nm
from repro.treepath import path_entry_str, path_str


@register_pytree_with_keys_class
class NMCompressed:
    """One compressed N:M projection: ``values``/``indices`` of shape
    ``(G, N, F)`` (or scan-stacked ``(L, G, N, F)``), group size ``m``.

    The dense equivalent is ``(..., G*m, F)``; ``decompress()`` materializes
    it (tests/checkpoint templates only — execution stays compressed).
    """

    __slots__ = ("values", "indices", "m")

    def __init__(self, values, indices, m: int):
        self.values = values
        self.indices = indices
        self.m = int(m)

    # -- pytree protocol ----------------------------------------------------

    def tree_flatten_with_keys(self):
        return (
            (GetAttrKey("values"), self.values),
            (GetAttrKey("indices"), self.indices),
        ), self.m

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    # -- convenience --------------------------------------------------------

    @property
    def n(self) -> int:
        return self.values.shape[-2]

    @property
    def dense_shape(self) -> tuple:
        lead = self.values.shape[:-3]
        g, _n, f = self.values.shape[-3:]
        return (*lead, g * self.m, f)

    @property
    def dtype(self):
        return self.values.dtype

    def decompress(self) -> jnp.ndarray:
        """Dense ``(..., K, F)`` weights (zeros off-support), bit-exact."""
        if self.values.ndim > 3:  # stacked: (L, G, N, F), (L, E, G, N, F), ...
            lead = self.values.shape[:-3]
            v = self.values.reshape(-1, *self.values.shape[-3:])
            i = self.indices.reshape(-1, *self.indices.shape[-3:])
            out = jax.vmap(lambda vi, ii: decompress_nm(vi, ii, self.m))(v, i)
            return out.reshape(*lead, *out.shape[-2:])
        return decompress_nm(self.values, self.indices, self.m)

    def nbytes(self) -> int:
        return int(self.values.nbytes) + int(self.indices.nbytes)

    def __repr__(self) -> str:  # shapes may be abstract under tracing
        try:
            shape = tuple(self.dense_shape)
        except Exception:
            shape = "?"
        return (
            f"NMCompressed({self.n}:{self.m}, dense_shape={shape}, "
            f"dtype={getattr(self.values, 'dtype', '?')})"
        )


def _is_compressed_leaf(x) -> bool:
    return isinstance(x, NMCompressed)


def is_sparse_params(tree) -> bool:
    """True if any leaf of ``tree`` is an :class:`NMCompressed` buffer."""
    return any(
        _is_compressed_leaf(leaf)
        for leaf in jax.tree.leaves(tree, is_leaf=_is_compressed_leaf)
    )


def compress_leaf(w: jnp.ndarray, mask: jnp.ndarray, pattern) -> NMCompressed:
    """Compress one 2-D ``(K, F)`` weight or a stacked one with any leading
    dims — scan-stacked ``(L, K, F)``, stacked MoE experts ``(L, E, K, F)``."""
    spec = PatternSpec.coerce(pattern)
    k = w.shape[-2]
    if k % spec.m != 0:
        raise ValueError(
            f"cannot compress shape {tuple(w.shape)} with M={spec.m}: the "
            f"reduction dim ({k}) must be a multiple of M — the (values, "
            "indices) layout has no partial groups (the mask solve pads, "
            "compressed storage cannot)"
        )
    if w.ndim > 2:
        lead = w.shape[:-2]
        wf = w.reshape(-1, *w.shape[-2:])
        mf = mask.astype(bool).reshape(-1, *mask.shape[-2:])
        vals, idx = jax.vmap(
            lambda wi, mi: compress_nm(wi, mi, spec.n, spec.m)
        )(wf, mf)
        vals = vals.reshape(*lead, *vals.shape[-3:])
        idx = idx.reshape(*lead, *idx.shape[-3:])
    else:
        vals, idx = compress_nm(w, mask.astype(bool), spec.n, spec.m)
    return NMCompressed(vals, idx, spec.m)


# Projection leaves the model layers actually dispatch through
# :func:`repro.models.layers.proj` (incl. the MoE expert einsums and the
# Mamba in/out projections) — only these may be compressed.  The embedding
# table (consumed by ``jnp.take``) and the unembedding/logit matmul stay
# dense even when a mask exists for them.
PROJ_KEYS = frozenset(
    {"wq", "wk", "wv", "wo", "gate", "up", "down", "in_proj", "out_proj"}
)


def default_compressible(path, p) -> bool:
    """True for leaves executed through the compressed-matmul dispatch."""
    return bool(path) and path_entry_str(path[-1]) in PROJ_KEYS


def projection_prunable(path, p, m: int) -> bool:
    """A ``sparsify_pytree(prunable=...)`` predicate matching the compressed
    execution surface: projection leaves only (no embed/unembed), with both
    matmul dims divisible by M."""
    from repro.sparsity.masks import default_prunable

    return default_compressible(path, p) and default_prunable(path, p, m)


def compress_params(params, masks, pattern, compressible=None,
                    strict: bool = True) -> dict:
    """``(params, masks) -> SparseParams``: every *compressible* leaf with a
    mask becomes an :class:`NMCompressed` buffer; the rest stay dense.

    ``compressible(path, leaf)`` defaults to :func:`default_compressible`
    (the projection matmuls the model dispatches through ``proj``).  Requires
    a *transposable* pattern — the compressed buffer serves both the forward
    and the transposed backward matmul, which only holds when the transposed
    mask is N:M too.

    ``strict`` (default) raises if a mask exists for a leaf the predicate
    rejects: such a mask would be silently *dropped*, and under
    ``mask_mode="compressed"`` (no mask application, no re-projection) that
    leaf's support would drift after the first optimizer step.  Solve masks
    with ``prunable=projection_prunable`` so the mask tree matches the
    compressed execution surface, or pass ``strict=False`` to knowingly
    keep those leaves dense *and unmasked*.
    """
    spec = PatternSpec.coerce(pattern)
    if not spec.transposable:
        raise ValueError(
            "compress_params needs a transposable pattern: the same buffer "
            f"must serve W and W^T (got {spec})"
        )
    comp = compressible if compressible is not None else default_compressible
    dropped: list[str] = []

    def f(path, p, mk):
        if mk is None:
            return p
        if not comp(path, p):
            dropped.append(path_str(path))
            return p
        return compress_leaf(p, mk, spec)

    out = jax.tree_util.tree_map_with_path(
        f, params, masks, is_leaf=lambda x: x is None
    )
    if dropped and strict:
        raise ValueError(
            "compress_params: masks exist for leaves the compressible "
            f"predicate rejects ({', '.join(sorted(dropped))}); their "
            "sparsity would be silently lost under mask_mode='compressed'. "
            "Solve masks with prunable=projection_prunable, pass a custom "
            "compressible=, or strict=False to keep them dense+unmasked."
        )
    return out


def decompress_params(params):
    """SparseParams -> dense params (exact inverse of ``compress_params``)."""
    return jax.tree.map(
        lambda x: x.decompress() if _is_compressed_leaf(x) else x,
        params,
        is_leaf=_is_compressed_leaf,
    )


def remap_slots(slots: jnp.ndarray, old_idx: jnp.ndarray,
                new_idx: jnp.ndarray, m: int) -> jnp.ndarray:
    """Carry per-slot data across a support swap.

    ``slots`` is any array living on the compressed slot layout — trained
    values, AdamW moments, error-feedback residuals — shaped ``(G, N, F)``
    (or scan-stacked ``(L, G, N, F)``), aligned with ``old_idx``.  Returns
    the same data re-laid-out on ``new_idx``'s layout (possibly a different
    N): a new slot holding a dense position that was live under the old
    support inherits that position's value; a position that just *entered*
    the support gets 0; dead slots (``new_idx == -1``) stay 0.
    """
    if slots.ndim > 3:  # stacked: flatten leading dims, recurse per matrix
        lead = slots.shape[:-3]
        out = jax.vmap(lambda s, o, ni: remap_slots(s, o, ni, m))(
            slots.reshape(-1, *slots.shape[-3:]),
            old_idx.reshape(-1, *old_idx.shape[-3:]),
            new_idx.reshape(-1, *new_idx.shape[-3:]),
        )
        return out.reshape(*lead, *out.shape[-3:])
    dense = decompress_nm(slots, old_idx, m)           # (G*m, F), zeros off-support
    g, _n, f = slots.shape
    dense = dense.reshape(g, m, f)
    safe = jnp.clip(new_idx.astype(jnp.int32), 0, m - 1)
    out = jnp.take_along_axis(dense, safe, axis=1)
    return jnp.where(new_idx >= 0, out, 0).astype(slots.dtype)


def remap_tree(tree, old_params, new_params):
    """Relay a params-shaped auxiliary tree across a support swap.

    ``tree`` mirrors a SparseParams tree's structure with per-slot data in
    place of the values — AdamW moments, error-feedback residuals — so each
    compressed position's data arrives wrapped in an :class:`NMCompressed`
    node (whose ``indices`` child is whatever placeholder the owner
    allocated; it is preserved).  Slots follow their dense positions from
    ``old_params``'s indices to ``new_params``'s: survivors carry their
    data, entering positions get 0, leaving positions drop.  Dense leaves
    pass through untouched.
    """

    def f(old, new, aux):
        if not _is_compressed_leaf(old):
            return aux
        if not _is_compressed_leaf(new):
            raise ValueError(
                "remap_tree: a compressed leaf became dense — support swaps "
                "must keep the compressed surface fixed"
            )
        if old.m != new.m:
            raise ValueError(
                f"remap_tree: group size changed ({old.m} -> {new.m}); a "
                "sparsity schedule may decay N but never M"
            )
        return NMCompressed(
            remap_slots(aux.values, old.indices, new.indices, old.m),
            aux.indices, aux.m,
        )

    return jax.tree.map(f, old_params, new_params, tree,
                        is_leaf=_is_compressed_leaf)


def recompress(params, masks, pattern, strict: bool = True, dense_ref=None):
    """Support-swap a live SparseParams tree onto a new mask tree.

    The DST primitive (see ``docs/architecture.md`` "Dynamic sparse
    training"): every :class:`NMCompressed` leaf with a mask in ``masks`` is
    re-compressed under that mask — surviving dense positions carry their
    trained values, positions entering the support start at 0 (or at
    ``dense_ref``'s value when a dense reference tree is passed), positions
    leaving the support are dropped.  Dense leaves and compressed leaves
    whose mask is ``None`` pass through untouched.

    Bit-identity contract (property-tested in ``tests/test_dst.py``):
    ``recompress(sp, masks, pat)`` equals
    ``compress_params(decompress_params(sp), masks, pat)`` exactly — a
    support swap is indistinguishable from a fresh compression of the
    decompressed weights under the same mask.

    ``strict`` (default) raises if a mask exists for a leaf that is *not*
    compressed (same support-drift guard as :func:`compress_params`: under
    ``mask_mode="compressed"`` that mask would be silently dropped).

    Returns ``(new_params, stats)`` where ``stats`` maps each swapped leaf's
    path to its churn telemetry (see
    :func:`repro.dst.telemetry.mask_flip_stats`).
    """
    spec = PatternSpec.coerce(pattern)
    if not spec.transposable:
        raise ValueError(
            "recompress needs a transposable pattern: the compressed buffer "
            f"must keep serving W and W^T (got {spec})"
        )
    from repro.dst.telemetry import mask_flip_stats

    dropped: list[str] = []
    stats: dict[str, dict] = {}
    ref_flat = None
    if dense_ref is not None:
        ref_flat = {
            path_str(p): leaf
            for p, leaf in jax.tree_util.tree_flatten_with_path(
                dense_ref, is_leaf=_is_compressed_leaf
            )[0]
        }

    def f(path, p, mk):
        if mk is None:
            return p
        if not _is_compressed_leaf(p):
            dropped.append(path_str(path))
            return p
        old_mask = NMCompressed(
            jnp.ones_like(p.values), p.indices, p.m
        ).decompress().astype(bool)
        base = p.decompress()
        if ref_flat is not None:
            ref = ref_flat.get(path_str(path))
            if ref is not None and not _is_compressed_leaf(ref):
                # New slots adopt the reference's dense value instead of 0.
                base = jnp.where(old_mask, base, ref.astype(base.dtype))
        new = compress_leaf(base, mk, spec)
        stats[path_str(path)] = mask_flip_stats(old_mask, mk)
        return new

    out = jax.tree_util.tree_map_with_path(
        f, params, masks, is_leaf=lambda x: x is None or _is_compressed_leaf(x)
    )
    if dropped and strict:
        raise ValueError(
            "recompress: masks exist for non-compressed leaves "
            f"({', '.join(sorted(dropped))}); their sparsity would be "
            "silently lost under mask_mode='compressed'.  Solve masks over "
            "the compressed leaves only, or pass strict=False to knowingly "
            "leave those leaves dense+unmasked."
        )
    return out, stats


def sparse_param_bytes(params) -> dict:
    """HBM footprint of a (possibly mixed) parameter tree.

    Returns ``{"dense": ..., "compressed": ..., "total": ..., "ratio": ...}``
    where ``dense`` is what the compressed leaves would occupy decompressed,
    ``compressed`` what they actually occupy, ``total`` the whole tree as
    stored, and ``ratio`` compressed/dense over the compressed leaves only
    (the number the ``compressed_bytes`` analytic model predicts).
    """
    dense_equiv = compressed = other = 0
    for leaf in jax.tree.leaves(params, is_leaf=_is_compressed_leaf):
        if _is_compressed_leaf(leaf):
            k = 1
            for d in leaf.dense_shape:
                k *= int(d)
            dense_equiv += k * leaf.values.dtype.itemsize
            compressed += leaf.nbytes()
        else:
            other += int(leaf.nbytes)
    return {
        "dense": dense_equiv,
        "compressed": compressed,
        "other": other,
        "total": compressed + other,
        "ratio": compressed / dense_equiv if dense_equiv else 1.0,
    }


def masks_from_params(params):
    """Recover the boolean mask tree encoded by a SparseParams tree's
    indices (``None`` at dense leaves) — useful for switching a compressed
    run back to ``mask_mode="fwd"``/``"post"`` without re-solving."""

    def f(x) -> Optional[jnp.ndarray]:
        if not _is_compressed_leaf(x):
            return None
        ones = NMCompressed(jnp.ones_like(x.values), x.indices, x.m)
        return ones.decompress().astype(bool)

    return jax.tree.map(f, params, is_leaf=_is_compressed_leaf)
