"""SparseParams: compressed transposable-N:M parameters as first-class pytrees.

:class:`NMCompressed` wraps one pruned projection in the ``(values, indices)``
layout of :mod:`repro.sparsity.compressed` and registers it as a JAX pytree
node, so a parameter tree whose pruned leaves are ``NMCompressed`` — a
*SparseParams* tree — flows through ``jit``/``grad``/``lax.scan``/checkpoint
flattening exactly like a dense tree:

* ``values``/``indices`` are the pytree children; the group size ``m`` is
  static aux data, so per-layer slicing (``tree.map(lambda a: a[l], blocks)``)
  and ``lax.scan`` over scan-stacked ``(L, G, N, F)`` buffers both work.
* ``jax.grad(..., allow_int=True)`` produces cotangents for ``values`` only
  (``indices`` come back as size-0 ``float0`` placeholders), which is what
  makes optimizer state land on the compressed shapes — N/M of the dense
  moment memory.
* model layers dispatch per-leaf (:func:`repro.models.layers.proj`): a dense
  leaf hits the MXU as a plain matmul, an ``NMCompressed`` leaf goes through
  :func:`repro.kernels.nm_spmm.ops.nm_linear_nd` — ONE compressed buffer
  serving both ``X·W`` and the transposed backward ``dY·Wᵀ`` (the
  transposable-mask training claim, DESIGN.md §2).

``compress_params`` converts ``(params, masks)`` into a SparseParams tree;
``decompress_params`` is the exact inverse (bit-identical dense weights — the
oracle the train/serve bit-identity tests rely on).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.tree_util import GetAttrKey, register_pytree_with_keys_class

from repro.patterns import PatternSpec
from repro.sparsity.compressed import compress_nm, decompress_nm
from repro.treepath import path_entry_str, path_str


@register_pytree_with_keys_class
class NMCompressed:
    """One compressed N:M projection: ``values``/``indices`` of shape
    ``(G, N, F)`` (or scan-stacked ``(L, G, N, F)``), group size ``m``.

    The dense equivalent is ``(..., G*m, F)``; ``decompress()`` materializes
    it (tests/checkpoint templates only — execution stays compressed).
    """

    __slots__ = ("values", "indices", "m")

    def __init__(self, values, indices, m: int):
        self.values = values
        self.indices = indices
        self.m = int(m)

    # -- pytree protocol ----------------------------------------------------

    def tree_flatten_with_keys(self):
        return (
            (GetAttrKey("values"), self.values),
            (GetAttrKey("indices"), self.indices),
        ), self.m

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    # -- convenience --------------------------------------------------------

    @property
    def n(self) -> int:
        return self.values.shape[-2]

    @property
    def dense_shape(self) -> tuple:
        lead = self.values.shape[:-3]
        g, _n, f = self.values.shape[-3:]
        return (*lead, g * self.m, f)

    @property
    def dtype(self):
        return self.values.dtype

    def decompress(self) -> jnp.ndarray:
        """Dense ``(..., K, F)`` weights (zeros off-support), bit-exact."""
        if self.values.ndim == 4:  # scan-stacked (L, G, N, F)
            return jax.vmap(lambda v, i: decompress_nm(v, i, self.m))(
                self.values, self.indices
            )
        return decompress_nm(self.values, self.indices, self.m)

    def nbytes(self) -> int:
        return int(self.values.nbytes) + int(self.indices.nbytes)

    def __repr__(self) -> str:  # shapes may be abstract under tracing
        try:
            shape = tuple(self.dense_shape)
        except Exception:
            shape = "?"
        return (
            f"NMCompressed({self.n}:{self.m}, dense_shape={shape}, "
            f"dtype={getattr(self.values, 'dtype', '?')})"
        )


def _is_compressed_leaf(x) -> bool:
    return isinstance(x, NMCompressed)


def is_sparse_params(tree) -> bool:
    """True if any leaf of ``tree`` is an :class:`NMCompressed` buffer."""
    return any(
        _is_compressed_leaf(leaf)
        for leaf in jax.tree.leaves(tree, is_leaf=_is_compressed_leaf)
    )


def compress_leaf(w: jnp.ndarray, mask: jnp.ndarray, pattern) -> NMCompressed:
    """Compress one 2-D ``(K, F)`` or scan-stacked 3-D ``(L, K, F)`` weight."""
    spec = PatternSpec.coerce(pattern)
    k = w.shape[-2]
    if k % spec.m != 0:
        raise ValueError(
            f"cannot compress shape {tuple(w.shape)} with M={spec.m}: the "
            f"reduction dim ({k}) must be a multiple of M — the (values, "
            "indices) layout has no partial groups (the mask solve pads, "
            "compressed storage cannot)"
        )
    if w.ndim == 3:
        vals, idx = jax.vmap(
            lambda wi, mi: compress_nm(wi, mi, spec.n, spec.m)
        )(w, mask.astype(bool))
    else:
        vals, idx = compress_nm(w, mask.astype(bool), spec.n, spec.m)
    return NMCompressed(vals, idx, spec.m)


# Projection leaves the model layers actually dispatch through
# :func:`repro.models.layers.proj` — only these may be compressed.  The
# embedding table (consumed by ``jnp.take``) and the unembedding/logit
# matmul stay dense even when a mask exists for them.
PROJ_KEYS = frozenset({"wq", "wk", "wv", "wo", "gate", "up", "down"})


def default_compressible(path, p) -> bool:
    """True for leaves executed through the compressed-matmul dispatch."""
    return bool(path) and path_entry_str(path[-1]) in PROJ_KEYS


def projection_prunable(path, p, m: int) -> bool:
    """A ``sparsify_pytree(prunable=...)`` predicate matching the compressed
    execution surface: projection leaves only (no embed/unembed), with both
    matmul dims divisible by M."""
    from repro.sparsity.masks import default_prunable

    return default_compressible(path, p) and default_prunable(path, p, m)


def compress_params(params, masks, pattern, compressible=None,
                    strict: bool = True) -> dict:
    """``(params, masks) -> SparseParams``: every *compressible* leaf with a
    mask becomes an :class:`NMCompressed` buffer; the rest stay dense.

    ``compressible(path, leaf)`` defaults to :func:`default_compressible`
    (the projection matmuls the model dispatches through ``proj``).  Requires
    a *transposable* pattern — the compressed buffer serves both the forward
    and the transposed backward matmul, which only holds when the transposed
    mask is N:M too.

    ``strict`` (default) raises if a mask exists for a leaf the predicate
    rejects: such a mask would be silently *dropped*, and under
    ``mask_mode="compressed"`` (no mask application, no re-projection) that
    leaf's support would drift after the first optimizer step.  Solve masks
    with ``prunable=projection_prunable`` so the mask tree matches the
    compressed execution surface, or pass ``strict=False`` to knowingly
    keep those leaves dense *and unmasked*.
    """
    spec = PatternSpec.coerce(pattern)
    if not spec.transposable:
        raise ValueError(
            "compress_params needs a transposable pattern: the same buffer "
            f"must serve W and W^T (got {spec})"
        )
    comp = compressible if compressible is not None else default_compressible
    dropped: list[str] = []

    def f(path, p, mk):
        if mk is None:
            return p
        if not comp(path, p):
            dropped.append(path_str(path))
            return p
        return compress_leaf(p, mk, spec)

    out = jax.tree_util.tree_map_with_path(
        f, params, masks, is_leaf=lambda x: x is None
    )
    if dropped and strict:
        raise ValueError(
            "compress_params: masks exist for leaves the compressible "
            f"predicate rejects ({', '.join(sorted(dropped))}); their "
            "sparsity would be silently lost under mask_mode='compressed'. "
            "Solve masks with prunable=projection_prunable, pass a custom "
            "compressible=, or strict=False to keep them dense+unmasked."
        )
    return out


def decompress_params(params):
    """SparseParams -> dense params (exact inverse of ``compress_params``)."""
    return jax.tree.map(
        lambda x: x.decompress() if _is_compressed_leaf(x) else x,
        params,
        is_leaf=_is_compressed_leaf,
    )


def sparse_param_bytes(params) -> dict:
    """HBM footprint of a (possibly mixed) parameter tree.

    Returns ``{"dense": ..., "compressed": ..., "total": ..., "ratio": ...}``
    where ``dense`` is what the compressed leaves would occupy decompressed,
    ``compressed`` what they actually occupy, ``total`` the whole tree as
    stored, and ``ratio`` compressed/dense over the compressed leaves only
    (the number the ``compressed_bytes`` analytic model predicts).
    """
    dense_equiv = compressed = other = 0
    for leaf in jax.tree.leaves(params, is_leaf=_is_compressed_leaf):
        if _is_compressed_leaf(leaf):
            k = 1
            for d in leaf.dense_shape:
                k *= int(d)
            dense_equiv += k * leaf.values.dtype.itemsize
            compressed += leaf.nbytes()
        else:
            other += int(leaf.nbytes)
    return {
        "dense": dense_equiv,
        "compressed": compressed,
        "other": other,
        "total": compressed + other,
        "ratio": compressed / dense_equiv if dense_equiv else 1.0,
    }


def masks_from_params(params):
    """Recover the boolean mask tree encoded by a SparseParams tree's
    indices (``None`` at dense leaves) — useful for switching a compressed
    run back to ``mask_mode="fwd"``/``"post"`` without re-solving."""

    def f(x) -> Optional[jnp.ndarray]:
        if not _is_compressed_leaf(x):
            return None
        ones = NMCompressed(jnp.ones_like(x.values), x.indices, x.m)
        return ones.decompress().astype(bool)

    return jax.tree.map(f, params, is_leaf=_is_compressed_leaf)
