"""Pallas kernels: MVU N:M gradient sparsification + compressed-x-compressed GEMM.

``nm_sparsify_pallas`` turns a dense activation-gradient tile ``dY`` into the
``(values, int8 indices)`` compressed layout ``nm_spmm`` consumes, N:M along
the *row* (token) dimension: per M-block of rows in each column, the top
``N-1`` magnitudes are kept verbatim and ONE more survivor is drawn from the
residual with probability proportional to its magnitude, rescaled so the
estimate is unbiased (Chmiel et al., "Minimum Variance Unbiased N:M Sparsity
for the Neural Gradients").  Drawing position ``j`` with ``p_j = a_j / S``
(``S`` = residual magnitude mass) and emitting ``x_j / p_j = sign(x_j) * S``
is the minimum-variance unbiased one-point estimator of the residual — see
``docs/solver_math.md`` for the derivation and the analytic variance
``a_j (S - a_j)`` the property tests pin.

Blocks with at most N nonzeros round-trip exactly (the residual holds one
nonzero, drawn with p=1 and rescaled to itself), so sparse gradients of an
already-N:M-sparse ``dY`` are bit-exact.

Randomness is **counter-based**: each (M-block row, column) hashes
``(seed, salt, block, col)`` through a murmur3-style finalizer built from
plain ``uint32`` jnp ops — no backend PRNG primitive — so interpret-mode CPU
runs and TPU runs draw the same numbers, the result is independent of the
grid tiling (counters are *global* coordinates), and a fixed seed replays
bit-identically.  ``salt`` decorrelates call sites (one per traced
projection), the layer index is folded into ``seed`` by the ops layer.

An optional stochastic cast to bf16 (``out_dtype=jnp.bfloat16``) rounds each
survivor to a neighbouring bf16 value with probability proportional to
proximity (add 16 random mantissa bits, truncate) — also unbiased, and it is
what makes the compressed-``dY`` byte ratio 3/8 of dense f32 at 8:16 instead
of 5/8 (see ``roofline.nm_grad_cost``).

``nm_spmm_cc_pallas`` is the dX GEMM with BOTH operands compressed:
``dY`` N:M along rows (pattern ``n_g:m_g``), ``W`` N:M along K (pattern
``n_w:m_w``, the transposable weight buffer).  Each grid step decompresses a
``(bt, ft)`` dY tile and a ``(kt, ft)`` W tile in VMEM and accumulates
``dot(dY, Wᵀ)`` on the MXU — dense dY never exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret
from repro.kernels.nm_spmm.kernel import (
    _decompress_tile,
    _pad_dim,
    _round_up,
)
from repro.kernels.vmem import VPU_ALIGN

_U32 = jnp.uint32


def counter_uniform(seed, salt: int, block, col, stream: int = 0):
    """Deterministic uniform in [0, 1) per (block, col) counter pair.

    ``seed`` is a traced int32 scalar; ``salt``/``stream`` are static ints
    (call site / draw index); ``block``/``col`` are int32 arrays of global
    coordinates.  murmur3-finalizer quality is plenty for rounding noise and
    — unlike ``pltpu.prng_random_bits`` — runs identically under interpret.
    """
    h = counter_bits(seed, salt, block, col, stream)
    # Top 24 bits -> [0, 1): exactly representable in f32.
    return (h >> _U32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def counter_bits(seed, salt: int, block, col, stream: int = 0):
    """The raw uint32 hash behind :func:`counter_uniform`."""
    h = block.astype(_U32) * _U32(0x9E3779B9)
    h = h ^ (col.astype(_U32) * _U32(0x85EBCA6B))
    h = h ^ (jnp.asarray(seed).astype(_U32) * _U32(0xC2B2AE35))
    h = h ^ _U32((salt * 0x27D4EB2F + stream * 0x165667B1) & 0xFFFFFFFF)
    h = h ^ (h >> _U32(16))
    h = h * _U32(0x85EBCA6B)
    h = h ^ (h >> _U32(13))
    h = h * _U32(0xC2B2AE35)
    h = h ^ (h >> _U32(16))
    return h


def _stochastic_bf16(x: jnp.ndarray, rbits: jnp.ndarray) -> jnp.ndarray:
    """Unbiased f32 -> bf16: add 16 random low bits, truncate the mantissa."""
    bits = jax.lax.bitcast_convert_type(x, _U32)
    bits = bits + (rbits & _U32(0xFFFF))
    trunc = jax.lax.bitcast_convert_type(bits & _U32(0xFFFF0000), jnp.float32)
    return trunc.astype(jnp.bfloat16)


def _mvu_select(dyb: jnp.ndarray, u: jnp.ndarray, n: int):
    """Core MVU selection on one (G, m, ft) block stack.

    Returns ``(out_dense, keep)``: the rescaled survivor values (f32, zeros
    at dropped positions) and the boolean survivor mask (<= n per (g, col)).
    Shared by the Pallas kernel and the pure-jnp oracle so the *selection*
    spec lives in exactly one place; the oracle re-derives the ranking with
    an independent argsort (see ``ref.py``).
    """
    g, m, ft = dyb.shape
    a = jnp.abs(dyb)
    # Rank by magnitude desc, position asc (stable): pairwise comparison on
    # the VPU — no in-kernel sort, Mosaic-friendly (m^2 bools per element).
    ai = a[:, :, None, :]
    aj = a[:, None, :, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (1, m, m, 1), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (1, m, m, 1), 2)
    beats = (aj > ai) | ((aj == ai) & (jj < ii))
    rank = jnp.sum(beats.astype(jnp.int32), axis=2)  # (g, m, ft)

    keep_det = (rank < n - 1) & (a > 0)
    elig = (rank >= n - 1) & (a > 0)
    a_e = jnp.where(elig, a, 0.0)
    # Position-ordered running mass; its last row is the residual mass S.
    # Deriving S from the SAME cumsum that defines the inverse-CDF intervals
    # keeps the emitted value bit-consistent with the interval endpoints
    # (a separate jnp.sum may reduce in a different order, off by an ULP —
    # and the numpy oracle could not reproduce it).
    cum = jnp.cumsum(a_e, axis=1)
    s_mass = cum[:, m - 1 : m, :]  # (g, 1, ft)

    # Inverse-CDF draw over the residual, in position order.
    t = (u * s_mass[:, 0, :])[:, None, :]  # (g, 1, ft)
    sel = elig & ((cum - a_e) <= t) & (t < cum)
    # Float rounding can make adjacent intervals overlap or leave t == S
    # uncovered: keep the first hit, else fall back to the last eligible.
    sel = sel & (jnp.cumsum(sel.astype(jnp.int32), axis=1) == 1)
    has = jnp.any(sel, axis=1)  # (g, ft)
    pos = jax.lax.broadcasted_iota(jnp.int32, (g, m, ft), 1)
    last = jnp.max(jnp.where(elig, pos, -1), axis=1)  # (g, ft)
    sel = sel | (elig & (pos == last[:, None, :]) & ~has[:, None, :])

    sgn = jnp.where(dyb >= 0, 1.0, -1.0)
    out = jnp.where(keep_det, dyb, 0.0) + jnp.where(sel, sgn * s_mass, 0.0)
    return out.astype(jnp.float32), keep_det | sel


def _pack_slots(out_dense: jnp.ndarray, keep: jnp.ndarray, n: int):
    """(G, m, ft) survivors -> (G, n, ft) slots, ascending position order,
    dead slots idx=-1/val=0 — the exact ``compress_nm`` layout."""
    g, m, ft = out_dense.shape
    r = jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1  # slot per position
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (g, n, m, ft), 1)
    eq = (r[:, None, :, :] == s_iota) & keep[:, None, :, :]
    vals = jnp.sum(jnp.where(eq, out_dense[:, None, :, :], 0.0), axis=2)
    posm = jax.lax.broadcasted_iota(jnp.int32, (g, n, m, ft), 2)
    idx = jnp.sum(jnp.where(eq, posm, 0), axis=2)
    count = jnp.sum(keep.astype(jnp.int32), axis=1)  # (g, ft)
    live = jax.lax.broadcasted_iota(jnp.int32, (g, n, ft), 1) < count[:, None, :]
    return jnp.where(live, vals, 0.0), jnp.where(live, idx, -1).astype(jnp.int8)


def _sparsify_kernel(
    seed_ref, dy_ref, vals_ref, idx_ref, *, n: int, m: int, salt: int,
    out_dtype,
):
    bt, ft = dy_ref.shape
    g = bt // m
    dyb = dy_ref[...].astype(jnp.float32).reshape(g, m, ft)
    seed = seed_ref[0]

    # GLOBAL counters -> randomness independent of the grid tiling.
    gi = jax.lax.broadcasted_iota(jnp.int32, (g, ft), 0) + pl.program_id(0) * g
    ci = jax.lax.broadcasted_iota(jnp.int32, (g, ft), 1) + pl.program_id(1) * ft
    u = counter_uniform(seed, salt, gi, ci, stream=0)

    out_dense, keep = _mvu_select(dyb, u, n)
    if jnp.dtype(out_dtype) != jnp.float32:
        ri = jax.lax.broadcasted_iota(jnp.int32, (g, m, ft), 0) * m
        ri = ri + jax.lax.broadcasted_iota(jnp.int32, (g, m, ft), 1)
        ri = ri + pl.program_id(0) * bt
        cc = jax.lax.broadcasted_iota(jnp.int32, (g, m, ft), 2)
        cc = cc + pl.program_id(1) * ft
        rbits = counter_bits(seed, salt, ri, cc, stream=1)
        out_dense = _stochastic_bf16(out_dense, rbits).astype(jnp.float32)
    vals, idx = _pack_slots(out_dense, keep, n)
    vals_ref[...] = vals.astype(out_dtype)
    idx_ref[...] = idx


def _resolve_sparsify_tiles(rows: int, f: int, m: int, bt, ft):
    if bt is None or ft is None:
        from repro.perf.table import nm_grad_tiles

        tuned = nm_grad_tiles("nm_sparsify", rows, f, f, m)
        tbt, _tkt, tft = tuned if tuned else (256, 256, 256)
        row_cap = _round_up(max(rows, 1), max(m, VPU_ALIGN))
        if bt is None:
            bt = max(m, _round_up(min(tbt, row_cap), m))
        if ft is None:
            ft = min(tft, _round_up(f, 128))
    assert bt % m == 0, (bt, m)
    return bt, ft


def nm_sparsify_pallas(
    dy: jnp.ndarray,
    n: int,
    m: int,
    seed,
    salt: int = 0,
    out_dtype=jnp.float32,
    bt: int | None = None,
    ft: int | None = None,
    interpret: bool | None = None,
):
    """Sparsify ``dy`` (R, F) to N:M along rows.

    Returns ``(values, indices)`` of shape ``(ceil(R/m), n, F)`` — rows are
    zero-padded to a whole number of M-blocks; padded rows are exact zeros
    and can never be selected, so consumers just crop output rows to R.
    ``seed`` may be a python int or a traced int32 scalar.
    """
    rows, f = dy.shape
    bt, ft = _resolve_sparsify_tiles(rows, f, m, bt, ft)
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1)
    return _nm_sparsify_call(
        seed_arr, dy, n, m, salt, jnp.dtype(out_dtype).name, bt, ft, interpret
    )


@functools.partial(
    jax.jit,
    static_argnames=("n", "m", "salt", "out_dtype", "bt", "ft", "interpret"),
)
def _nm_sparsify_call(seed_arr, dy, n, m, salt, out_dtype, bt, ft, interpret):
    if interpret is None:
        interpret = default_interpret()
    rows, f = dy.shape
    out_dtype = jnp.dtype(out_dtype)
    dyp = _pad_dim(_pad_dim(dy, 0, bt), 1, ft)
    pr, pf = dyp.shape
    grid = (pr // bt, pf // ft)
    vals, idx = pl.pallas_call(
        functools.partial(
            _sparsify_kernel, n=n, m=m, salt=salt, out_dtype=out_dtype
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bt, ft), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bt // m, n, ft), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bt // m, n, ft), lambda i, j: (i, 0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pr // m, n, pf), out_dtype),
            jax.ShapeDtypeStruct((pr // m, n, pf), jnp.int8),
        ],
        interpret=interpret,
    )(seed_arr, dyp)
    g_out = -(-rows // m)
    return vals[:g_out, :, :f], idx[:g_out, :, :f]


# ---------------------------------------------------------------------------
# Compressed x compressed: dX = dY_sparse · Wᵀ.
# ---------------------------------------------------------------------------


def _cc_kernel(gv_ref, gi_ref, wv_ref, wi_ref, o_ref, *, m_g: int, m_w: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dy = _decompress_tile(gv_ref[...], gi_ref[...], m_g)  # (bt, ft)
    w = _decompress_tile(wv_ref[...], wi_ref[...], m_w)  # (kt, ft)
    o_ref[...] += jnp.dot(dy, w.T, preferred_element_type=jnp.float32)


def _resolve_cc_tiles(b: int, k: int, f: int, m_g: int, m_w: int, bt, kt, ft):
    if bt is None or kt is None or ft is None:
        from repro.perf.table import nm_grad_tiles

        # Default row tile is 4x nm_spmm's: with BOTH operands compressed the
        # VMEM-resident tile set is tiny, and a taller dY tile divides the
        # W-operand revisit count (see roofline.nm_spmm_cc_cost).
        tuned = nm_grad_tiles("nm_spmm_cc", b, k, f, max(m_g, m_w))
        tbt, tkt, tft = tuned if tuned else (1024, 256, 256)
        if bt is None:
            row_cap = _round_up(max(b, 1), max(m_g, VPU_ALIGN))
            bt = max(m_g, _round_up(min(tbt, row_cap), m_g))
        if kt is None:
            kt = max(m_w, _round_up(min(tkt, _round_up(k, m_w)), m_w))
        if ft is None:
            ft = min(tft, _round_up(f, 128))
    assert bt % m_g == 0 and kt % m_w == 0, (bt, m_g, kt, m_w)
    return bt, kt, ft


def nm_spmm_cc_pallas(
    gvals: jnp.ndarray,
    gidx: jnp.ndarray,
    wvals: jnp.ndarray,
    widx: jnp.ndarray,
    m_g: int,
    m_w: int,
    bt: int | None = None,
    kt: int | None = None,
    ft: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """dX = decompress(dY) · decompress(W)ᵀ with both operands compressed.

    ``gvals/gidx``: (B/m_g, n_g, F) gradient compressed along rows;
    ``wvals/widx``: (K/m_w, n_w, F) weight compressed along K.  Returns
    (B, K) float32; neither dense operand ever exists outside VMEM tiles.
    """
    b = gvals.shape[0] * m_g
    k = wvals.shape[0] * m_w
    f = gvals.shape[2]
    assert wvals.shape[2] == f, (gvals.shape, wvals.shape)
    bt, kt, ft = _resolve_cc_tiles(b, k, f, m_g, m_w, bt, kt, ft)
    return _nm_spmm_cc_call(
        gvals, gidx, wvals, widx, m_g, m_w, bt, kt, ft, interpret
    )


@functools.partial(
    jax.jit,
    static_argnames=("m_g", "m_w", "bt", "kt", "ft", "interpret"),
)
def _nm_spmm_cc_call(gvals, gidx, wvals, widx, m_g, m_w, bt, kt, ft, interpret):
    if interpret is None:
        interpret = default_interpret()
    b = gvals.shape[0] * m_g
    k = wvals.shape[0] * m_w
    n_g, n_w = gvals.shape[1], wvals.shape[1]
    gv = _pad_dim(_pad_dim(gvals, 0, bt // m_g), 2, ft)
    gi = _pad_dim(_pad_dim(gidx, 0, bt // m_g), 2, ft)
    wv = _pad_dim(_pad_dim(wvals, 0, kt // m_w), 2, ft)
    wi = _pad_dim(_pad_dim(widx, 0, kt // m_w), 2, ft)
    pb = gv.shape[0] * m_g
    pk = wv.shape[0] * m_w
    pf = gv.shape[2]
    grid = (pb // bt, pk // kt, pf // ft)
    out = pl.pallas_call(
        functools.partial(_cc_kernel, m_g=m_g, m_w=m_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt // m_g, n_g, ft), lambda i, j, kk: (i, 0, kk)),
            pl.BlockSpec((bt // m_g, n_g, ft), lambda i, j, kk: (i, 0, kk)),
            pl.BlockSpec((kt // m_w, n_w, ft), lambda i, j, kk: (j, 0, kk)),
            pl.BlockSpec((kt // m_w, n_w, ft), lambda i, j, kk: (j, 0, kk)),
        ],
        out_specs=pl.BlockSpec((bt, kt), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pk), jnp.float32),
        interpret=interpret,
    )(gv, gi, wv, wi)
    return out[:b, :k]
