"""Structured-sparse backward: custom-VJP linear op + trace-time context.

``nm_linear_sg`` computes the SAME forward as
:func:`repro.kernels.nm_spmm.ops.nm_linear` (one compressed weight buffer),
but its backward sparsifies the incoming cotangent ``dY`` to N:M along rows
(``nm_sparsify_pallas``, MVU stochastic rounding) and streams the compressed
result through BOTH backward GEMMs:

  dX = compressed-dY · Wᵀ   (``nm_spmm_cc_pallas`` — both operands compressed)
  dW = Xᵀ · compressed-dY   (``nm_spmm_pallas`` with dY as the sparse operand)

Dense ``dY`` never reaches HBM-resident GEMM operands — the byte accounting
lives in ``repro.perf.roofline.nm_grad_cost``.

The gradient pattern is independent of the weight pattern (e.g. 8:16 grads
over t16:32 weights) and need not be transposable — dY is only ever consumed
in one orientation per GEMM.

Seed plumbing (``sparse_grad_context``): the train step derives one int32
seed per microbatch (step * accum + microbatch) and installs a trace-time
context around the loss; :func:`repro.models.layers.proj` consults it and
routes compressed leaves through ``nm_linear_sg_nd``.  Each traced call site
takes a fresh static ``salt``; the scanned layer index is folded into the
seed (``sparse_grad_layer`` — installed by the ``models.lm`` stack runners)
so every (layer, call site, microbatch) triple draws an independent counter
stream while remaining bit-reproducible for a fixed step.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.nm_grad.kernel import nm_sparsify_pallas, nm_spmm_cc_pallas
from repro.kernels.nm_spmm.kernel import nm_spmm_pallas
from repro.patterns import PatternSpec

_LAYER_MIX = 1000003  # odd prime; decorrelates scanned layers in the seed


@dataclasses.dataclass
class SparseGradContext:
    """Trace-time state for one loss evaluation under sparse gradients."""

    spec: PatternSpec
    seed: Any                      # int or traced int32 scalar
    dtype: str = "bfloat16"        # compressed-dY value dtype (SR cast)
    layer: Any = None              # traced layer index inside lax.scan
    _salt: int = 0

    def call_key(self):
        """(effective seed, fresh per-call-site salt) for one projection."""
        seed = jnp.asarray(self.seed, jnp.int32)
        if self.layer is not None:
            seed = seed + (jnp.asarray(self.layer, jnp.int32) + 1) * jnp.int32(
                _LAYER_MIX
            )
        salt = self._salt
        self._salt += 1
        return seed, salt


_ACTIVE: list[SparseGradContext] = []


def current_sparse_grad() -> Optional[SparseGradContext]:
    """The innermost active context, or None (dense-gradient path)."""
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def sparse_grad_context(pattern, seed, dtype=jnp.bfloat16):
    """Route every compressed ``proj`` traced inside to ``nm_linear_sg``."""
    ctx = SparseGradContext(
        PatternSpec.coerce(pattern), seed, jnp.dtype(dtype).name
    )
    _ACTIVE.append(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.pop()


@contextlib.contextmanager
def sparse_grad_layer(layer):
    """Fold a (possibly traced) layer index into the active context's seed.

    No-op when no context is active, so the model stack runners install it
    unconditionally without perturbing the dense path.
    """
    ctx = current_sparse_grad()
    if ctx is None:
        yield
        return
    prev = ctx.layer
    ctx.layer = layer
    try:
        yield
    finally:
        ctx.layer = prev


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def nm_linear_sg(x, vals, idx, seed, m, n_g, m_g, salt, grad_dtype):
    """Forward identical to ``nm_linear``; backward streams N:M-sparse dY."""
    del seed  # backward-only
    return nm_spmm_pallas(x, vals, idx, m).astype(x.dtype)


def _sg_fwd(x, vals, idx, seed, m, n_g, m_g, salt, grad_dtype):
    y = nm_spmm_pallas(x, vals, idx, m).astype(x.dtype)
    return y, (x, vals, idx, seed)


def _sg_bwd(m, n_g, m_g, salt, grad_dtype, res, dy):
    x, vals, idx, seed = res
    rows = dy.shape[0]
    gvals, gidx = nm_sparsify_pallas(
        dy, n_g, m_g, seed, salt=salt, out_dtype=jnp.dtype(grad_dtype)
    )
    rp = gvals.shape[0] * m_g  # rows padded to whole M-blocks

    # dX: both operands compressed; crop the row padding back off.
    dx = nm_spmm_cc_pallas(gvals, gidx, vals, idx, m_g, m)[:rows]

    # dW restricted to the weight support, with compressed dY as the sparse
    # operand (reduction over the padded rows; pad X to match — zero rows
    # contribute exactly nothing).
    xp = x.astype(jnp.float32)
    if rp != rows:
        xp = jnp.pad(xp, ((0, rp - rows), (0, 0)))
    dw = nm_spmm_pallas(xp.T, gvals, gidx, m_g)  # (K, F) dense-on-support
    g, _n, f = vals.shape
    dwg = dw.reshape(g, m, f)
    gathered = jnp.take_along_axis(
        dwg, jnp.maximum(idx.astype(jnp.int32), 0), axis=1
    )
    dvals = jnp.where(idx >= 0, gathered, 0.0).astype(vals.dtype)
    return dx.astype(x.dtype), dvals, None, None


nm_linear_sg.defvjp(_sg_fwd, _sg_bwd)


def nm_linear_sg_nd(x, vals, idx, m, ctx: SparseGradContext):
    """``nm_linear_sg`` over activations with arbitrary leading dims."""
    seed, salt = ctx.call_key()
    lead = x.shape[:-1]
    y = nm_linear_sg(
        x.reshape(-1, x.shape[-1]), vals, idx, seed,
        m, ctx.spec.n, ctx.spec.m, salt, ctx.dtype,
    )
    return y.reshape(*lead, y.shape[-1])
