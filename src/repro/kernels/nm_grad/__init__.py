"""In-flight N:M sparsification of activation gradients (MVU rounding).

Kernel/ref/ops triple, same layout as ``repro.kernels.nm_spmm``:

* :mod:`kernel` — Pallas kernels: ``nm_sparsify_pallas`` (top-(N-1) +
  minimum-variance-unbiased stochastic survivor per M-block, counter-based
  PRNG) and ``nm_spmm_cc_pallas`` (both operands compressed).
* :mod:`ref` — pure-jnp oracles + the analytic MVU variance.
* :mod:`ops` — ``nm_linear_sg`` custom-VJP and the trace-time
  ``sparse_grad_context`` that :func:`repro.models.layers.proj` consults.
"""
