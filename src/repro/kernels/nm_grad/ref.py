"""Pure-jnp oracles for the MVU gradient-sparsify + cc-GEMM kernels.

``nm_sparsify_ref`` re-derives the survivor set with an *independent*
implementation (stable argsort ranking instead of the kernel's pairwise
comparison network; gather-based slot packing instead of one-hot sums) while
sharing only the counter-PRNG spec (:func:`..kernel.counter_uniform`) — the
randomness is part of the op's contract, the selection logic is what the
oracle cross-checks.

``mvu_variance_ref`` is the analytic per-element variance of the estimator,
``a_j (S - a_j)`` on residual positions and 0 on deterministic ones (see
``docs/solver_math.md``) — the bound the property tests compare Monte-Carlo
variance against.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.nm_grad.kernel import counter_bits, counter_uniform
from repro.sparsity.compressed import decompress_nm


def _rank_desc_stable(a: np.ndarray, axis: int) -> np.ndarray:
    """rank[i] = position of i in a stable descending sort along ``axis``."""
    order = np.argsort(-a, axis=axis, kind="stable")
    return np.argsort(order, axis=axis, kind="stable")


def nm_sparsify_ref(dy, n: int, m: int, seed, salt: int = 0,
                    out_dtype=jnp.float32):
    """Oracle for ``nm_sparsify_pallas``: same (values, indices) bit-layout.

    numpy implementation over (ceil(R/m), m, F) blocks; rows are zero-padded
    to whole M-blocks exactly like the kernel.
    """
    dy = np.asarray(dy, np.float32)
    rows, f = dy.shape
    g = -(-rows // m)
    pad = g * m - rows
    if pad:
        dy = np.concatenate([dy, np.zeros((pad, f), np.float32)])
    dyb = dy.reshape(g, m, f)
    a = np.abs(dyb)

    rank = _rank_desc_stable(a, axis=1)
    keep_det = (rank < n - 1) & (a > 0)
    elig = (rank >= n - 1) & (a > 0)
    a_e = np.where(elig, a, 0.0)
    # The position-ordered running mass (S = last row) is part of the op's
    # bit-contract, like the counter PRNG: XLA's scan associates additions
    # differently from np.cumsum (ULP-level), which would shift S and could
    # even flip a draw landing within ULPs of an interval boundary — so the
    # oracle shares the scan primitive and re-derives everything else.
    cum = np.asarray(jnp.cumsum(jnp.asarray(a_e, jnp.float32), axis=1))
    s_mass = cum[:, -1:, :]

    gi = np.broadcast_to(np.arange(g)[:, None], (g, f)).astype(np.int32)
    ci = np.broadcast_to(np.arange(f)[None, :], (g, f)).astype(np.int32)
    u = np.asarray(counter_uniform(
        jnp.asarray(seed, jnp.int32), salt, jnp.asarray(gi), jnp.asarray(ci)
    ))

    t = (u * s_mass[:, 0, :])[:, None, :]
    sel = elig & ((cum - a_e) <= t) & (t < cum)
    sel &= np.cumsum(sel, axis=1) == 1
    has = sel.any(axis=1)
    pos = np.broadcast_to(np.arange(m)[None, :, None], (g, m, f))
    last = np.max(np.where(elig, pos, -1), axis=1)
    sel |= elig & (pos == last[:, None, :]) & ~has[:, None, :]

    out = np.where(keep_det, dyb, 0.0) + np.where(
        sel, np.where(dyb >= 0, 1.0, -1.0) * s_mass, 0.0
    )
    if jnp.dtype(out_dtype) != jnp.float32:
        ri = (np.arange(g * m)[:, None] + np.zeros((1, f))).astype(np.int32)
        cc = (np.zeros((g * m, 1)) + np.arange(f)[None, :]).astype(np.int32)
        rbits = np.asarray(counter_bits(
            jnp.asarray(seed, jnp.int32), salt,
            jnp.asarray(ri), jnp.asarray(cc), stream=1,
        )).reshape(g, m, f)
        bits = out.astype(np.float32).view(np.uint32)
        bits = bits + (rbits & np.uint32(0xFFFF))
        out = (bits & np.uint32(0xFFFF0000)).view(np.float32)
    keep = keep_det | sel

    # Independent packing: gather kept positions in ascending order.
    vals = np.zeros((g, n, f), np.float32)
    idx = np.full((g, n, f), -1, np.int8)
    for gg in range(g):
        for ff in range(f):
            where = np.nonzero(keep[gg, :, ff])[0]
            assert len(where) <= n, (gg, ff, where)
            vals[gg, : len(where), ff] = out[gg, where, ff]
            idx[gg, : len(where), ff] = where.astype(np.int8)
    return (jnp.asarray(vals).astype(out_dtype), jnp.asarray(idx))


def mvu_variance_ref(dy, n: int, m: int) -> np.ndarray:
    """Analytic per-element variance of the MVU estimator, shape = dy.shape.

    Residual position j (not among the top N-1 magnitudes): Var = a_j(S-a_j);
    deterministic survivors and zeros: Var = 0.  Exact in infinite precision;
    the Monte-Carlo property test budgets its own sampling error on top.
    """
    dy = np.asarray(dy, np.float32)
    rows, f = dy.shape
    assert rows % m == 0
    a = np.abs(dy.reshape(-1, m, f))
    rank = _rank_desc_stable(a, axis=1)
    elig = (rank >= n - 1) & (a > 0)
    a_e = np.where(elig, a, 0.0)
    s_mass = a_e.sum(axis=1, keepdims=True)
    var = np.where(elig, a_e * np.maximum(s_mass - a_e, 0.0), 0.0)
    return var.reshape(rows, f)


def nm_spmm_cc_ref(gvals, gidx, wvals, widx, m_g: int, m_w: int):
    """Oracle for the compressed-x-compressed GEMM: decompress both, f32."""
    dy = decompress_nm(gvals, gidx, m_g).astype(jnp.float32)  # (B, F)
    w = decompress_nm(wvals, widx, m_w).astype(jnp.float32)  # (K, F)
    return dy @ w.T
