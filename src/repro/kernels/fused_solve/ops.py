"""Public jit'd wrappers for the fused single-pass solve kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fused_solve.kernel import fused_block_b, fused_solve_pallas
from repro.sparsity.bitpack import unpack_rows

__all__ = ["fused_solve", "fused_solve_masks", "fused_block_b"]


def fused_solve(
    w_abs_blocks: jnp.ndarray, n: int, **kw
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B, M, M) |W| -> ((B, M) uint32 packed mask rows, per-tile iters)."""
    return fused_solve_pallas(w_abs_blocks, n, **kw)


def fused_solve_masks(w_abs_blocks: jnp.ndarray, n: int, **kw) -> jnp.ndarray:
    """Convenience: fused solve returning unpacked (B, M, M) bool masks."""
    words, _ = fused_solve_pallas(w_abs_blocks, n, **kw)
    return unpack_rows(words, w_abs_blocks.shape[-1])
