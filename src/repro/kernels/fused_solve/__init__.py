from repro.kernels.fused_solve.ops import (  # noqa: F401
    fused_block_b,
    fused_solve,
    fused_solve_masks,
)
