"""Single-pass fused TSENOR block solve (Algorithms 1 + 2) for TPU.

The ``dense-jit`` / ``pallas`` pipelines pay three HBM round-trips per block
batch: the Dykstra plan is written out, XLA argsorts it, the greedy kernel
reads it back, and local search makes one more pass.  This kernel executes
the *entire* solve — tau scaling, log-space Dykstra, descending-stable sort,
greedy capacity rounding and swap local search — in one ``pallas_call``:

  * one HBM read of the ``(BT, M, M)`` |W| tile,
  * one HBM write of the mask, bit-packed as ``(BT, M)`` uint32 rows
    (``repro.sparsity.bitpack`` layout — a 32x cut in mask write bandwidth
    at M=32, and exactly the words the service cache stores),
  * everything else (fractional plan, Dykstra dual, sort keys, capacity
    counters) lives in VMEM/registers for the whole solve.

Stage notes:

  * **Dykstra** at ``tol=0`` reuses the exact log-space iteration of the
    standalone kernel (fixed T, bit-identical masks).  ``tol>0`` arms the
    adaptive fast mode: the log-space state is kept (the tau=200 regime
    underflows a linear iterate's tail), but exp(s) is maintained
    incrementally through the normalization factors, leaving ONE
    per-element transcendental per sweep, and a ``while_loop`` exits the
    tile once the pre-clamp marginal violation drops to ``tol``
    (checked every ``_CHECK_EVERY`` sweeps).  Per-tile iteration counts
    are written to a side output for the benchmark's early-exit histogram.
  * **Sort**: XLA's argsort is unavailable in-kernel, so the M² entries are
    ordered by a bitonic network on (key, index) pairs.  All (key, index)
    pairs are distinct, so the network produces *exactly* the
    descending-stable order of ``jnp.argsort(-s)`` — greedy processes the
    same sequence as the XLA path and masks stay bit-identical.  The
    compare-exchange is reshape-based (no gathers), ``L log² L / 4``
    comparisons per block.
  * **Greedy** keeps the mask bit-packed *during* the counter loop: the
    per-step update touches one uint32 row word and two (BT, M) counters —
    O(BT·M) per step instead of the O(BT·M²) one-hot outer product the
    standalone rounding kernel pays.  Steps are unrolled 8-wide with
    cascaded capacity checks (sequentially exact).
  * **Local search** unpacks the mask once into VMEM, runs the same
    arithmetic as ``core.rounding.local_search`` (one-hot row/col gathers
    are exact — they select, never sum, real values), exits once a sweep
    swaps nothing (remaining sweeps are provable no-ops), and repacks.

Masks are bit-identical to ``dense-jit`` at ``tol=0`` (property-tested in
interpret mode); ``tol>0`` trades bounded marginal violation for a large
iteration cut.  M <= 32 (one packed word per row) — every paper pattern.

TPU caveat: the bitonic reshapes split the trailing M² lane dimension below
128 lanes for small strides; Mosaic handles these as sublane shuffles on
current toolchains, but if a future compiler rejects them the sort can be
restated with ``jnp.roll`` at ~2x the op count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret
from repro.kernels.dykstra.kernel import _iteration, _normalized
from repro.kernels.vmem import vmem_plan
from repro.sparsity.bitpack import MAX_M, pack_rows, unpack_rows

_SUM_FLOOR = 1e-30  # guards n/rowsum against fully-underflowed rows
_CHECK_EVERY = 4    # convergence-check stride of the adaptive fast mode

# Live float32-equivalent tile copies: |W|, plan, dual, sort keys + indices,
# and the local-search score temporary.
LIVE_BUFFERS = 6


def fused_block_b(m: int, device=None) -> int:
    """VMEM-derived tile size for the fused solve kernel."""
    return vmem_plan(m, device, live_buffers=LIVE_BUFFERS).block_b


def _bitonic_argsort_desc(keys: jnp.ndarray) -> jnp.ndarray:
    """(BT, L) keys -> (BT, L) int32 indices in descending-stable order.

    Sorts (key, index) pairs with the total order "larger key first, ties by
    smaller index first" — identical to ``jnp.argsort(-keys)`` (stable).
    Keys must be non-negative: a non-power-of-two L (odd M) is padded to the
    next power of two with -1 sentinels, which sort strictly last, so the
    first L output positions are exactly the real order.
    """
    bt, ell = keys.shape
    pot = 1 << max(ell - 1, 1).bit_length()
    if ell & (ell - 1):  # not a power of two: pad with always-last sentinels
        keys = jnp.concatenate(
            [keys, jnp.full((bt, pot - ell), -1.0, keys.dtype)], axis=1
        )
        ell = pot
    idx = jax.lax.broadcasted_iota(jnp.int32, (bt, ell), 1)
    pos = idx  # positions coincide with initial indices

    def before(ka, ia, kb, ib):
        """(ka, ia) strictly precedes (kb, ib) in descending-stable order."""
        return (ka > kb) | ((ka == kb) & (ia < ib))

    size = 2
    while size <= ell:
        # "Ascending" (= desired order) blocks of this merge level.
        dirs = (pos // size) % 2 == 0
        stride = size // 2
        while stride >= 1:
            shape4 = (bt, ell // (2 * stride), 2, stride)
            k4 = keys.reshape(shape4)
            i4 = idx.reshape(shape4)
            d4 = dirs.reshape(shape4)[:, :, 0, :]  # same dir for both partners
            klo, khi = k4[:, :, 0, :], k4[:, :, 1, :]
            ilo, ihi = i4[:, :, 0, :], i4[:, :, 1, :]
            swap = jnp.where(
                d4, before(khi, ihi, klo, ilo), before(klo, ilo, khi, ihi)
            )
            nklo = jnp.where(swap, khi, klo)
            nkhi = jnp.where(swap, klo, khi)
            nilo = jnp.where(swap, ihi, ilo)
            nihi = jnp.where(swap, ilo, ihi)
            keys = jnp.stack([nklo, nkhi], axis=2).reshape(bt, ell)
            idx = jnp.stack([nilo, nihi], axis=2).reshape(bt, ell)
            stride //= 2
        size *= 2
    return idx


def _greedy_packed(order: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Greedy capacity rounding over a precomputed order, packed in VMEM.

    ``order`` is (BT, M²) flat indices, best first.  Returns (BT, M) uint32
    mask words (bit j of row word = column j).  Equivalent to
    ``core.rounding.greedy_round`` fed the same order.

    Several consecutive order entries are processed per loop step, each
    entry's capacity check seeing the previous entries' (conditional)
    counter increments — exactly the sequential semantics at a fraction of
    the loop-dispatch overhead.  Entries past the largest unrollable
    multiple are cascaded in an unrolled tail.
    """
    bt = order.shape[0]
    rows = order // m
    cols = order % m
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bt, m), 1)

    def take_one(k, words, rc, cc):
        r = jax.lax.dynamic_slice_in_dim(rows, k, 1, axis=1)  # (BT, 1)
        c = jax.lax.dynamic_slice_in_dim(cols, k, 1, axis=1)
        r_oh = iota_m == r  # (BT, M) one-hot of this step's row
        c_oh = iota_m == c
        rcount = jnp.sum(jnp.where(r_oh, rc, 0), axis=1, keepdims=True)
        ccount = jnp.sum(jnp.where(c_oh, cc, 0), axis=1, keepdims=True)
        can = (rcount < n) & (ccount < n)  # (BT, 1)
        # Single-bit OR in the sparsity.bitpack row-word layout (bit j of a
        # row word = column j, LSB-first); the bulk pack/unpack goes through
        # bitpack itself, and the bit-identity tests vs fused_solve_ref
        # (which packs with bitpack.pack_rows) pin this update to it.
        bit = jnp.left_shift(jnp.uint32(1), c.astype(jnp.uint32))  # (BT, 1)
        words = jnp.where(r_oh & can, words | bit, words)
        inc = can.astype(jnp.int32)
        rc = rc + jnp.where(r_oh, inc, 0)
        cc = cc + jnp.where(c_oh, inc, 0)
        return words, rc, cc

    unroll = 8
    total = m * m
    steps, tail = divmod(total, unroll)

    def body(i, carry):
        words, rc, cc = carry
        for u in range(unroll):
            words, rc, cc = take_one(unroll * i + u, words, rc, cc)
        return words, rc, cc

    carry = (
        jnp.zeros((bt, m), jnp.uint32),
        jnp.zeros((bt, m), jnp.int32),
        jnp.zeros((bt, m), jnp.int32),
    )
    if steps:
        carry = jax.lax.fori_loop(0, steps, body, carry)
    words, rc, cc = carry
    for k in range(total - tail, total):
        words, rc, cc = take_one(k, words, rc, cc)
    return words


def _local_search(mask: jnp.ndarray, x: jnp.ndarray, n: int, steps: int):
    """In-kernel twin of ``core.rounding.local_search`` (one-hot gathers).

    One-hot selection reproduces the fancy-indexing gathers exactly (it picks
    a single real value; the masked sum adds only zeros), so scores, argmax
    tie-breaks and therefore masks match the XLA path bit for bit.

    The loop exits as soon as a sweep applies no swap anywhere in the tile:
    a swap-free sweep recomputes the identical state next sweep, so every
    remaining sweep is a no-op and skipping them is exact.
    """
    bt, m, _ = mask.shape
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bt, m), 1)
    neg_inf = jnp.float32(-jnp.inf)

    def sweep(mask):
        rdef = jnp.sum(mask, axis=2) < n  # (BT, M) unsaturated rows
        cdef = jnp.sum(mask, axis=1) < n
        i = jnp.argmax(rdef, axis=1)  # first deficit row per block
        j = jnp.argmax(cdef, axis=1)
        need = jnp.any(rdef, axis=1) & jnp.any(cdef, axis=1)
        i_oh = iota_m == i[:, None]  # (BT, M)
        j_oh = iota_m == j[:, None]

        w_row_i = jnp.sum(jnp.where(i_oh[:, :, None], x, 0.0), axis=1)  # x[b,i,:]
        w_col_j = jnp.sum(jnp.where(j_oh[:, None, :], x, 0.0), axis=2)  # x[b,:,j]
        score = w_row_i[:, None, :] + w_col_j[:, :, None] - x
        s_row_i = jnp.any(mask & i_oh[:, :, None], axis=1)  # mask[b,i,:]
        s_col_j = jnp.any(mask & j_oh[:, None, :], axis=2)  # mask[b,:,j]
        valid = mask & ~s_row_i[:, None, :] & ~s_col_j[:, :, None]
        score = jnp.where(valid, score, neg_inf)

        flat = score.reshape(bt, m * m)
        k = jnp.argmax(flat, axis=1)
        smax = jnp.max(flat, axis=1)
        ip, jp = k // m, k % m
        do = need & (smax > 0)
        ip_oh = iota_m == ip[:, None]
        jp_oh = iota_m == jp[:, None]

        d3 = do[:, None, None]
        mask = jnp.where(d3 & ip_oh[:, :, None] & jp_oh[:, None, :], False, mask)
        mask = jnp.where(d3 & ip_oh[:, :, None] & j_oh[:, None, :], True, mask)
        mask = jnp.where(d3 & i_oh[:, :, None] & jp_oh[:, None, :], True, mask)
        return mask, jnp.any(do)

    def cond(carry):
        _, it, changed = carry
        return (it < steps) & changed

    def body(carry):
        mask, it, _ = carry
        mask, changed = sweep(mask)
        return mask, it + 1, changed

    mask, _, _ = jax.lax.while_loop(cond, body, (mask, jnp.int32(0), True))
    return mask


def _fused_kernel(
    x_ref, words_ref, iters_ref, *,
    n: int, m: int, iters: int, ls_steps: int, tau_scale: float, tol: float,
):
    x = x_ref[...].astype(jnp.float32)  # (BT, M, M) |W| tile
    bt = x.shape[0]
    log_n = jnp.log(jnp.float32(n))

    # tau scaling — same arithmetic as backends._batched_solve.
    scale = jnp.max(x, axis=(1, 2), keepdims=True)
    tau = tau_scale / jnp.maximum(scale, 1e-30)
    s0 = tau * x

    # Dykstra.  tol=0: log-space fixed-T, bit-identical to dense-jit.
    # tol>0: adaptive fast mode.  The state stays in log space (the tau=200
    # regime puts most entries hundreds of nats below the top — a linear
    # iterate would underflow the tail that later becomes solution support),
    # but exp(s) is maintained *incrementally*: the normalizations multiply
    # it by the (BT, M, 1)-shaped factors n/rowsum / n/colsum, whose log is
    # an M-vector transcendental, and only the capacity clamp re-exponenti-
    # ates elementwise.  One per-element exp per iteration instead of the
    # four exp/log sweeps of the logsumexp form — same dynamics to ~1e-4 —
    # and a while_loop exits once the pre-clamp marginal violation (col sums
    # are exactly N there, cf. core.dykstra.marginal_violation) drops to
    # <= tol.
    if tol <= 0.0:
        s_log, _ = jax.lax.fori_loop(
            0, iters,
            lambda _, c: _iteration(c[0], c[1], log_n),
            (s0, jnp.zeros_like(s0)),
        )
        plan = jnp.exp(s_log)
        it = jnp.int32(iters)
    else:
        nf = jnp.float32(n)
        # Iteration 1 uses the shifted logsumexp (tau*|W| reaches ~200, so
        # a raw exp would overflow).  With q0 = 0 the capacity step is
        # closed-form: s1 = min(s, 0), q1 = max(s, 0).
        s = _normalized(s0, log_n)
        q = jnp.maximum(s, 0.0)
        s = jnp.minimum(s, 0.0)
        e = jnp.exp(s)

        def sweep(_, carry):
            s, q, e = carry
            fr = nf / jnp.maximum(jnp.sum(e, axis=2, keepdims=True), _SUM_FLOOR)
            s = s + jnp.log(fr)  # (BT, M, 1) log — M-vector, not M^2
            e = e * fr
            fc = nf / jnp.maximum(jnp.sum(e, axis=1, keepdims=True), _SUM_FLOOR)
            s = s + jnp.log(fc)
            e = e * fc
            tmp = s + q
            s = jnp.minimum(tmp, 0.0)
            q = tmp - s
            e = jnp.exp(s)  # the single per-element transcendental
            return s, q, e

        def cond(carry):
            _, _, _, it, viol = carry
            return (it < iters) & (viol > tol)

        def chunk(carry):
            # Convergence is tested once per _CHECK_EVERY sweeps: the
            # violation decays geometrically, so the strided check gives up
            # little exit resolution while the inner sweeps stay branch- and
            # reduction-free.  The final chunk shrinks so the total lands
            # exactly on the ``iters`` cap.  The last sweep of each chunk is
            # instrumented: its violation is read off the *pre-clamp*
            # iterate (right after the column projection, where col sums are
            # exactly N), cf. core.dykstra.marginal_violation.
            s, q, e, it, _ = carry
            plain = jnp.minimum(_CHECK_EVERY - 1, iters - it - 1)
            s, q, e = jax.lax.fori_loop(0, plain, sweep, (s, q, e))
            fr = nf / jnp.maximum(jnp.sum(e, axis=2, keepdims=True), _SUM_FLOOR)
            s = s + jnp.log(fr)
            e = e * fr
            fc = nf / jnp.maximum(jnp.sum(e, axis=1, keepdims=True), _SUM_FLOOR)
            s = s + jnp.log(fc)
            e = e * fc
            viol = jnp.max(jnp.abs(jnp.sum(e, axis=2) - nf)) / nf
            tmp = s + q
            s = jnp.minimum(tmp, 0.0)
            q = tmp - s
            e = jnp.exp(s)
            return s, q, e, it + plain + 1, viol

        _, _, plan, it, _ = jax.lax.while_loop(
            cond, chunk, (s, q, e, jnp.int32(1), jnp.float32(jnp.inf))
        )

    # Descending-stable order of the fractional plan, then packed greedy.
    order = _bitonic_argsort_desc(plan.reshape(bt, m * m))
    words = _greedy_packed(order, n, m)

    if ls_steps > 0:
        # Unpack once into VMEM, run swap local search on |W|, repack —
        # through the canonical bitpack helpers (traceable), so the kernel
        # cannot drift from the layout the cache and scheduler consume.
        mask = unpack_rows(words, m)
        mask = _local_search(mask, x, n, ls_steps)
        words = pack_rows(mask)

    words_ref[...] = words
    iters_ref[...] = jnp.full(iters_ref.shape, it, jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "iters", "ls_steps", "tau_scale", "tol", "block_b", "interpret"
    ),
)
def fused_solve_pallas(
    w_abs_blocks: jnp.ndarray,
    n: int,
    iters: int = 300,
    ls_steps: int = 10,
    tau_scale: float = 200.0,
    tol: float = 0.0,
    block_b: int | None = None,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """End-to-end TSENOR solve of a (B, M, M) |W| batch in one kernel.

    Returns ``(words, tile_iters)``: ``words`` is the (B, M) uint32
    bit-packed mask (``bitpack.unpack_rows`` recovers the boolean blocks),
    ``tile_iters`` is the (num_tiles,) int32 Dykstra iteration count each
    tile ran before converging (== ``iters`` everywhere at ``tol=0``).
    """
    b, m, _ = w_abs_blocks.shape
    if m > MAX_M:
        raise ValueError(
            f"fused solve packs one uint32 word per row and supports "
            f"M <= {MAX_M}, got M={m}; use the 'dense-jit' or 'pallas' backend"
        )
    if interpret is None:
        interpret = default_interpret()
    bt = block_b or fused_block_b(m)
    pb = -(-b // bt) * bt
    x = jnp.asarray(w_abs_blocks, jnp.float32)
    if pb != b:
        # Sentinel all-zero blocks solve to an arbitrary-but-valid mask and
        # are cropped below; they never touch real blocks.
        x = jnp.pad(x, ((0, pb - b), (0, 0), (0, 0)))
    grid = pb // bt
    words, tile_iters = pl.pallas_call(
        functools.partial(
            _fused_kernel, n=n, m=m, iters=iters, ls_steps=ls_steps,
            tau_scale=tau_scale, tol=tol,
        ),
        grid=(grid,),
        in_specs=[pl.BlockSpec((bt, m, m), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((bt, m), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pb, m), jnp.uint32),
            jax.ShapeDtypeStruct((grid, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return words[:b], tile_iters[:, 0]
