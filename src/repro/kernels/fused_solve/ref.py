"""Pure-jnp oracle for the fused solve kernel: the dense pipeline + packing.

This is literally the ``dense-jit`` backend pipeline (tau scaling, log-space
Dykstra, greedy + local-search rounding) followed by ``bitpack.pack_rows`` —
the fused kernel must reproduce it bit for bit at ``tol=0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dykstra import dykstra_log
from repro.core.rounding import round_blocks
from repro.sparsity.bitpack import pack_rows


@functools.partial(
    jax.jit, static_argnames=("n", "iters", "ls_steps", "tau_scale", "tol")
)
def fused_solve_ref(
    w_abs_blocks: jnp.ndarray,
    n: int,
    iters: int = 300,
    ls_steps: int = 10,
    tau_scale: float = 200.0,
    tol: float = 0.0,
) -> jnp.ndarray:
    """(B, M, M) |W| -> (B, M) uint32 packed mask rows (XLA reference)."""
    x = jnp.asarray(w_abs_blocks, jnp.float32)
    scale = jnp.max(x, axis=(1, 2), keepdims=True)
    tau = tau_scale / jnp.maximum(scale, 1e-30)
    s_approx = dykstra_log(x, n, iters, tau=tau, tol=tol)
    mask = round_blocks(s_approx, x, n, ls_steps)
    return pack_rows(mask)
