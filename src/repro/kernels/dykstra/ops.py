"""Public jit'd wrapper for the fused Dykstra kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dykstra.kernel import dykstra_pallas


def dykstra(tlw: jnp.ndarray, n: int, iters: int = 300, **kw) -> jnp.ndarray:
    """Solve the entropy-regularized OT relaxation for a block batch.

    ``tlw`` must already be scaled by the regularization strength
    (tau * |W|); see ``repro.core.solver`` for the tau rule.
    """
    return dykstra_pallas(tlw, n, iters, **kw)
