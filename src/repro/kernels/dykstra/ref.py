"""Pure-jnp oracle for the fused Dykstra kernel (identical math, no Pallas)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n", "iters"))
def dykstra_ref(tlw: jnp.ndarray, n: int, iters: int = 300) -> jnp.ndarray:
    """(B, M, M) pre-scaled log scores -> fractional plan, log-space Dykstra."""
    x = jnp.asarray(tlw, jnp.float32)
    log_n = jnp.log(jnp.float32(n))

    def lse(v, axis):
        return jax.scipy.special.logsumexp(v, axis=axis, keepdims=True)

    def body(_, carry):
        s, q = carry
        s = s - lse(s, 2) + log_n
        s = s - lse(s, 1) + log_n
        tmp = s + q
        s = jnp.minimum(tmp, 0.0)
        q = tmp - s
        return s, q

    s, _ = jax.lax.fori_loop(0, iters, body, (x, jnp.zeros_like(x)))
    return jnp.exp(s)
