"""Fused Dykstra iteration kernel (paper Algorithm 1) for TPU.

Design (DESIGN.md §2): the GPU implementation launches one elementwise kernel
per projection per iteration, paying an HBM round-trip each time.  On TPU we
tile the block batch into VMEM — BlockSpec ``(BT, M, M)`` — and run *all* T
iterations on-chip: one HBM read of the scaled scores, one HBM write of the
fractional plan.  Row/col logsumexp reductions run on the VPU; the dual
variable of the capacity constraint lives in registers/VMEM for the whole
solve.

VMEM budget: the tile, the dual and ~2 temporaries are live, i.e.
``4 * BT * M * M * 4B``.  BT=512 at M=32 is 8 MB < 16 MB VMEM.  The default
tile is chosen per M to stay under ~8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret


def _logsumexp(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    mx = jnp.max(x, axis=axis, keepdims=True)
    return mx + jnp.log(jnp.sum(jnp.exp(x - mx), axis=axis, keepdims=True))


def _dykstra_kernel(tlw_ref, out_ref, *, n: int, iters: int):
    x = tlw_ref[...].astype(jnp.float32)  # (BT, M, M) log-space scores
    log_n = jnp.log(jnp.float32(n))

    def body(_, carry):
        s, q = carry
        # KL projection onto C1 (row sums = N): row-wise log normalization.
        s = s - _logsumexp(s, axis=2) + log_n
        # KL projection onto C2 (col sums = N).
        s = s - _logsumexp(s, axis=1) + log_n
        # KL projection onto C3 (S <= 1) with Dykstra dual update.
        tmp = s + q
        s = jnp.minimum(tmp, 0.0)
        q = tmp - s
        return s, q

    s, _ = jax.lax.fori_loop(0, iters, body, (x, jnp.zeros_like(x)))
    out_ref[...] = jnp.exp(s)


def default_block_b(m: int) -> int:
    """Tile size keeping ~4 live copies under ~8 MB of VMEM."""
    budget = 8 * 1024 * 1024 // (4 * 4 * m * m)
    return max(8, min(512, budget))


@functools.partial(jax.jit, static_argnames=("n", "iters", "block_b", "interpret"))
def dykstra_pallas(
    tlw: jnp.ndarray,
    n: int,
    iters: int = 300,
    block_b: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Run the fused Dykstra solve.

    Args:
      tlw: (B, M, M) *pre-scaled* log-space scores, i.e. tau * |W|.
      n: target row/col sum.
      iters: Dykstra iterations T.
    Returns:
      (B, M, M) float32 fractional transport plan in [0, 1].
    """
    if interpret is None:
        interpret = default_interpret()
    b, m, _ = tlw.shape
    bt = block_b or default_block_b(m)
    pb = -(-b // bt) * bt
    if pb != b:
        # Padding blocks are all-zero scores; they solve to the uniform plan
        # and are cropped afterwards — harmless.
        tlw = jnp.pad(tlw, ((0, pb - b), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dykstra_kernel, n=n, iters=iters),
        grid=(pb // bt,),
        in_specs=[pl.BlockSpec((bt, m, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bt, m, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((pb, m, m), jnp.float32),
        interpret=interpret,
    )(tlw.astype(jnp.float32))
    return out[:b]
