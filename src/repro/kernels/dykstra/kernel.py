"""Fused Dykstra iteration kernel (paper Algorithm 1) for TPU.

Design (DESIGN.md §2): the GPU implementation launches one elementwise kernel
per projection per iteration, paying an HBM round-trip each time.  On TPU we
tile the block batch into VMEM — BlockSpec ``(BT, M, M)`` — and run *all* T
iterations on-chip: one HBM read of the scaled scores, one HBM write of the
fractional plan.  Row/col logsumexp reductions run on the VPU; the dual
variable of the capacity constraint lives in registers/VMEM for the whole
solve.

VMEM budget: the tile, the dual and ~2 temporaries are live; the tile size
comes from :func:`repro.kernels.vmem.vmem_plan` (``live_buffers=4``), which
keeps it under half the device's VMEM and aligned to the VPU sublane
multiple.

``tol > 0`` switches the fixed ``fori_loop`` for a convergence-tested
``while_loop`` that exits a tile once its max row/col marginal violation
drops to ``<= tol`` (relative to N).  ``tol=0`` keeps the historical
fixed-T path bit for bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret
from repro.kernels.vmem import vmem_plan


def _logsumexp(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    mx = jnp.max(x, axis=axis, keepdims=True)
    return mx + jnp.log(jnp.sum(jnp.exp(x - mx), axis=axis, keepdims=True))


def _normalized(s, log_n):
    """KL projections onto C1 (row sums = N) then C2 (col sums = N)."""
    s = s - _logsumexp(s, axis=2) + log_n
    return s - _logsumexp(s, axis=1) + log_n


def _capacity(s, q):
    """KL projection onto C3 (S <= 1) with Dykstra dual update."""
    tmp = s + q
    s = jnp.minimum(tmp, 0.0)
    return s, tmp - s


def _iteration(s, q, log_n):
    """One Dykstra iteration: C1, C2 projections + capacity dual update."""
    return _capacity(_normalized(s, log_n), q)


def _iteration_with_violation(s, q, log_n, n):
    """One Dykstra iteration, also reporting the tile's marginal violation.

    The violation is measured on the pre-clamp iterate (after the column
    projection), where column sums equal N exactly — see
    ``core.dykstra.marginal_violation`` for why the post-clamp iterate is the
    wrong place to test convergence.
    """
    s = _normalized(s, log_n)
    pre = jnp.exp(s)
    nf = jnp.float32(n)
    row_dev = jnp.max(jnp.abs(jnp.sum(pre, axis=2) - nf))
    col_dev = jnp.max(jnp.abs(jnp.sum(pre, axis=1) - nf))
    viol = jnp.maximum(row_dev, col_dev) / nf
    s, q = _capacity(s, q)
    return s, q, viol


def _dykstra_kernel(tlw_ref, out_ref, *, n: int, iters: int, tol: float):
    x = tlw_ref[...].astype(jnp.float32)  # (BT, M, M) log-space scores
    log_n = jnp.log(jnp.float32(n))

    if tol <= 0.0:

        def body(_, carry):
            s, q = carry
            return _iteration(s, q, log_n)

        s, _ = jax.lax.fori_loop(0, iters, body, (x, jnp.zeros_like(x)))
    else:

        def cond(carry):
            _, _, it, viol = carry
            return (it < iters) & (viol > tol)

        def step(carry):
            s, q, it, _ = carry
            s, q, viol = _iteration_with_violation(s, q, log_n, n)
            return s, q, it + 1, viol

        s, _, _, _ = jax.lax.while_loop(
            cond, step,
            (x, jnp.zeros_like(x), jnp.int32(0), jnp.float32(jnp.inf)),
        )
    out_ref[...] = jnp.exp(s)


def default_block_b(m: int) -> int:
    """Tile size for the Dykstra kernel (input, plan, dual, temp live)."""
    return vmem_plan(m, live_buffers=4).block_b


@functools.partial(
    jax.jit, static_argnames=("n", "iters", "block_b", "tol", "interpret")
)
def dykstra_pallas(
    tlw: jnp.ndarray,
    n: int,
    iters: int = 300,
    block_b: int | None = None,
    tol: float = 0.0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Run the fused Dykstra solve.

    Args:
      tlw: (B, M, M) *pre-scaled* log-space scores, i.e. tau * |W|.
      n: target row/col sum.
      iters: Dykstra iterations T.
      tol: per-tile adaptive early exit (0 = fixed T, bit-identical to the
        pre-tol kernel).
    Returns:
      (B, M, M) float32 fractional transport plan in [0, 1].
    """
    if interpret is None:
        interpret = default_interpret()
    b, m, _ = tlw.shape
    bt = block_b or default_block_b(m)
    pb = -(-b // bt) * bt
    if pb != b:
        # Padding blocks are all-zero scores; they solve to the uniform plan
        # and are cropped afterwards — harmless.
        tlw = jnp.pad(tlw, ((0, pb - b), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dykstra_kernel, n=n, iters=iters, tol=tol),
        grid=(pb // bt,),
        in_specs=[pl.BlockSpec((bt, m, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bt, m, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((pb, m, m), jnp.float32),
        interpret=interpret,
    )(tlw.astype(jnp.float32))
    return out[:b]
