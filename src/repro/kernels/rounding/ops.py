"""Public wrapper for the greedy-rounding kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rounding.kernel import greedy_round_pallas


def greedy_round(scores: jnp.ndarray, n: int, **kw) -> jnp.ndarray:
    return greedy_round_pallas(scores, n, **kw)
