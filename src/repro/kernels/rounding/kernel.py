"""Greedy-selection rounding kernel (paper Algorithm 2, lines 1-6) for TPU.

Split of labor: XLA performs the descending ``argsort`` of the M² block
entries (sorts belong in XLA on TPU), and this kernel runs the *sequential*
counter loop fused in VMEM: M² steps, each a fully-vectorized one-hot
capacity check/update across the block tile on the VPU.  The GPU version
pays a scatter per step into HBM-resident counters; here counters and the
mask tile never leave VMEM.

The per-step one-hot outer product makes each step O(M²) VPU work per block
(vs O(1) scatter work in the XLA path) — the win is zero HBM round-trips and
no per-step kernel dispatch; see EXPERIMENTS.md §Perf for the accounting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret
from repro.kernels.vmem import VPU_ALIGN, vmem_plan


def _greedy_kernel(order_ref, out_ref, *, n: int, m: int):
    order = order_ref[...]  # (bt, m*m) int32, descending-score order
    bt = order.shape[0]
    rows = order // m
    cols = order % m
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bt, m), 1)

    def body(k, carry):
        mask, rc, cc = carry
        r = jax.lax.dynamic_slice_in_dim(rows, k, 1, axis=1)  # (bt, 1)
        c = jax.lax.dynamic_slice_in_dim(cols, k, 1, axis=1)
        r_oh = iota_m == r  # (bt, m) one-hot of this step's row
        c_oh = iota_m == c
        rcount = jnp.sum(jnp.where(r_oh, rc, 0), axis=1, keepdims=True)
        ccount = jnp.sum(jnp.where(c_oh, cc, 0), axis=1, keepdims=True)
        can = (rcount < n) & (ccount < n)  # (bt, 1)
        upd = (r_oh[:, :, None] & c_oh[:, None, :]) & can[:, :, None]
        mask = jnp.where(upd, jnp.int8(1), mask)
        inc = can.astype(jnp.int32)
        rc = rc + jnp.where(r_oh, inc, 0)
        cc = cc + jnp.where(c_oh, inc, 0)
        return mask, rc, cc

    mask0 = jnp.zeros((bt, m, m), jnp.int8)
    cnt0 = jnp.zeros((bt, m), jnp.int32)
    mask, _, _ = jax.lax.fori_loop(0, m * m, body, (mask0, cnt0, cnt0))
    out_ref[...] = mask


def default_rounding_block_b(m: int) -> int:
    """VMEM-derived tile: order, mask, counters + temporaries live (~3)."""
    return vmem_plan(m, live_buffers=3).block_b


@functools.partial(jax.jit, static_argnames=("n", "block_b", "interpret"))
def greedy_round_pallas(
    scores: jnp.ndarray,
    n: int,
    block_b: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(B, M, M) scores -> boolean mask, greedy selection in VMEM.

    The tile size comes from :func:`repro.kernels.vmem.vmem_plan`; small
    batches are padded UP to the VPU sublane multiple (like
    ``dykstra_pallas``) instead of running a ragged tile — the padded
    sentinel rows are all-zero orders whose updates land in cropped rows.
    """
    if interpret is None:
        interpret = default_interpret()
    b, m, _ = scores.shape
    order = jnp.argsort(-scores.reshape(b, m * m), axis=1).astype(jnp.int32)
    bt = min(block_b or default_rounding_block_b(m),
             -(-max(1, b) // VPU_ALIGN) * VPU_ALIGN)
    pb = -(-b // bt) * bt
    if pb != b:
        order = jnp.pad(order, ((0, pb - b), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_greedy_kernel, n=n, m=m),
        grid=(pb // bt,),
        in_specs=[pl.BlockSpec((bt, m * m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, m, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((pb, m, m), jnp.int8),
        interpret=interpret,
    )(order)
    return out[:b].astype(bool)
