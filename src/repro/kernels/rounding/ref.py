"""Oracle for the greedy-rounding kernel: the core (XLA scatter) greedy."""
from repro.core.rounding import greedy_round as greedy_round_ref

__all__ = ["greedy_round_ref"]
