"""Pure-jnp oracle for the compressed N:M matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.sparsity.compressed import compress_nm, decompress_nm  # re-export
__all__ = ["compress_nm", "decompress_nm", "nm_spmm_ref"]


def nm_spmm_ref(
    x: jnp.ndarray,
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    m: int,
    transpose: bool = False,
) -> jnp.ndarray:
    """Decompress to dense and matmul in float32 (the correctness oracle)."""
    w = decompress_nm(vals, idx, m).astype(jnp.float32)  # (K, F)
    x = x.astype(jnp.float32)
    return x @ (w.T if transpose else w)
