"""Compressed transposable-N:M sparse matmul kernel for TPU.

The MXU has no sparse mode (unlike Ampere Sparse Tensor Cores), so the
TPU-native adaptation of the paper's nmSPMM speedup (Fig. 4 lower) is a
*bandwidth* optimization: weights stream from HBM in compressed
(values[K/M, N, F] + int8 indices) form — (N·bw + N)/(M·bw) of the dense
traffic — are decompressed into a dense VMEM tile via a one-hot select on the
VPU, and then hit the MXU as a regular dense matmul.

Because the mask is *transposable*, the same compressed buffer computes both
  forward :  Y = X · W      (reduction over K)
  backward:  dX = dY · Wᵀ   (reduction over F)
The backward kernel decompresses the tile and transposes it in VMEM; no dense
Wᵀ copy or re-compression ever exists in HBM — this is the paper's training
claim mapped to TPU (DESIGN.md §2).

Tiling: grid (B/bt, F/ft, K/kt) for forward (K innermost = accumulation), and
(B/bt, K/kt, F/ft) for the transposed product.  MXU-aligned tiles default to
(bt, kt, ft) = (256, 256, 256); VMEM live set ≈ x-tile + vals + idx + dense
tile + out-tile ≈ 1.1 MB at bf16 — comfortably under budget, leaving room for
double buffering of the streamed operands.

Tile *selection* is measurement-driven: when a tile argument is left None,
``_resolve_tiles`` consults the versioned tuning table
(``repro.perf.table`` — winners measured by ``benchmarks/kernel_autotune.py``
on this device kind at this operand shape class) and otherwise falls back to
the fixed defaults with the batch tile clamped to the VPU-aligned padded row
count.  The clamp is the decode-GEMV fix: at B=8 decode rows, bt=256 used to
pad 8 real rows to 256 — 31 wasted rows of MXU work and X traffic per real
one.  Per-row results are independent of the row tiling, so clamping is
bit-identical to the historic tiles (regression-tested).  Explicit tile
arguments are always honored verbatim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret
from repro.kernels.vmem import VPU_ALIGN


def _decompress_tile(vals: jnp.ndarray, idx: jnp.ndarray, m: int) -> jnp.ndarray:
    """(G, N, ft) values + indices -> dense (G*m, ft) float32 tile (VPU)."""
    g, n, ft = vals.shape
    p = jax.lax.broadcasted_iota(jnp.int32, (g, m, n, ft), 1)
    eq = idx.astype(jnp.int32)[:, None, :, :] == p
    dense = jnp.sum(jnp.where(eq, vals[:, None, :, :].astype(jnp.float32), 0.0), axis=2)
    return dense.reshape(g * m, ft)


def _fwd_kernel(x_ref, vals_ref, idx_ref, o_ref, *, m: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bt, kt)
    dense = _decompress_tile(vals_ref[...], idx_ref[...], m)  # (kt, ft)
    o_ref[...] += jnp.dot(
        x.astype(jnp.float32), dense, preferred_element_type=jnp.float32
    )


def _tr_kernel(g_ref, vals_ref, idx_ref, o_ref, *, m: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    gy = g_ref[...]  # (bt, ft)
    dense = _decompress_tile(vals_ref[...], idx_ref[...], m)  # (kt, ft)
    o_ref[...] += jnp.dot(
        gy.astype(jnp.float32), dense.T, preferred_element_type=jnp.float32
    )


def _pad_dim(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _resolve_tiles(
    b: int, k: int, f: int, m: int, transpose: bool,
    bt: int | None, kt: int | None, ft: int | None,
) -> tuple[int, int, int]:
    """Fill in None tile args: tuning table first, clamped defaults second.

    Table tiles are legality-clamped against the concrete shape (``kt`` a
    multiple of max(m, sublane), ``ft`` a multiple of the lane width); the
    batch tile is additionally clamped to the padded row count whenever the
    caller did not pin it — rows are independent, so the clamp never changes
    results, only how much padding the grid carries.
    """
    row_cap = max(VPU_ALIGN, _round_up(b, VPU_ALIGN))
    if bt is None or kt is None or ft is None:
        from repro.perf.table import nm_spmm_tiles

        tuned = nm_spmm_tiles(b, k, f, m, transpose)
        tbt, tkt, tft = tuned if tuned else (256, 256, 256)
        if bt is None:
            bt = min(tbt, row_cap)
        if kt is None:
            kt = tkt if tuned else _round_up(256, m)
            kt = max(min(kt, _round_up(k, max(m, VPU_ALIGN))), m)
            kt = _round_up(kt, m)
        if ft is None:
            ft = min(tft, _round_up(f, 128))
    return bt, kt, ft


def nm_spmm_pallas(
    x: jnp.ndarray,
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    m: int,
    transpose: bool = False,
    bt: int | None = None,
    kt: int | None = None,
    ft: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Compressed N:M matmul.

    Args:
      x: (B, K) activations (forward) or (B, F) cotangents (transpose=True).
      vals/idx: compressed weight, shapes (K/M, N, F).
      transpose: False -> returns X·W (B, F); True -> returns X·Wᵀ (B, K).
      bt/kt/ft: tile sizes; None (the default) resolves through the tuning
        table / clamped defaults at trace time (see module docstring).

    Returns float32 output (cast at the call site if bf16 is wanted).
    """
    g, n, f = vals.shape
    k = g * m
    bt, kt, ft = _resolve_tiles(
        int(x.shape[0]), k, f, m, transpose, bt, kt, ft
    )
    return _nm_spmm_call(
        x, vals, idx, m, transpose, bt, kt, ft, interpret
    )


@functools.partial(
    jax.jit, static_argnames=("m", "transpose", "bt", "kt", "ft", "interpret")
)
def _nm_spmm_call(
    x: jnp.ndarray,
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    m: int,
    transpose: bool,
    bt: int,
    kt: int,
    ft: int,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    g, n, f = vals.shape
    k = g * m
    assert kt % m == 0, (kt, m)
    b = x.shape[0]

    xb = _pad_dim(_pad_dim(x, 0, bt), 1, kt if not transpose else ft)
    vals_p = _pad_dim(_pad_dim(vals, 0, kt // m), 2, ft)
    idx_p = _pad_dim(_pad_dim(idx, 0, kt // m), 2, ft)
    pb = xb.shape[0]
    pk = vals_p.shape[0] * m
    pf = vals_p.shape[2]

    if not transpose:
        grid = (pb // bt, pf // ft, pk // kt)
        out = pl.pallas_call(
            functools.partial(_fwd_kernel, m=m),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, kt), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((kt // m, n, ft), lambda i, j, kk: (kk, 0, j)),
                pl.BlockSpec((kt // m, n, ft), lambda i, j, kk: (kk, 0, j)),
            ],
            out_specs=pl.BlockSpec((bt, ft), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((pb, pf), jnp.float32),
            interpret=interpret,
        )(xb, vals_p, idx_p)
        return out[:b, :f]

    grid = (pb // bt, pk // kt, pf // ft)
    out = pl.pallas_call(
        functools.partial(_tr_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, ft), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((kt // m, n, ft), lambda i, j, kk: (j, 0, kk)),
            pl.BlockSpec((kt // m, n, ft), lambda i, j, kk: (j, 0, kk)),
        ],
        out_specs=pl.BlockSpec((bt, kt), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pk), jnp.float32),
        interpret=interpret,
    )(xb, vals_p, idx_p)
    return out[:b, :k]
