"""Public wrapper for the compressed N:M matmul: custom-VJP sparse linear op.

``nm_linear`` is the layer-level entry point used by sparse fine-tuning: the
forward pass computes X·W from the compressed buffer, and the backward pass
computes dX = dY·Wᵀ from the *same* buffer (transposable masks make the
transposed view N:M too).  dW is returned densely against the mask support —
weight gradients are only needed at mask positions.

``nm_linear_nd`` is the model-facing variant: it accepts activations with any
leading batch dims (``(B, S, K)`` training tensors, ``(B, 1, K)`` decode
steps) by flattening them into the kernel's ``(rows, K)`` layout — this is
what :func:`repro.models.layers.proj` dispatches compressed parameter leaves
through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.nm_spmm.kernel import nm_spmm_pallas


def nm_spmm(x, vals, idx, m, transpose=False, **kw):
    return nm_spmm_pallas(x, vals, idx, m, transpose=transpose, **kw)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def nm_linear(x, vals, idx, m):
    """y = x @ decompress(vals, idx); differentiable in x and vals."""
    return nm_spmm_pallas(x, vals, idx, m).astype(x.dtype)


def _fwd(x, vals, idx, m):
    y = nm_spmm_pallas(x, vals, idx, m).astype(x.dtype)
    return y, (x, vals, idx)


def _bwd(m, res, dy):
    x, vals, idx = res
    # dX via the SAME compressed buffer — the transposable-mask payoff.
    dx = nm_spmm_pallas(dy, vals, idx, m, transpose=True).astype(x.dtype)
    # dVals: gradient of each stored value = <x[:, k], dy[:, f]> at its
    # (k, f) position; gather from the dense dW restricted to the support.
    dw = (x.astype(jnp.float32).T @ dy.astype(jnp.float32))  # (K, F)
    g, n, f = vals.shape
    dwg = dw.reshape(g, m, f)
    gathered = jnp.take_along_axis(
        dwg, jnp.maximum(idx.astype(jnp.int32), 0), axis=1
    )
    # Dead slots (idx == -1, groups with fewer than N nonzeros) must not
    # gather another position's gradient: their value stays pinned at 0.
    dvals = jnp.where(idx >= 0, gathered, 0.0).astype(vals.dtype)
    return dx, dvals, None


nm_linear.defvjp(_fwd, _bwd)


def nm_linear_nd(x, vals, idx, m):
    """``nm_linear`` over activations with arbitrary leading dims.

    ``x``: ``(..., K)`` -> returns ``(..., F)`` in ``x.dtype``.  Leading dims
    are flattened into the kernel's row dimension (rows are independent, so
    this is exact) and restored on the way out.
    """
    lead = x.shape[:-1]
    y = nm_linear(x.reshape(-1, x.shape[-1]), vals, idx, m)
    return y.reshape(*lead, y.shape[-1])
