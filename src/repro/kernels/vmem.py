"""VMEM budgeting for the block-solver Pallas kernels.

Every solver kernel tiles the (B, M, M) block batch into VMEM-resident
tiles of ``block_b`` blocks and keeps some number of live float32 copies of
the tile (scores, Dykstra dual, mask, temporaries).  The right tile size is
therefore a pure function of M, the number of live buffers, and the
device's VMEM capacity — :func:`vmem_plan` computes it once and every
kernel (and the service scheduler's bucket-ladder cost model) queries it
instead of hard-coding its own heuristic.

``default_block_b`` in ``kernels.dykstra.kernel`` and the tile choice in
``kernels.rounding`` both delegate here, so the scheduler's buckets, the
Dykstra tiles and the fused-solve tiles all agree on alignment.
"""
from __future__ import annotations

import dataclasses

# Per-core VMEM by TPU generation (bytes).  Conservative; unknown kinds
# (including CPU/GPU hosts running the kernels in interpret mode) fall back
# to the v2-v4 figure so tiling stays portable.
_VMEM_BYTES_BY_KIND = {
    "TPU v5": 128 * 1024 * 1024,
    "TPU v5p": 128 * 1024 * 1024,
    "TPU v6": 128 * 1024 * 1024,
}
_DEFAULT_VMEM_BYTES = 16 * 1024 * 1024

# The kernel may only plan against a fraction of physical VMEM: the Mosaic
# compiler needs headroom for spills, semaphores and double-buffered DMA.
_BUDGET_FRACTION = 0.5

# Sublane granularity of float32 tiles on the VPU; block tiles are padded to
# a multiple of this so the batch axis maps cleanly onto (8, 128) registers.
VPU_ALIGN = 8


@dataclasses.dataclass(frozen=True)
class VmemPlan:
    """Tiling decision for one (kernel, M, device) combination."""

    m: int                # block side
    vmem_bytes: int       # physical per-core VMEM assumed for the device
    budget_bytes: int     # fraction of it the kernel plans against
    live_buffers: int     # live float32 tile copies the kernel keeps
    block_b: int          # tile size in blocks (multiple of VPU_ALIGN)

    @property
    def bytes_per_block(self) -> int:
        """Live VMEM bytes one block costs across all kernel buffers."""
        return self.live_buffers * 4 * self.m * self.m

    def tile_bytes(self) -> int:
        return self.block_b * self.bytes_per_block


def device_vmem_bytes(device=None) -> int:
    """Per-core VMEM of ``device`` (default: first local jax device)."""
    if device is None:
        import jax

        devices = jax.local_devices()
        device = devices[0] if devices else None
    kind = getattr(device, "device_kind", "") or ""
    for prefix, size in _VMEM_BYTES_BY_KIND.items():
        if kind.startswith(prefix):
            return size
    return _DEFAULT_VMEM_BYTES


def vmem_plan(
    m: int,
    device=None,
    *,
    live_buffers: int = 4,
    max_block_b: int = 512,
) -> VmemPlan:
    """Pick the block-tile size for an M x M block kernel on ``device``.

    ``live_buffers`` is the kernel's own accounting of live float32 tile
    copies (the Dykstra kernel keeps ~4: input, plan, dual, temporary; the
    fused solve kernel ~6, adding the mask and local-search scores).
    """
    if m < 1:
        raise ValueError(f"vmem_plan needs m >= 1, got {m}")
    if live_buffers < 1:
        raise ValueError(f"vmem_plan needs live_buffers >= 1, got {live_buffers}")
    vmem = device_vmem_bytes(device)
    budget = int(vmem * _BUDGET_FRACTION)
    per_block = live_buffers * 4 * m * m
    raw = budget // per_block
    # Round DOWN to a power of two (>= VPU_ALIGN): the tile never exceeds
    # budget, stays VPU-sublane aligned, and divides the scheduler's
    # power-of-two bucket ladder exactly — so mega-batches never pad a
    # partial tile.
    pot = 1 << max(raw, 1).bit_length() - 1
    aligned = max(VPU_ALIGN, pot)
    return VmemPlan(
        m=m,
        vmem_bytes=vmem,
        budget_bytes=budget,
        live_buffers=live_buffers,
        block_b=min(max_block_b, aligned),
    )
