"""Pallas TPU kernels for the perf-critical compute of TSENOR.

Four hot spots (see DESIGN.md §2):
  * ``dykstra``     — Algorithm 1 fused in VMEM: all T iterations of the
                      entropy-regularized OT solve run on-chip per block tile.
  * ``fused_solve`` — the single-pass pipeline: Dykstra + bitonic sort +
                      greedy rounding + swap local search in ONE pallas_call;
                      one HBM |W| read, one bit-packed uint32-row mask write.
                      Supersedes the split dykstra+rounding pipeline on the
                      hot path (backend ``"pallas-fused"``).
  * ``nm_spmm``     — compressed transposable-N:M matmul: weights live in HBM
                      in (values, int8 indices) form, are decompressed
                      tile-by-tile in VMEM, and feed the MXU; the same buffer
                      serves W and Wᵀ.
  * ``rounding``    — greedy-selection counter loop fused in VMEM (the argsort
                      stays in XLA in this split pipeline).

Each kernel directory has ``kernel.py`` (pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp oracle used by the
equality/allclose test sweeps).  Tile sizes come from ``kernels.vmem``
(one VMEM budget shared by all kernels and the service scheduler's bucket
ladder).  On non-TPU backends the wrappers run the kernel body in interpret
mode, which is how this CPU container validates them.
"""


def default_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"
