"""Naive-softmax oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jnp.ndarray,  # (BKV, G, S, hd)
    k: jnp.ndarray,  # (BKV, S, hd)
    v: jnp.ndarray,  # (BKV, S, hd)
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    bkv, g, s, hd = q.shape
    sc = jnp.einsum(
        "bgqh,bkh->bgqk", q.astype(jnp.float32) * hd**-0.5,
        k.astype(jnp.float32),
    )
    if causal:
        qp = jnp.arange(s)[:, None]
        kp = jnp.arange(s)[None, :]
        ok = qp >= kp
        if window:
            ok &= (qp - kp) < window
        sc = jnp.where(ok[None, None], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bgqk,bkh->bgqh", w, v.astype(jnp.float32)).astype(q.dtype)
