"""Fused flash attention kernel for TPU (beyond-paper optimization).

Motivation (EXPERIMENTS.md §Perf): the XLA chunked-scan attention materializes
every (Q, KV-chunk) score tile in HBM — at prefill_32k that's
B·H·S² · 4 bytes of score traffic, 10-100x the K/V/Q/O traffic, making every
prefill cell memory-bound.  This kernel keeps the running max / denominator /
accumulator in VMEM scratch across KV-grid steps, so HBM traffic collapses to
Q + K + V + O.

Grid: (batch*kv_heads, q_tiles, kv_tiles) — kv innermost so the scratch
carries (m, l, acc) for one q-tile across its kv sweep; the output tile is
emitted at the last kv step.  Causal masking is applied per-tile from absolute
positions; GQA is handled by blocking q as (group, q_tile) per kv head.

Tiles default to (q, kv) = (256, 256): VMEM live set ~= q-tile + k/v tiles +
scores tile + acc ~= 1.5 MB at bf16 — room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import default_interpret

NEG_INF = -1e30


def _tile_scores(q, k, qi, ki, q_tile, kv_tile, scale, causal, window):
    sc = jax.lax.dot_general(
        q.astype(jnp.float32) * scale, k.astype(jnp.float32),
        (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, qt, kt)
    if causal:
        q_pos = qi * q_tile + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        k_pos = ki * kv_tile + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 2)
        ok = q_pos >= k_pos
        if window:
            ok &= (q_pos - k_pos) < window
        sc = jnp.where(ok, sc, NEG_INF)
    return sc


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, q_tile: int, kv_tile: int,
                  window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (G, qt, hd)
    k = k_ref[0]  # (kt, hd)
    v = v_ref[0]  # (kt, hd)
    sc = _tile_scores(q, k, qi, ki, q_tile, kv_tile, scale, causal, window)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=-1))
    p = jnp.exp(sc - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + p.sum(axis=-1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[..., None]).astype(o_ref.dtype)
        m_ref[0] = m_scr[...]
        l_ref[0] = denom


def _flash_fwd_impl(q, k, v, causal, window, q_tile, kv_tile, interpret):
    bkv, g, s, hd = q.shape
    q_tile = min(q_tile, s)
    kv_tile = min(kv_tile, s)
    assert s % q_tile == 0 and s % kv_tile == 0, (s, q_tile, kv_tile)
    grid = (bkv, s // q_tile, s // kv_tile)
    scale = hd**-0.5
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, q_tile=q_tile,
            kv_tile=kv_tile, window=window,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, q_tile, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, q_tile, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, g, q_tile), lambda b, i, j: (b, 0, i)),
            pl.BlockSpec((1, g, q_tile), lambda b, i, j: (b, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bkv, g, s, hd), q.dtype),
            jax.ShapeDtypeStruct((bkv, g, s), jnp.float32),  # row max m
            jax.ShapeDtypeStruct((bkv, g, s), jnp.float32),  # denominator l
        ],
        scratch_shapes=[
            pltpu.VMEM((g, q_tile), jnp.float32),
            pltpu.VMEM((g, q_tile), jnp.float32),
            pltpu.VMEM((g, q_tile, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward kernels: recompute score tiles in VMEM (never materialize S^2).
# The XLA autodiff of the online-softmax scan stacks every (q, kv-chunk)
# linearization residual in HBM — measured as the dominant train-cell traffic
# (EXPERIMENTS.md §Perf) — whereas these kernels re-derive p from (m, l) per
# tile and keep it in VMEM.
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, d_ref,
                         dq_ref, dq_scr, *, scale, causal, q_tile, kv_tile,
                         window):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    sc = _tile_scores(q, k, qi, ki, q_tile, kv_tile, scale, causal, window)
    p = jnp.exp(sc - m_ref[0][..., None]) / jnp.maximum(
        l_ref[0], 1e-30
    )[..., None]  # (G, qt, kt)
    dp = jax.lax.dot_general(
        do_ref[0].astype(jnp.float32), v.astype(jnp.float32),
        (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    ds = p * (dp - d_ref[0][..., None]) * scale
    dq_scr[...] += jax.lax.dot_general(
        ds, k.astype(jnp.float32), (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _emit():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, d_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal,
                          q_tile, kv_tile, window):
    ki = pl.program_id(1)
    qi = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0].astype(jnp.float32)
    sc = _tile_scores(q, k, qi, ki, q_tile, kv_tile, scale, causal, window)
    p = jnp.exp(sc - m_ref[0][..., None]) / jnp.maximum(
        l_ref[0], 1e-30
    )[..., None]  # (G, qt, kt)
    # dv += sum_g p^T do
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    dp = jax.lax.dot_general(
        do, v.astype(jnp.float32), (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    ds = p * (dp - d_ref[0][..., None]) * scale
    dk_scr[...] += jax.lax.dot_general(
        ds, q.astype(jnp.float32), (((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(qi == nq - 1)
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _flash_bwd_impl(res, dout, causal, window, q_tile, kv_tile, interpret):
    q, k, v, o, m, l = res
    bkv, g, s, hd = q.shape
    q_tile = min(q_tile, s)
    kv_tile = min(kv_tile, s)
    scale = hd**-0.5
    delta = jnp.sum(
        dout.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    )  # (BKV, G, S)

    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, scale=scale, causal=causal, q_tile=q_tile,
            kv_tile=kv_tile, window=window,
        ),
        grid=(bkv, s // q_tile, s // kv_tile),
        in_specs=[
            pl.BlockSpec((1, g, q_tile, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, g, q_tile, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, g, q_tile), lambda b, i, j: (b, 0, i)),
            pl.BlockSpec((1, g, q_tile), lambda b, i, j: (b, 0, i)),
            pl.BlockSpec((1, g, q_tile), lambda b, i, j: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, g, q_tile, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bkv, g, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, q_tile, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, m, l, delta)

    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, scale=scale, causal=causal, q_tile=q_tile,
            kv_tile=kv_tile, window=window,
        ),
        grid=(bkv, s // kv_tile, s // q_tile),
        in_specs=[
            pl.BlockSpec((1, g, q_tile, hd), lambda b, j, i: (b, 0, i, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, g, q_tile, hd), lambda b, j, i: (b, 0, i, 0)),
            pl.BlockSpec((1, g, q_tile), lambda b, j, i: (b, 0, i)),
            pl.BlockSpec((1, g, q_tile), lambda b, j, i: (b, 0, i)),
            pl.BlockSpec((1, g, q_tile), lambda b, j, i: (b, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, kv_tile, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bkv, s, hd), k.dtype),
            jax.ShapeDtypeStruct((bkv, s, hd), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((kv_tile, hd), jnp.float32),
            pltpu.VMEM((kv_tile, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, dout, m, l, delta)
    return dq, dk, dv


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (BKV, G, S, hd)  — batch*kv_heads, q-groups per kv head
    k: jnp.ndarray,  # (BKV, S, hd)
    v: jnp.ndarray,  # (BKV, S, hd)
    causal: bool = True,
    window: int = 0,
    q_tile: int = 256,
    kv_tile: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    o, _, _ = _flash_fwd_impl(q, k, v, causal, window, q_tile, kv_tile, interpret)
    return o


def _fa_fwd(q, k, v, causal, window, q_tile, kv_tile, interpret):
    if interpret is None:
        interpret = default_interpret()
    o, m, l = _flash_fwd_impl(q, k, v, causal, window, q_tile, kv_tile, interpret)
    return o, (q, k, v, o, m, l)


def _fa_bwd(causal, window, q_tile, kv_tile, interpret, res, dout):
    if interpret is None:
        interpret = default_interpret()
    return _flash_bwd_impl(res, dout, causal, window, q_tile, kv_tile, interpret)


flash_attention_pallas.defvjp(_fa_fwd, _fa_bwd)
