"""Model-facing wrapper: (B, S, KV, G, hd) layout -> fused flash attention."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(
    qg: jnp.ndarray,  # (B, S, KV, G, hd) — as used by repro.models.attention
    k: jnp.ndarray,   # (B, S, KV, hd)
    v: jnp.ndarray,   # (B, S, KV, hd)
    causal: bool = True,
    window: int = 0,
    **kw,
) -> jnp.ndarray:
    b, s, kv, g, hd = qg.shape
    qk = qg.transpose(0, 2, 3, 1, 4).reshape(b * kv, g, s, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    out = flash_attention_pallas(qk, kk, vk, causal=causal, window=window, **kw)
    return out.reshape(b, kv, g, s, hd).transpose(0, 3, 1, 2, 4)
