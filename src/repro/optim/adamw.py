"""AdamW with global-norm clipping and configurable moment dtype.

Moments inherit the parameter sharding (elementwise update), so under the
FSDP("data") x TP("model") rules the optimizer state is fully ZeRO-sharded
for free.  ``moment_dtype="bfloat16"`` halves optimizer HBM for the 100B+
archs (see EXPERIMENTS.md §Dry-run memory table).

Compressed SparseParams trees work out of the box: moments are allocated on
the *stored* leaf shapes, so an :class:`~repro.sparsity.params.NMCompressed`
projection's moments live on its ``(G, N, F)`` values — N/M of the dense
optimizer memory — and its integer ``indices`` leaf gets a size-0
placeholder and passes through every update untouched.

Structured-sparse backward (``StepConfig(grad_sparsity="nm")``) feeds this
optimizer MVU-sparsified gradients: unbiased elementwise, so the first
moment ``mu`` converges to the same EMA as under dense gradients, but with
extra variance ``a_j(S - a_j)`` per residual element (see
``docs/solver_math.md``).  That variance inflates ``nu`` (it estimates
``E[g^2] = E[g]^2 + Var``), which *shrinks* the effective per-element step —
a mild, self-regularising damping rather than an instability.  No optimizer
changes are needed; keep ``b2`` at its default so the inflated second
moment averages over many independent MVU draws.

Dynamic sparse training swaps the support under a live optimizer:
:func:`remap_moments` relays ``mu``/``nu`` across a
:func:`~repro.sparsity.params.recompress` — a slot that keeps its dense
position keeps its first/second moments, a position entering the support
starts with zero moments (the Adam cold-start for a weight that just
(re)appeared), and the bias-correction step count carries over.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def remap_moments(state: AdamWState, old_params, new_params) -> AdamWState:
    """Carry AdamW state across a SparseParams support swap.

    For every :class:`~repro.sparsity.params.NMCompressed` leaf whose
    indices changed between ``old_params`` and ``new_params`` (a
    :func:`~repro.sparsity.params.recompress`), ``mu``/``nu`` slots follow
    their dense positions: surviving positions keep their moments, entering
    positions start at zero, leaving positions are dropped.  The shared
    ``step`` (bias-correction) counter is preserved — the optimizer has
    genuinely taken that many steps.  Dense leaves pass through untouched.
    The slot bookkeeping is :func:`repro.sparsity.params.remap_tree`.
    """
    from repro.sparsity.params import remap_tree

    return AdamWState(
        step=state.step,
        mu=remap_tree(state.mu, old_params, new_params),
        nu=remap_tree(state.nu, old_params, new_params),
    )


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    moment_dtype: Optional[str] = None  # None -> param dtype

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype) if self.moment_dtype else None

        def zeros(p):
            if not jnp.issubdtype(p.dtype, jnp.inexact):
                return jnp.zeros((0,), jnp.float32)  # non-diff (e.g. indices)
            return jnp.zeros(p.shape, dt or p.dtype)

        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)
                    if g.dtype != jax.dtypes.float0)
            )
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        else:
            gnorm = jnp.asarray(0.0)
            scale = jnp.asarray(1.0)
        lr = self._lr(step)
        c1 = 1.0 - self.b1**step.astype(jnp.float32)
        c2 = 1.0 - self.b2**step.astype(jnp.float32)

        def upd(g, m, v, p):
            if not jnp.issubdtype(p.dtype, jnp.inexact):
                return p, m, v  # integer leaf (compressed indices): frozen
            g = g.astype(jnp.float32) * scale
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mh = m_new / c1
            vh = v_new / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m_new.astype(m.dtype), v_new.astype(v.dtype)

        flat_p, tree = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = jax.tree.unflatten(tree, [o[0] for o in out])
        new_mu = jax.tree.unflatten(tree, [o[1] for o in out])
        new_nu = jax.tree.unflatten(tree, [o[2] for o in out])
        return new_params, AdamWState(step, new_mu, new_nu), {
            "grad_norm": gnorm, "lr": lr,
        }
