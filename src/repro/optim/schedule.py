"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    peak: float, warmup_steps: int, total_steps: int, floor: float = 0.1
):
    """Linear warmup then cosine decay to ``floor * peak``."""

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        t = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
