"""Optimizer substrate (no optax in the container: built from scratch)."""
from repro.optim.adamw import AdamW, AdamWState, remap_moments
from repro.optim.schedule import warmup_cosine

__all__ = ["AdamW", "AdamWState", "remap_moments", "warmup_cosine"]
