"""PartitionSpec trees for parameters, train state and caches.

FSDP("data") x TP("model") rules (DESIGN.md §3): weight matrices are 2-D
sharded (in->"data", out->"model" or transposed for output projections);
expert tensors put E on "model" (EP) and d on "data"; norms and tiny SSM
params are replicated; the scan-stacked layer axis is always replicated.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# Leaf-name -> spec for (in-dim, out-dim)-style weights, *without* the
# stacked-layer axis (prepended for "blocks" leaves).
_LEAF_SPECS = {
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    "gate": P("data", "model"),
    "up": P("data", "model"),
    "down": P("model", "data"),
    "router": P("data", None),
    "in_proj": P("data", "model"),
    "out_proj": P("model", "data"),
    "conv_w": P(),
    "norm_w": P("model"),
    "a_log": P(),
    "d_skip": P(),
    "dt_bias": P(),
    "ln": P(),
    "ln1": P(),
    "ln2": P(),
}

_MOE_LEAF_SPECS = {
    "gate": P("model", "data", None),
    "up": P("model", "data", None),
    "down": P("model", None, "data"),
    "router": P("data", None),
}

# When the expert count doesn't divide the model axis (e.g. mixtral E=8 on a
# 16-wide TP axis), fall back to TP-sharding the per-expert matrices instead
# of replicating them.
_MOE_FALLBACK_SPECS = {
    "gate": P(None, "data", "model"),
    "up": P(None, "data", "model"),
    "down": P(None, "model", "data"),
}


def _spec_for_path(path, cfg: ModelConfig) -> P:
    keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    name = keys[-1]
    in_moe = "moe" in keys
    stacked = keys[0] == "blocks"
    if name == "embed":
        spec = P("model", "data")
    elif name == "unembed":
        spec = P("data", "model")
    elif name == "final_ln":
        spec = P()
    elif in_moe and name in _MOE_LEAF_SPECS:
        spec = _MOE_LEAF_SPECS[name]
    elif name in _LEAF_SPECS:
        spec = _LEAF_SPECS[name]
    else:
        spec = P()
    if stacked:
        spec = P(None, *spec)
    return spec


def param_specs(cfg: ModelConfig, params_shape: Any) -> Any:
    """Pytree of PartitionSpec matching an (abstract) params tree."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [_spec_for_path(path, cfg) for path, _ in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


def _fit(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop shard axes that don't divide the dim or exist in the mesh."""
    sizes = dict(mesh.shape)
    out = []
    for dim, s in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        axes = tuple(a for a in axes if a in sizes)
        total = 1
        for a in axes:
            total *= sizes[a]
        if total and dim % total == 0 and axes:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def batch_axes(mesh: Mesh, pure_dp: bool = False) -> tuple:
    names = ("pod", "data", "model") if pure_dp else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def batch_spec(mesh: Mesh, global_batch: int, ndim: int, pure_dp: bool = False) -> P:
    axes = batch_axes(mesh, pure_dp)
    total = 1
    for a in axes:
        total *= dict(mesh.shape)[a]
    lead = axes if (axes and global_batch % total == 0) else None
    if isinstance(lead, tuple) and len(lead) == 1:
        lead = lead[0]
    return P(lead, *([None] * (ndim - 1)))


def cache_specs(cfg: ModelConfig, caches_shape: Any, mesh: Mesh) -> Any:
    """Specs for (unstacked, per-layer) decode caches: batch -> data axes;
    KV heads -> model when divisible, else the cache *sequence* dim takes
    "model" (context parallelism); when the batch doesn't shard (long_500k
    B=1) the sequence also takes "data"."""
    from repro.models.attention import KVCache
    from repro.models.mamba2 import SSMCache

    sizes = dict(mesh.shape)
    baxes = batch_axes(mesh)
    btotal = 1
    for a in baxes:
        btotal *= sizes[a]

    def b_spec_for(bdim: int):
        ok = btotal > 1 and bdim % btotal == 0
        return (baxes if len(baxes) > 1 else baxes[0]) if (baxes and ok) else None

    def kv_spec(shape):
        # (B, S_buf, KV, hd)
        b_spec = b_spec_for(shape[0])
        kv = "model" if shape[2] % sizes.get("model", 1) == 0 else None
        seq = None
        if kv is None and "model" in sizes and shape[1] % sizes["model"] == 0:
            seq = "model"
        if b_spec is None and "data" in sizes and shape[1] % sizes["data"] == 0:
            seq = ("data", seq) if seq else "data"
        return _fit(P(b_spec, seq, kv, None), shape, mesh)

    def walk(node):
        if isinstance(node, KVCache):
            return KVCache(
                k=kv_spec(node.k.shape), v=kv_spec(node.v.shape), index=P()
            )
        if isinstance(node, SSMCache):
            return SSMCache(
                conv=_fit(P(b_spec_for(node.conv.shape[0]), None, "model"),
                          node.conv.shape, mesh),
                state=_fit(P(b_spec_for(node.state.shape[0]), "model", None, None),
                           node.state.shape, mesh),
            )
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return P()

    return walk(caches_shape)


def _fit_preserves(spec: P, shape: tuple, mesh: Mesh) -> bool:
    return _fit(spec, shape, mesh) == P(
        *(tuple(spec) + (None,) * (len(shape) - len(spec)))
    )


def fit_param_specs(
    cfg: ModelConfig, params_shape: Any, mesh: Mesh, pure_dp: bool = False
) -> Any:
    """param_specs with every axis validated against the mesh/shape; MoE
    expert matrices fall back to TP sharding when EP doesn't divide.

    ``pure_dp``: drop "model" from param specs (params replicated over the
    model axis; the batch takes it instead) — the right recipe for sub-1B
    archs where TP shards are smaller than a VPU tile (EXPERIMENTS.md §Perf).
    """
    flat = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat[0]:
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name, in_moe, stacked = keys[-1], "moe" in keys, keys[0] == "blocks"
        spec = _spec_for_path(path, cfg)
        if in_moe and name in _MOE_FALLBACK_SPECS:
            if not _fit_preserves(spec, leaf.shape, mesh):
                fb = _MOE_FALLBACK_SPECS[name]
                spec = P(None, *fb) if stacked else fb
        if pure_dp:
            spec = P(*(
                None if s == "model" else s for s in spec
            ))
        out.append(_fit(spec, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(flat[1], out)


def shardings_of(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def as_sds(shape_tree: Any, sharding_tree: Any) -> Any:
    """ShapeDtypeStructs with shardings attached (dry-run inputs)."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shape_tree,
        sharding_tree,
    )
