"""Shared building blocks: norms, MLP, embeddings, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * w.astype(dtype)


def swiglu_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Gated MLP: down( silu(x@gate) * (x@up) )."""
    h = jax.nn.silu(x @ p["gate"].astype(x.dtype)) * (x @ p["up"].astype(x.dtype))
    h = shard(h, "act_batch", "act_seq", "act_heads")
    return h @ p["down"].astype(x.dtype)


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0).astype(dtype)
    return shard(out, "act_batch", "act_seq", "act_embed")


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    logits = x @ table.astype(x.dtype)
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, ignore_index: int = -1
) -> jnp.ndarray:
    """Mean CE over non-ignored positions; stable in float32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    ok = labels != ignore_index
    return jnp.sum(jnp.where(ok, nll, 0.0)) / jnp.maximum(jnp.sum(ok), 1)


# ---------------------------------------------------------------------------
# Init helpers.
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (std = scale or 1/sqrt(fan_in))."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(
        dtype
    )
