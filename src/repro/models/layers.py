"""Shared building blocks: norms, MLP, embeddings, init helpers.

Projection matmuls go through :func:`proj`, which dispatches per parameter
leaf at trace time: dense leaves stay plain ``x @ w`` (bit-identical to the
historical path), :class:`~repro.sparsity.params.NMCompressed` leaves execute
through the compressed transposable-N:M kernel (``nm_linear_nd``) — forward
AND input-gradient matmuls read the same compressed buffer, never a dense W.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels.nm_grad.ops import current_sparse_grad, nm_linear_sg_nd
from repro.kernels.nm_spmm.ops import nm_linear_nd
from repro.sparsity.params import NMCompressed


def proj(x: jnp.ndarray, w) -> jnp.ndarray:
    """``x @ w`` for a dense OR compressed (``NMCompressed``) weight leaf.

    The isinstance branch resolves at trace time, so under ``jit`` each leaf
    compiles to exactly one of the two paths — mixed trees (pruned
    projections compressed, embeddings dense) cost nothing extra.

    When a :func:`repro.kernels.nm_grad.ops.sparse_grad_context` is active
    (``StepConfig(grad_sparsity=...)``), compressed leaves route through the
    structured-sparse-backward op instead: the forward is identical, the
    backward N:M-sparsifies ``dY`` in-flight so BOTH backward GEMMs stream
    compressed operands.  Dense leaves are unaffected either way.
    """
    if isinstance(w, NMCompressed):
        ctx = current_sparse_grad()
        if ctx is not None:
            return nm_linear_sg_nd(x, w.values, w.indices, w.m, ctx)
        return nm_linear_nd(x, w.values, w.indices, w.m)
    return x @ w.astype(x.dtype)


def expert_einsum(eq: str, xe: jnp.ndarray, w) -> jnp.ndarray:
    """Per-expert einsum (``"gecd,edf->gecf"`` / ``"gecf,efd->gecd"``) with
    compressed-dispatch support.

    Dense leaves keep the exact historical ``jnp.einsum`` (bit-identical).
    ``NMCompressed`` leaves — stacked ``(E, G, N, F)`` buffers — unroll over
    the expert axis and route each expert's ``(g, c, d) @ (d, f)`` through
    :func:`proj`, so expert FFNs inherit compressed execution AND sparse
    gradients from the same dispatch point as the dense projections.
    """
    if not isinstance(w, NMCompressed):
        return jnp.einsum(eq, xe, w.astype(xe.dtype))
    e = xe.shape[1]
    outs = [
        proj(xe[:, ei], NMCompressed(w.values[ei], w.indices[ei], w.m))
        for ei in range(e)
    ]
    return jnp.stack(outs, axis=1)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * w.astype(dtype)


def swiglu_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Gated MLP: down( silu(x@gate) * (x@up) )."""
    h = jax.nn.silu(proj(x, p["gate"])) * proj(x, p["up"])
    h = shard(h, "act_batch", "act_seq", "act_heads")
    return proj(h, p["down"])


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0).astype(dtype)
    return shard(out, "act_batch", "act_seq", "act_embed")


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    logits = x @ table.astype(x.dtype)
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, ignore_index: int = -1
) -> jnp.ndarray:
    """Mean CE over non-ignored positions; stable in float32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    ok = labels != ignore_index
    return jnp.sum(jnp.where(ok, nll, 0.0)) / jnp.maximum(jnp.sum(ok), 1)


# ---------------------------------------------------------------------------
# Init helpers.
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (std = scale or 1/sqrt(fan_in))."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(
        dtype
    )
