"""Unified language model: init, training forward, prefill and decode.

One code path serves all ten assigned architectures; the family switch picks
block kinds, the layer stack is a ``lax.scan`` over stacked parameters (keeps
HLO size and compile time bounded for 94-layer models on 512-device meshes),
and remat policy comes from the config.

Hybrid (zamba2) models scan over *groups* of ``hybrid_attn_every`` ssm layers
and apply the shared-weight attention block between groups, so only
``num_layers // every`` KV caches exist — the reason 500k-token decode fits.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels.nm_grad.ops import sparse_grad_layer
from repro.models import transformer as tf
from repro.models.attention import init_kv_cache
from repro.models.config import ModelConfig
from repro.models.layers import cross_entropy, dense_init, embed_tokens, rms_norm, unembed
from repro.models.mamba2 import init_ssm_cache


def _act_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = _param_dtype(cfg)
    k_emb, k_blocks, k_shared, k_out = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_blocks, cfg.num_layers)

    if cfg.family in ("ssm", "hybrid"):
        blocks = jax.vmap(lambda k: tf.init_ssm_block(k, cfg, dtype))(layer_keys)
    else:
        blocks = jax.vmap(lambda k: tf.init_attn_block(k, cfg, dtype))(layer_keys)

    params = {
        "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
        "blocks": blocks,
        "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": dense_init(k_out, (cfg.d_model, cfg.vocab_size), dtype),
    }
    if cfg.hybrid_attn_every:
        params["shared"] = tf.init_attn_block(k_shared, cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# Layer-stack runners.
# ---------------------------------------------------------------------------


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _layer_params(blocks, l):
    return jax.tree.map(lambda a: a[l], blocks)


def _run_attn_stack(params, x, cfg, positions, caches):
    """Training path scans over stacked blocks (bounded HLO/compile time).

    Serving paths (caches given) UNROLL the layer loop with *per-layer*
    cache tensors: scanning with caches (as xs/ys or carry) makes XLA copy
    the whole (L, B, S, KV, hd) buffer every token — measured 87 GB/step on
    granite decode_32k — whereas unrolled per-layer buffers alias the
    one-token dynamic-update-slice in place.  Serving HLO is ~L x larger but
    each layer is a handful of GEMV ops.
    """

    def body_nocache(x, xs):
        lp, li = xs
        x = shard(x, "act_batch", "act_seq", "act_embed")
        with sparse_grad_layer(li):  # no-op unless sparse-grad ctx active
            x, _ = tf.attn_block_apply(lp, x, cfg, positions, None)
        return x, None

    if caches is None:
        x, _ = jax.lax.scan(
            _remat(body_nocache, cfg), x,
            (params["blocks"], jnp.arange(cfg.num_layers)),
        )
        return x, None
    new_caches = []
    for l in range(cfg.num_layers):
        x = shard(x, "act_batch", "act_seq", "act_embed")
        x, nc = tf.attn_block_apply(
            _layer_params(params["blocks"], l), x, cfg, positions, caches[l]
        )
        new_caches.append(nc)
    return x, new_caches


def _run_ssm_stack(params, x, cfg, caches):
    def body_nocache(x, xs):
        lp, li = xs
        x = shard(x, "act_batch", "act_seq", "act_embed")
        with sparse_grad_layer(li):
            x, _ = tf.ssm_block_apply(lp, x, cfg, None)
        return x, None

    if caches is None:
        x, _ = jax.lax.scan(
            _remat(body_nocache, cfg), x,
            (params["blocks"], jnp.arange(cfg.num_layers)),
        )
        return x, None
    new_caches = []
    for l in range(cfg.num_layers):
        x = shard(x, "act_batch", "act_seq", "act_embed")
        x, nc = tf.ssm_block_apply(
            _layer_params(params["blocks"], l), x, cfg, caches[l]
        )
        new_caches.append(nc)
    return x, new_caches


def _hybrid_groups(cfg: ModelConfig):
    every = cfg.hybrid_attn_every
    full = cfg.num_layers // every
    tail = cfg.num_layers - full * every
    return every, full, tail


def _slice_blocks(blocks, start, count):
    return jax.tree.map(lambda a: a[start : start + count], blocks)


def _run_hybrid_stack(params, x, cfg, positions, ssm_caches, kv_caches):
    """Groups of `every` ssm layers, shared attention block between groups.

    Caches stay in carries / are updated at indices in place (see
    _run_attn_stack) so nothing is copied wholesale per token.
    """
    every, full, tail = _hybrid_groups(cfg)

    def ssm_body_nocache(x, xs):
        lp, li = xs
        x = shard(x, "act_batch", "act_seq", "act_embed")
        with sparse_grad_layer(li):
            x, _ = tf.ssm_block_apply(lp, x, cfg, None)
        return x, None

    groups = [(g * every, every) for g in range(full)]
    if tail:
        groups.append((full * every, tail))

    new_ssm, new_kv = [], []
    for gidx, (start, count) in enumerate(groups):
        lp = _slice_blocks(params["blocks"], start, count)
        if ssm_caches is None:
            x, _ = jax.lax.scan(
                _remat(ssm_body_nocache, cfg), x,
                (lp, jnp.arange(start, start + count)),
            )
        else:
            for l in range(start, start + count):
                x = shard(x, "act_batch", "act_seq", "act_embed")
                x, nc = tf.ssm_block_apply(
                    _layer_params(params["blocks"], l), x, cfg, ssm_caches[l]
                )
                new_ssm.append(nc)
        if gidx < full:  # shared attention after each complete group
            kvc = None if kv_caches is None else kv_caches[gidx]
            if ssm_caches is None:
                x, nkv = _remat(
                    lambda x, c: tf.attn_block_apply(
                        params["shared"], x, cfg, positions, c
                    ),
                    cfg,
                )(x, kvc)
            else:
                x, nkv = tf.attn_block_apply(
                    params["shared"], x, cfg, positions, kvc
                )
            if kv_caches is not None:
                new_kv.append(nkv)
    return x, new_ssm if ssm_caches is not None else None, (
        new_kv if kv_caches is not None else None
    )


# ---------------------------------------------------------------------------
# Public entry points.
# ---------------------------------------------------------------------------


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Training forward -> logits (B, S, V)."""
    dtype = _act_dtype(cfg)
    if embeds is None:
        x = embed_tokens(params["embed"], tokens, dtype)
    else:
        x = embeds.astype(dtype)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family == "ssm":
        x, _ = _run_ssm_stack(params, x, cfg, None)
    elif cfg.family == "hybrid":
        x, _, _ = _run_hybrid_stack(params, x, cfg, positions, None, None)
    else:
        x, _ = _run_attn_stack(params, x, cfg, positions, None)

    x = rms_norm(x, params["final_ln"])
    return unembed(x, params["unembed"])


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    logits = forward(
        params,
        cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions=batch.get("positions"),
    )
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Decode caches: PER-LAYER lists (unstacked) so serving's unrolled layer
    loop aliases every cache update in place (see _run_attn_stack)."""
    dtype = _act_dtype(cfg)
    if cfg.family == "ssm":
        return [init_ssm_cache(cfg, batch, dtype) for _ in range(cfg.num_layers)]
    if cfg.family == "hybrid":
        every, full, tail = _hybrid_groups(cfg)
        return {
            "ssm": [init_ssm_cache(cfg, batch, dtype) for _ in range(cfg.num_layers)],
            "kv": [init_kv_cache(cfg, batch, max_len, dtype) for _ in range(full)],
        }
    return [init_kv_cache(cfg, batch, max_len, dtype) for _ in range(cfg.num_layers)]


def prefill(
    params: dict,
    cfg: ModelConfig,
    caches: Any,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
):
    """Process a prompt, returning (last-position logits, filled caches)."""
    dtype = _act_dtype(cfg)
    x = embed_tokens(params["embed"], tokens, dtype) if embeds is None else embeds.astype(dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family == "ssm":
        x, caches = _run_ssm_stack(params, x, cfg, caches)
    elif cfg.family == "hybrid":
        x, ssm, kv = _run_hybrid_stack(
            params, x, cfg, positions, caches["ssm"], caches["kv"]
        )
        caches = {"ssm": ssm, "kv": kv}
    else:
        x, caches = _run_attn_stack(params, x, cfg, positions, caches)

    x = rms_norm(x[:, -1:, :], params["final_ln"])
    return unembed(x, params["unembed"])[:, 0], caches


def decode_step(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B,) int32
    caches: Any,
    index: jnp.ndarray,  # () int32 current absolute position
):
    """One autoregressive step with a filled cache -> (logits (B,V), caches)."""
    dtype = _act_dtype(cfg)
    x = embed_tokens(params["embed"], token[:, None], dtype)  # (B, 1, d)
    b = x.shape[0]
    positions = jnp.broadcast_to(index.astype(jnp.int32), (b, 1))

    if cfg.family == "ssm":
        x, caches = _run_ssm_stack(params, x, cfg, caches)
    elif cfg.family == "hybrid":
        x, ssm, kv = _run_hybrid_stack(
            params, x, cfg, positions, caches["ssm"], caches["kv"]
        )
        caches = {"ssm": ssm, "kv": kv}
    else:
        x, caches = _run_attn_stack(params, x, cfg, positions, caches)

    x = rms_norm(x, params["final_ln"])
    return unembed(x, params["unembed"])[:, 0], caches
