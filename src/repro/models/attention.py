"""GQA attention with RoPE / M-RoPE, sliding windows, online-softmax
training path and ring-buffer KV caches for decode.

The training/prefill path is a chunked online-softmax (flash-style) scan over
KV chunks, so the (S x S) score matrix is never materialized — on TPU the
per-chunk einsums feed the MXU and the running max/denominator stay in
registers (XLA fuses the scan body).  Decode uses a single einsum against the
cache; sliding-window archs keep a ring buffer of size `window`, which is what
makes mixtral's 500k-token decode cell feasible (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_mesh, model_axis_size, shard
from repro.models.config import ModelConfig
from repro.models.layers import proj

NEG_INF = -1e30


def _use_seq_parallel_attn(cfg: ModelConfig, s: int) -> bool:
    """Head counts that don't divide the TP axis leave the flash-scan score
    tensors unsharded on "model" (measured 100+ TB/step on phi3 train_4k).
    In that case shard the *query sequence* over "model" instead — S always
    divides — and let k/v replicate (they are tiny next to scores)."""
    ms = model_axis_size(current_mesh())
    if ms <= 1 or s % ms != 0 or s == 1:
        return False
    return cfg.num_heads % ms != 0 or cfg.num_kv_heads % ms != 0


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_buf, KV, hd)
    v: jnp.ndarray        # (B, S_buf, KV, hd)
    index: jnp.ndarray    # () int32 — next absolute position


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE).
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    mrope_sections: Optional[tuple] = None,
) -> jnp.ndarray:
    """Apply rotary embedding.

    x: (B, S, H, hd); positions: (B, S) or (3, B, S) for M-RoPE.
    M-RoPE (qwen2-vl): the hd/2 frequency slots are split into 3 sections
    (temporal, height, width) that take positions from the corresponding
    stream.  Text tokens use identical streams, recovering standard RoPE.
    """
    b, s, h, hd = x.shape
    freqs = _rope_freqs(hd, theta)  # (hd/2,)
    if mrope_sections is not None:
        assert sum(mrope_sections) == hd // 2, (mrope_sections, hd)
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None], (3, b, s))
        sec_id = jnp.repeat(
            jnp.arange(3), jnp.array(mrope_sections), total_repeat_length=hd // 2
        )
        pos_per_freq = positions[sec_id]  # (hd/2, B, S)
        angle = jnp.einsum("fbs,f->bsf", pos_per_freq.astype(jnp.float32), freqs)
    else:
        if positions.ndim == 3:
            positions = positions[0]
        angle = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd/2)
    cos = jnp.cos(angle)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angle)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Chunked online-softmax causal attention (training / prefill).
# ---------------------------------------------------------------------------


def _flash_attention(
    q: jnp.ndarray,  # (B, S, KV, G, hd)  — query heads grouped per KV head
    k: jnp.ndarray,  # (B, S, KV, hd)
    v: jnp.ndarray,  # (B, S, KV, hd)
    q_pos: jnp.ndarray,  # (B, S) absolute positions of queries
    k_pos: jnp.ndarray,  # (B, S) absolute positions of keys
    window: int,
    chunk: int,
) -> jnp.ndarray:
    b, s, kvh, g, hd = q.shape
    scale = hd**-0.5
    nc = -(-k.shape[1] // chunk)
    pad = nc * chunk - k.shape[1]
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-(10**9))
    k_c = k.reshape(b, nc, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, nc, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    p_c = k_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

    qs = (q * scale).astype(q.dtype)  # keep operands narrow; accumulate f32

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        sc = jnp.einsum(
            "bsngh,bcnh->bsngc", qs, kc, preferred_element_type=jnp.float32
        )  # (B,S,KV,G,C) f32 accum without materializing f32 operands
        causal = q_pos[:, :, None] >= pc[:, None, :]  # (B, S, C)
        if window:
            causal &= (q_pos[:, :, None] - pc[:, None, :]) < window
        sc = jnp.where(causal[:, :, None, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bsngc,bcnh->bsngh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, s, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, kvh, g), jnp.float32)
    acc0 = jnp.zeros((b, s, kvh, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (k_c, v_c, p_c))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention layer.
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    from repro.models.layers import dense_init

    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype, scale=(h * hd) ** -0.5
                         / (2 * cfg.num_layers) ** 0.5),
    }


def attention(
    p: dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    positions: jnp.ndarray,
    cache: Optional[KVCache] = None,
    capture: Optional[dict] = None,
):
    """Returns (out (B,S,d), new_cache).

    Modes:
      * cache is None                  -> training forward (chunked causal).
      * cache given, update_cache      -> decode step (S==1) or prefill write.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kv
    q = proj(x, p["wq"]).reshape(b, s, h, hd)
    k = proj(x, p["wk"]).reshape(b, s, kv, hd)
    v = proj(x, p["wv"]).reshape(b, s, kv, hd)
    if _use_seq_parallel_attn(cfg, s):
        q = shard(q, "act_batch", "act_attn_seq", None, None)
        k = shard(k, "act_batch", None, None, None)
        v = shard(v, "act_batch", None, None, None)
    else:
        q = shard(q, "act_batch", "act_seq", "act_heads", None)
        k = shard(k, "act_batch", "act_seq", "act_heads", None)
        v = shard(v, "act_batch", "act_seq", "act_heads", None)

    pos2 = positions[0] if positions.ndim == 3 else positions
    q = rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    if cache is None:
        qg = q.reshape(b, s, kv, g, hd)
        out = _flash_attention(
            qg, k, v, pos2, pos2, cfg.sliding_window, min(cfg.attn_chunk, s)
        )
        out = out.reshape(b, s, h * hd)
        new_cache = None
    else:
        s_buf = cache.k.shape[1]
        if s == 1:
            # Decode: write this token's K/V into the (ring) buffer.
            slot = (
                cache.index % s_buf if cfg.sliding_window else cache.index
            )
            ck = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0)
            )
            new_cache = KVCache(ck, cv, cache.index + 1)
            # Valid cache positions: absolute position of each buffer slot.
            slots = jnp.arange(s_buf)
            if cfg.sliding_window:
                # Ring: slot holds absolute pos p with p % s_buf == slot and
                # p <= index;  p = index - ((index - slot) % s_buf).
                abs_pos = cache.index - ((cache.index - slots) % s_buf)
            else:
                abs_pos = slots
            valid = (abs_pos <= cache.index) & (abs_pos >= 0)
            if cfg.sliding_window:
                valid &= (cache.index - abs_pos) < cfg.sliding_window
            # Never convert the cache: bf16 reads, f32 MXU accumulation.
            qg = (q.reshape(b, 1, kv, g, hd) * hd**-0.5).astype(ck.dtype)
            sc = jnp.einsum(
                "bsngh,bcnh->bsngc", qg, ck, preferred_element_type=jnp.float32
            )
            sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
            w = jax.nn.softmax(sc, axis=-1)
            out = jnp.einsum(
                "bsngc,bcnh->bsngh", w.astype(cv.dtype), cv,
                preferred_element_type=jnp.float32,
            )
            out = out.reshape(b, 1, h * hd).astype(x.dtype)
        else:
            # Prefill: run flash attention and write the cache.
            qg = q.reshape(b, s, kv, g, hd)
            out = _flash_attention(
                qg, k, v, pos2, pos2, cfg.sliding_window, min(cfg.attn_chunk, s)
            ).reshape(b, s, h * hd)
            if cfg.sliding_window and s_buf < s:
                # Place the last s_buf tokens at their ring slots (pos % s_buf).
                tail = s - s_buf
                last_pos = jnp.arange(tail, s)
                ck = jnp.zeros_like(cache.k).at[:, last_pos % s_buf].set(
                    k[:, tail:].astype(cache.k.dtype)
                )
                cv = jnp.zeros_like(cache.v).at[:, last_pos % s_buf].set(
                    v[:, tail:].astype(cache.v.dtype)
                )
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)
                )
            new_cache = KVCache(ck, cv, cache.index + s)

    if _use_seq_parallel_attn(cfg, s):
        out = shard(out, "act_batch", "act_attn_seq", None)
    else:
        out = shard(out, "act_batch", "act_seq", "act_heads")
    if capture is not None:
        capture["pre_out"] = out  # inputs to wo — used by layer-wise pruning
    return proj(out, p["wo"]), new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    """Per-layer cache buffer; sliding-window archs use a ring of size window."""
    s_buf = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, s_buf, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        index=jnp.zeros((), jnp.int32),
    )
