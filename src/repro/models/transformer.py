"""Decoder blocks for every assigned family, with cache plumbing.

Block kinds:
  * attention block ("dense"/"moe"/"vlm"/"audio"): pre-RMSNorm attn + SwiGLU
    MLP (or GSPMD MoE).
  * ssm block ("ssm"): pre-RMSNorm Mamba2/SSD (no MLP, following Mamba2).
  * hybrid ("hybrid", zamba2-style): ssm blocks; one *shared-weight*
    attention+MLP block applied after every ``hybrid_attn_every`` layers.

Attention/MLP projection leaves may be dense arrays OR compressed
:class:`~repro.sparsity.params.NMCompressed` buffers (SparseParams): the
matmuls route through :func:`repro.models.layers.proj`, which dispatches
per leaf, so the same block code serves dense training, masked fine-tuning
and fully compressed execution.  MoE expert tensors and Mamba projections
stay dense (their einsums don't route through ``proj``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import moe
from repro.models.attention import KVCache, attention, init_attention
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm, swiglu_mlp
from repro.models.mamba2 import SSMCache, init_mamba, mamba_block


def init_mlp(key, cfg: ModelConfig, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "gate": dense_init(ks[0], (d, ff), dtype),
        "up": dense_init(ks[1], (d, ff), dtype),
        "down": dense_init(ks[2], (ff, d), dtype,
                           scale=ff**-0.5 / (2 * cfg.num_layers) ** 0.5),
    }


def init_attn_block(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ka, km = jax.random.split(key)
    block = {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": init_attention(ka, cfg, dtype),
        "ln2": jnp.ones((d,), jnp.float32),
    }
    if cfg.is_moe:
        block["moe"] = moe.init_moe(km, cfg, dtype)
    else:
        block["mlp"] = init_mlp(km, cfg, dtype)
    return block


def init_ssm_block(key, cfg: ModelConfig, dtype) -> dict:
    return {
        "ln": jnp.ones((cfg.d_model,), jnp.float32),
        "mamba": init_mamba(key, cfg, dtype),
    }


def attn_block_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    cache: Optional[KVCache] = None,
):
    h = rms_norm(x, p["ln1"])
    out, new_cache = attention(p["attn"], h, cfg, positions, cache)
    x = x + out
    h = rms_norm(x, p["ln2"])
    if cfg.is_moe:
        x = x + moe.moe_ffn(p["moe"], h, cfg)
    else:
        x = x + swiglu_mlp(p["mlp"], h)
    return x, new_cache


def ssm_block_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[SSMCache] = None,
):
    h = rms_norm(x, p["ln"])
    out, new_cache = mamba_block(p["mamba"], h, cfg, cache)
    return x + out, new_cache
