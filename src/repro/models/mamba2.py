"""Mamba2 (SSD — state-space duality) block, chunked for TPU.

Forward uses the SSD chunked decomposition [Dao & Gu 2024]: within-chunk
attention-like quadratic term + across-chunk recurrent state carried by a
``lax.scan`` (seq/chunk steps).  Decode maintains O(1) state per layer:
a (heads, head_dim, state) SSM state and a (kernel-1, conv_dim) conv tail —
this is what makes the 500k-token decode cell trivial for SSM archs.

Sharding: d_inner (heads) is TP-sharded on "model"; the SSM state tensors
inherit it.  in/out projections are the FLOP carriers and are the matrices
TSENOR prunes (DESIGN.md §4); conv/Δ/A/D params are exempt (1-D / tiny).
Both projections go through :func:`repro.models.layers.proj`, so pruned
``NMCompressed`` leaves execute compressed (and pick up sparse gradients)
exactly like the attention/MLP projections; dense leaves compile to the
same ``x @ w.astype`` as before.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # (B, kernel-1, conv_dim) trailing conv inputs
    state: jnp.ndarray  # (B, H, P, N) SSM state


def _dims(cfg: ModelConfig):
    din = cfg.d_inner
    nheads = cfg.ssm_heads
    dstate = cfg.ssm_state
    conv_dim = din + 2 * dstate
    return din, nheads, cfg.ssm_head_dim, dstate, conv_dim


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    from repro.models.layers import dense_init

    d = cfg.d_model
    din, nh, hp, ns, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * din + 2 * ns + nh
    return {
        "in_proj": dense_init(ks[0], (d, d_in_proj), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_dim), dtype, scale=0.3),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(
                        ks[2], (nh,), jnp.float32,
                        jnp.log(1e-3), jnp.log(1e-1),
                    )
                )
            )
        ),
        "norm_w": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[3], (din, d), dtype,
                               scale=din**-0.5 / (2 * cfg.num_layers) ** 0.5),
    }


def _split_in_proj(zxbcdt, cfg):
    din, nh, hp, ns, conv_dim = _dims(cfg)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : din + conv_dim]
    dt = zxbcdt[..., din + conv_dim :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, tail: Optional[jnp.ndarray]):
    """Depthwise causal conv along seq.  xbc: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, C)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i].astype(xbc.dtype) for i in range(k)
    )
    new_tail = xp[:, -(k - 1) :, :] if k > 1 else None
    return jax.nn.silu(out), new_tail


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + 1e-5) * w


def mamba_block(
    p: dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    cache: Optional[SSMCache] = None,
):
    """Returns (out (B,S,d), new_cache)."""
    from repro.models.layers import proj

    b, s, d = x.shape
    din, nh, hp, ns, conv_dim = _dims(cfg)
    zxbcdt = proj(x, p["in_proj"])
    z, xbc, dt_raw = _split_in_proj(zxbcdt, cfg)
    a = -jnp.exp(p["a_log"])  # (H,) negative

    if cache is None or s > 1:
        tail = cache.conv if cache is not None else None
        xbc, new_tail = _causal_conv(xbc, p["conv_w"], tail)
        xs = xbc[..., :din].reshape(b, s, nh, hp)
        bmat = xbc[..., din : din + ns]
        cmat = xbc[..., din + ns :]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
        y, state = _ssd_chunked(xs, bmat, cmat, dt, a, cfg)
        y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
        y = y.reshape(b, s, din)
        y = _gated_norm(y, z, p["norm_w"]).astype(x.dtype)
        out = proj(y, p["out_proj"])
        new_cache = None
        if cache is not None:
            new_cache = SSMCache(conv=new_tail.astype(cache.conv.dtype),
                                 state=state.astype(cache.state.dtype))
        return out, new_cache

    # Single-token decode: O(1) recurrent update.
    conv_in = jnp.concatenate([cache.conv.astype(x.dtype), xbc], axis=1)
    xbc1 = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_in, p["conv_w"].astype(x.dtype))
    )[:, None, :]
    new_conv = conv_in[:, 1:, :]
    xs = xbc1[..., :din].reshape(b, nh, hp)
    bmat = xbc1[:, 0, din : din + ns]  # (B, N)
    cmat = xbc1[:, 0, din + ns :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp(dt * a)  # (B, H)
    xf = xs.astype(jnp.float32)
    state = cache.state.astype(jnp.float32) * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xf, bmat.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cmat.astype(jnp.float32))
    y = y + xf * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, din)
    y = _gated_norm(y, z, p["norm_w"]).astype(x.dtype)
    out = proj(y, p["out_proj"])
    return out, SSMCache(conv=new_conv.astype(cache.conv.dtype),
                         state=state.astype(cache.state.dtype))


def _ssd_chunked(xs, bmat, cmat, dt, a, cfg: ModelConfig):
    """Chunked SSD scan.

    xs: (B,S,H,P); bmat/cmat: (B,S,N); dt: (B,S,H); a: (H,).
    Returns (y (B,S,H,P) float32, final_state (B,H,P,N) float32).
    """
    b, s, nh, hp = xs.shape
    ns = bmat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xf = xs.astype(jnp.float32).reshape(b, nc, q, nh, hp)
    bf = bmat.astype(jnp.float32).reshape(b, nc, q, ns)
    cf = cmat.astype(jnp.float32).reshape(b, nc, q, ns)
    dtc = dt.reshape(b, nc, q, nh)
    da = dtc * a  # (B,NC,Q,H) negative increments
    cum = jnp.cumsum(da, axis=2)  # inclusive within-chunk cumsum
    seg_total = cum[:, :, -1, :]  # (B,NC,H)

    # Within-chunk (quadratic) term: y_i += sum_{j<=i} C_i·B_j exp(cum_i-cum_j) dt_j x_j
    cb = jnp.einsum("bcqn,bckn->bcqk", cf, bf)  # (B,NC,Q,Q)
    decay = jnp.exp(
        cum[:, :, :, None, :] - cum[:, :, None, :, :]
    )  # (B,NC,Q(i),Q(j),H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    scores = cb[..., None] * lmat * dtc[:, :, None, :, :]  # (B,NC,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xf)

    # Chunk-boundary states: S_c = sum_j exp(seg_total - cum_j) dt_j B_j x_j^T
    w_state = jnp.exp(seg_total[:, :, None, :] - cum) * dtc  # (B,NC,Q,H)
    s_chunk = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w_state, xf, bf)

    def scan_body(state, xs_c):
        s_c, seg_c = xs_c  # (B,H,P,N), (B,H)
        new_state = state * jnp.exp(seg_c)[:, :, None, None] + s_c
        return new_state, state  # emit the *incoming* state for this chunk

    init = jnp.zeros((b, nh, hp, ns), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_body,
        init,
        (s_chunk.transpose(1, 0, 2, 3, 4), seg_total.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,NC,H,P,N)

    # Across-chunk term: y_i += exp(cum_i) C_i · state_prev
    y_inter = jnp.einsum(
        "bcqh,bcqn,bchpn->bcqhp", jnp.exp(cum), cf, prev_states
    )
    y = (y_intra + y_inter).reshape(b, nc * q, nh, hp)[:, :s]
    return y, final_state


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    din, nh, hp, ns, conv_dim = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        state=jnp.zeros((batch, nh, hp, ns), jnp.float32),
    )
