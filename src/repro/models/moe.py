"""GSPMD-style token-choice top-k MoE with capacity-bounded dispatch.

Tokens are processed in fixed-size groups; routing builds (group, token,
expert, capacity) dispatch/combine tensors via k rounds of top-1 selection
with per-expert capacity counters (the Switch/GSPMD pattern generalized to
top-k).  Expert FFN weights are stacked (E, d, ff) and sharded
experts->"model", d->"data": the dispatch einsum reshards tokens from
data-parallel groups to expert-parallel shards, which XLA lowers to the
canonical MoE all-to-all — visible in the dry-run HLO and counted by the
roofline (EXPERIMENTS.md §Dry-run).

The group size bounds the dispatch tensor to
  tokens/group * group * E * C  with  C = ceil(group * k / E * capacity_factor),
i.e. O(tokens * group * k * cf) elements regardless of E — set
``cfg.moe_group`` to trade routing memory against load-balance slack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    from repro.models.layers import dense_init

    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "gate": dense_init(ks[1], (e, d, ff), dtype),
        "up": dense_init(ks[2], (e, d, ff), dtype),
        "down": dense_init(ks[3], (e, ff, d), dtype,
                           scale=ff**-0.5 / (2 * cfg.num_layers) ** 0.5),
    }


def _capacity(group: int, cfg: ModelConfig) -> int:
    c = int(group * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    group = min(cfg.moe_group, t)
    assert t % group == 0, (t, group)
    ng = t // group
    cap = _capacity(group, cfg)

    xs = x.reshape(ng, group, d)
    logits = (xs.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)

    # k rounds of top-1 with capacity counters.
    gates = probs
    combine = jnp.zeros((ng, group, e, cap), jnp.float32)
    expert_count = jnp.zeros((ng, e), jnp.int32)
    gate_sum = jnp.zeros((ng, group), jnp.float32)
    for _ in range(k):
        eidx = jnp.argmax(gates, axis=-1)  # (G, g)
        oh = jax.nn.one_hot(eidx, e, dtype=jnp.float32)  # (G, g, E)
        # Position of each token within its expert's buffer this round.
        pos = jnp.cumsum(oh, axis=1) - 1 + expert_count[:, None, :].astype(jnp.float32)
        pos_tok = jnp.sum(pos * oh, axis=-1)  # (G, g)
        keep = pos_tok < cap
        gval = jnp.sum(gates * oh, axis=-1)  # (G, g)
        pos_oh = jax.nn.one_hot(pos_tok.astype(jnp.int32), cap, dtype=jnp.float32)
        combine = combine + (gval * keep)[..., None, None] * (
            oh[..., None] * pos_oh[:, :, None, :]
        )
        gate_sum = gate_sum + gval * keep
        expert_count = expert_count + jnp.sum(
            oh * keep[..., None], axis=1
        ).astype(jnp.int32)
        gates = gates * (1.0 - oh)  # exclude chosen expert from later rounds
    if cfg.norm_topk:
        combine = combine / jnp.maximum(gate_sum, 1e-9)[..., None, None]
    dispatch = (combine > 0).astype(x.dtype)
    combine = combine.astype(x.dtype)

    # Dispatch -> expert compute -> combine.  Expert weights go through the
    # compressed-aware dispatch (dense leaves: the same einsum as always).
    from repro.models.layers import expert_einsum

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xs)
    xe = shard(xe, "act_batch", "act_exp", None, None)
    hg = expert_einsum("gecd,edf->gecf", xe, p["gate"])
    hu = expert_einsum("gecd,edf->gecf", xe, p["up"])
    hidden = jax.nn.silu(hg) * hu
    hidden = shard(hidden, "act_batch", "act_exp", None, None)
    ye = expert_einsum("gecf,efd->gecd", hidden, p["down"])
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)
    return y.reshape(b, s, d)
