"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int                        # dense MLP hidden (per-expert for MoE)
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # MoE.
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 512             # tokens per dispatch group
    norm_topk: bool = True

    # SSM (Mamba2 / SSD).
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # Hybrid (zamba2-style): shared attention block every k mamba blocks.
    hybrid_attn_every: int = 0

    # Attention flavor.
    sliding_window: int = 0          # 0 -> full causal
    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # Modality frontend (vlm/audio): training inputs are precomputed
    # embeddings from a stubbed encoder (per assignment).
    frontend: str = "none"           # none | vision | audio

    # Attention impl knobs.
    attn_chunk: int = 512            # online-softmax KV chunk

    # Numerics.
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"

    # Remat policy for the layer scan: "none" | "full" | "dots".
    remat: str = "full"

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + unembed)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        per_layer = 0
        if self.family in ("ssm", "hybrid"):
            din = self.d_inner
            conv_dim = din + 2 * self.ssm_state
            in_proj = d * (2 * din + 2 * self.ssm_state + self.ssm_heads)
            per_layer = in_proj + self.conv_kernel * conv_dim + din * d + din
        if self.family != "ssm" and self.hybrid_attn_every == 0:
            qkvo = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
            per_layer += qkvo
            if self.is_moe:
                per_layer += d * self.num_experts + self.num_experts * 3 * d * ff
            else:
                per_layer += 3 * d * ff
        total = self.num_layers * per_layer
        if self.hybrid_attn_every:
            shared = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d + 3 * d * ff
            total += shared
        total += 2 * v * d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = self.num_layers * (self.num_experts - self.top_k) * 3 * d * ff
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
