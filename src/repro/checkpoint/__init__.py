"""Fault-tolerant checkpointing."""
from repro.checkpoint.manager import CheckpointManager, ContentStore

__all__ = ["CheckpointManager", "ContentStore"]
