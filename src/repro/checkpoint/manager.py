"""Checkpoint manager: atomic commits, keep-N retention, reshard-on-load.

Layout:  <dir>/step_<N>/  with one .npy per pytree leaf (path-encoded file
names) plus  meta.json  (step, user metadata, tree manifest).  Writes go to a
temp directory and are committed with an atomic ``os.rename`` — a crash
mid-save can never corrupt the latest checkpoint, which is the invariant the
restart path relies on.

``restore(...)`` takes an optional ``sharding_tree`` (or a mesh + specs) and
``jax.device_put``s each leaf accordingly — loading a checkpoint onto a
*different* mesh shape (elastic restart after losing a slice) is therefore
just a restore with new shardings.  Saves can be asynchronous (background
thread); ``wait()`` joins before the next save or shutdown.

This container is single-process; on a real multi-host deployment each host
would write only the addressable shards of its arrays (the manifest format
already records per-leaf shapes/dtypes so per-shard files slot in).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class ContentStore:
    """Content-addressed array store with the same atomic-commit discipline
    as :class:`CheckpointManager`.

    Entries are immutable ``<key>.npz`` bundles (key = caller-supplied content
    hash), written to a temp file and committed with ``os.replace`` so a crash
    mid-write never leaves a readable-but-corrupt entry.  Used by
    ``repro.service`` to persist solved masks / pruned tensors across runs:
    because keys are content hashes, restarts and re-runs dedupe for free.
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def path(self, key: str) -> str:
        return os.path.join(self.dir, key + ".npz")

    def has(self, key: str) -> bool:
        return os.path.exists(self.path(key))

    def put(self, key: str, **arrays: np.ndarray) -> None:
        if self.has(key):  # immutable: same key == same content
            return
        tmp = self.path(key) + f".tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            np.savez(f, **{k: np.asarray(v) for k, v in arrays.items()})
        os.replace(tmp, self.path(key))  # atomic commit

    def get(self, key: str) -> dict[str, np.ndarray]:
        with np.load(self.path(key)) as z:
            return {k: z[k] for k in z.files}

    def keys(self) -> list[str]:
        return sorted(
            name[:-4] for name in os.listdir(self.dir) if name.endswith(".npz")
        )


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write path ---------------------------------------------------------

    def save(self, step: int, state: Any, metadata: Optional[dict] = None):
        """Snapshot state (host copy happens synchronously; IO may be async)."""
        arrays = _flatten(state)
        meta = {"step": int(step), "user": metadata or {},
                "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in arrays.items()}}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, arrays, meta)

    def _write(self, step: int, arrays: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for k, v in arrays.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # -- read path ----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:010d}", "meta.json")) as f:
            return json.load(f)

    def restore(self, step: int, template: Any, sharding_tree: Any = None) -> Any:
        """Load into the structure of ``template``; reshard if tree given.

        ``sharding_tree``: pytree of jax.sharding.Sharding (or None leaves)
        matching ``template`` — pass shardings built from a *new* mesh to
        perform an elastic reshard-on-load.
        """
        base = os.path.join(self.dir, f"step_{step:010d}")
        flat = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            jax.tree.leaves(
                sharding_tree, is_leaf=lambda x: x is None or hasattr(x, "device_set")
            )
            if sharding_tree is not None
            else [None] * len(flat[0])
        )
        leaves = []
        for (path, leaf), sh in zip(flat[0], shard_leaves):
            key = _SEP.join(_path_str(p) for p in path)
            arr = np.load(os.path.join(base, key + ".npy"))
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(flat[1], leaves)
