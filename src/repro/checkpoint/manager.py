"""Checkpoint manager: atomic commits, keep-N retention, reshard-on-load.

Layout:  <dir>/step_<N>/  with one .npy per pytree leaf (path-encoded file
names) plus  meta.json  (step, user metadata, tree manifest).  Writes go to a
temp directory and are committed with an atomic ``os.rename`` — a crash
mid-save can never corrupt the latest checkpoint, which is the invariant the
restart path relies on.

``restore(...)`` takes an optional ``sharding_tree`` (or a mesh + specs) and
``jax.device_put``s each leaf accordingly — loading a checkpoint onto a
*different* mesh shape (elastic restart after losing a slice) is therefore
just a restore with new shardings.  Saves can be asynchronous (background
thread); ``wait()`` joins before the next save or shutdown.

This container is single-process; on a real multi-host deployment each host
would write only the addressable shards of its arrays (the manifest format
already records per-leaf shapes/dtypes so per-shard files slot in).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.treepath import path_str

_SEP = "__"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        out[path_str(path, _SEP)] = np.asarray(leaf)
    return out


class ContentStore:
    """Content-addressed array store with the same atomic-commit discipline
    as :class:`CheckpointManager`.

    Entries are immutable ``<key>.npz`` bundles (key = caller-supplied content
    hash), written to a temp file and committed with ``os.replace`` so a crash
    mid-write never leaves a readable-but-corrupt entry.  Used by
    ``repro.service`` to persist solved masks / pruned tensors across runs:
    because keys are content hashes, restarts and re-runs dedupe for free.

    Retention: model-scale stores grow without bound (every distinct tensor
    content is a new immutable entry), so ``prune(max_bytes=...)`` evicts
    least-recently-*accessed* entries until the store fits.  Each ``get``/
    ``put`` bumps the entry's mtime, which is the LRU clock — cheap, crash
    safe, and survives process restarts.

    Multiple *processes* may share one directory (the mask server's shared
    cache tier; two prune jobs on one cache volume): writes are per-pid
    tmp files committed with atomic ``os.replace`` (concurrent puts of the
    same key converge — same content), and every maintenance path
    (``touch``/``size_bytes``/``prune``) tolerates entries deleted under
    it.  Readers racing an eviction use :meth:`get_or_none`, which turns
    the race into a miss instead of a ``FileNotFoundError``.
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def path(self, key: str) -> str:
        return os.path.join(self.dir, key + ".npz")

    def has(self, key: str) -> bool:
        return os.path.exists(self.path(key))

    def touch(self, key: str) -> None:
        """Bump the entry's LRU clock (mtime = last access) without IO of
        the payload — callers with their own memory front use this so their
        hits still count as recency for :meth:`prune`."""
        try:
            os.utime(self.path(key))
        except OSError:
            pass

    def put(self, key: str, **arrays: np.ndarray) -> None:
        if self.has(key):  # immutable: same key == same content
            self.touch(key)
            return
        tmp = self.path(key) + f".tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            np.savez(f, **{k: np.asarray(v) for k, v in arrays.items()})
        os.replace(tmp, self.path(key))  # atomic commit

    def get(self, key: str) -> dict[str, np.ndarray]:
        with np.load(self.path(key)) as z:
            out = {k: z[k] for k in z.files}
        self.touch(key)
        return out

    def get_or_none(self, key: str) -> Optional[dict[str, np.ndarray]]:
        """Like :meth:`get` but None for missing *or concurrently evicted*
        entries.

        This is the read contract for stores shared between processes (the
        mask server's shared cache tier, two prune jobs over one cache
        volume): another process's ``prune()`` may delete an entry at any
        moment, including between a ``has()`` and a ``get()`` — callers
        using this accessor see a plain miss instead of a
        ``FileNotFoundError`` escaping mid-read.  Entries themselves can
        never be *torn* (writes are tmp + atomic ``os.replace``), so the
        only failure mode a reader can observe is absence.
        """
        try:
            return self.get(key)
        except OSError:
            return None

    def keys(self) -> list[str]:
        return sorted(
            name[:-4] for name in os.listdir(self.dir) if name.endswith(".npz")
        )

    def size_bytes(self) -> int:
        total = 0
        for name in os.listdir(self.dir):
            if name.endswith(".npz"):
                try:
                    total += os.path.getsize(os.path.join(self.dir, name))
                except OSError:
                    pass  # concurrently evicted
        return total

    def prune(self, max_bytes: int, tmp_max_age: float = 3600.0) -> list[str]:
        """Evict least-recently-accessed entries until the store holds at
        most ``max_bytes``; returns the evicted keys (oldest first).

        Also garbage-collects ``*.tmp.<pid>`` orphans older than
        ``tmp_max_age`` seconds — writers killed mid-``put`` leave them
        behind, invisible to the ``.npz`` accounting but still on disk.
        """
        cutoff = time.time() - tmp_max_age
        for name in os.listdir(self.dir):
            if ".tmp." in name:
                path = os.path.join(self.dir, name)
                try:
                    if os.path.getmtime(path) < cutoff:
                        os.remove(path)
                except OSError:
                    pass
        entries = []
        for key in self.keys():
            try:
                st = os.stat(self.path(key))
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, key))
        total = sum(size for _, size, _ in entries)
        evicted = []
        for _mtime, size, key in sorted(entries):
            if total <= max_bytes:
                break
            try:
                os.remove(self.path(key))
            except OSError:
                continue
            total -= size
            evicted.append(key)
        return evicted


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write path ---------------------------------------------------------

    def save(self, step: int, state: Any, metadata: Optional[dict] = None):
        """Snapshot state (host copy happens synchronously; IO may be async)."""
        arrays = _flatten(state)
        meta = {"step": int(step), "user": metadata or {},
                "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in arrays.items()}}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, arrays, meta)

    def _write(self, step: int, arrays: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for k, v in arrays.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # -- read path ----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:010d}", "meta.json")) as f:
            return json.load(f)

    def user_metadata(self, step: int) -> dict:
        """The caller-supplied metadata dict passed to :meth:`save`.

        This is where host-side training state that is not an array pytree
        rides along — e.g. the DST refresh-controller ``state_dict()``
        (schedule spec, in-flight refresh, per-refresh telemetry) that
        ``TrainLoop`` stores so a dynamic-sparse run resumes mid-schedule.
        """
        return self.metadata(step).get("user", {})

    def restore(self, step: int, template: Any, sharding_tree: Any = None) -> Any:
        """Load into the structure of ``template``; reshard if tree given.

        ``sharding_tree``: pytree of jax.sharding.Sharding (or None leaves)
        matching ``template`` — pass shardings built from a *new* mesh to
        perform an elastic reshard-on-load.

        Leaf *shapes* come from the checkpoint, not the template: only the
        tree structure (and per-leaf dtype) must match.  That is what lets
        a dynamic-sparse-training run resume from a mid-schedule
        checkpoint whose ``NMCompressed`` buffers have a decayed N — the
        template built from the fresh (stage-0) state has different shapes
        but the identical tree.  A template whose *structure* diverges
        from the manifest fails fast with the differing paths.
        """
        base = os.path.join(self.dir, f"step_{step:010d}")
        flat = jax.tree_util.tree_flatten_with_path(template)
        manifest = set(self.metadata(step).get("leaves", {}))
        want = {path_str(p, _SEP) for p, _ in flat[0]}
        if manifest and manifest != want:
            missing = sorted(want - manifest)[:5]
            extra = sorted(manifest - want)[:5]
            raise ValueError(
                f"checkpoint step {step} tree structure does not match the "
                f"restore template (template-only: {missing}; "
                f"checkpoint-only: {extra}). A support swap may change "
                "compressed leaf shapes but never the tree itself."
            )
        shard_leaves = (
            jax.tree.leaves(
                sharding_tree, is_leaf=lambda x: x is None or hasattr(x, "device_set")
            )
            if sharding_tree is not None
            else [None] * len(flat[0])
        )
        leaves = []
        for (path, leaf), sh in zip(flat[0], shard_leaves):
            key = path_str(path, _SEP)
            arr = np.load(os.path.join(base, key + ".npy"))
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(flat[1], leaves)
