"""Host-process environment workarounds, importable before (and without) jax.

This module must stay dependency-free: ``tests/conftest.py`` and the tools
(``tools/check_docs.py``, CI helpers) call it *before* the first jax import,
because XLA reads these environment variables exactly once at client
creation.
"""
from __future__ import annotations

import os

__all__ = ["single_core_xla_workaround"]


def single_core_xla_workaround(environ=None) -> bool:
    """Force a second XLA host device on single-core machines.

    On a single-core host the XLA CPU client has one execution thread, so
    the ``io_callback`` escape hatch (``solve_via="callback"``) deadlocks:
    the outer jitted computation holds the only thread while the callback
    waits on a nested dispatch.  A second host device gives that dispatch
    somewhere to run.

    Returns True when the flag was applied (single-core host, no existing
    ``XLA_FLAGS``).  Must run before jax is imported.
    """
    env = os.environ if environ is None else environ
    if (os.cpu_count() or 2) != 1:
        return False
    before = env.get("XLA_FLAGS")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")
    return before is None
