"""pallas-fused single-pass solve: bit-identity, early exit, bit-packing,
VMEM plans and the VMEM-aware bucket ladder (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import PatternSpec, SolverConfig, get_backend, is_transposable_nm
from repro.core.dykstra import dykstra_log
from repro.core.solver import nm_mask, objective, solve_mask
from repro.kernels.fused_solve.kernel import fused_block_b, fused_solve_pallas
from repro.kernels.fused_solve.ref import fused_solve_ref
from repro.kernels.rounding.kernel import default_rounding_block_b
from repro.kernels.vmem import VPU_ALIGN, vmem_plan
from repro.service.scheduler import BucketPolicy, StreamStats
from repro.sparsity import bitpack

RNG = np.random.default_rng(7)

PATTERNS = [
    ("t1:4", 5), ("t2:4", 9), ("t4:8", 6), ("t16:32", 3),
]


def _blocks(b, m, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return np.abs(rng.normal(size=(b, m, m))).astype(np.float32)


# ---------------------------------------------------------------------------
# Mask identity: pallas-fused == dense-jit at tol=0.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern,b", PATTERNS)
def test_fused_backend_mask_identical_to_dense_jit(pattern, b):
    spec = PatternSpec.parse(pattern)
    config = SolverConfig(iters=80, backend="pallas-fused")
    blocks = jnp.asarray(_blocks(b, spec.m))
    got = np.array(get_backend("pallas-fused").solve(blocks, spec, config))
    want = np.array(get_backend("dense-jit").solve(blocks, spec, config))
    assert (got == want).all()


@pytest.mark.parametrize("seed", range(5))
def test_fused_kernel_identical_to_ref_random_shapes(seed):
    """Property sweep: random (B, M, N) vs the XLA reference, incl. tile
    padding (B not a multiple of block_b) and duplicate magnitudes."""
    rng = np.random.default_rng(100 + seed)
    m = int(rng.choice([2, 4, 6, 8, 16, 32]))
    n = int(rng.integers(1, m + 1))
    b = int(rng.integers(1, 20))
    w = np.abs(rng.normal(size=(b, m, m))).astype(np.float32)
    if seed % 2:  # force ties: quantize magnitudes
        w = np.round(w, 1)
    words, _ = fused_solve_pallas(jnp.asarray(w), n, iters=60, block_b=8)
    ref = fused_solve_ref(jnp.asarray(w), n, iters=60)
    assert (np.array(words) == np.array(ref)).all(), (m, n, b)


@pytest.mark.parametrize("m,n", [(3, 1), (6, 3), (12, 5)])
def test_fused_non_power_of_two_m_identical(m, n):
    """Odd/non-power-of-two block sides go through the sentinel-padded
    bitonic sort and must still match dense-jit exactly."""
    w = jnp.asarray(_blocks(7, m, seed=21))
    words, _ = fused_solve_pallas(w, n, iters=60, block_b=8)
    assert (np.array(words) == np.array(fused_solve_ref(w, n, iters=60))).all()


def test_fused_solve_mask_end_to_end():
    """Whole-matrix solve through solve_mask with pad/crop geometry."""
    w = RNG.normal(size=(20, 12)).astype(np.float32)
    spec = PatternSpec(2, 4)
    got = np.array(solve_mask(jnp.asarray(w), spec,
                              SolverConfig(iters=60, backend="pallas-fused")))
    want = np.array(solve_mask(jnp.asarray(w), spec, SolverConfig(iters=60)))
    assert (got == want).all()


# ---------------------------------------------------------------------------
# Early exit (tol > 0): feasible masks, objective within 0.1% of full-T.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern", ["t2:4", "t4:8", "t16:32"])
def test_fused_early_exit_feasible_and_near_optimal(pattern):
    spec = PatternSpec.parse(pattern)
    w = _blocks(24, spec.m, seed=13)
    full = SolverConfig(iters=300, backend="pallas-fused")
    early = SolverConfig(iters=300, backend="pallas-fused", tol=1e-4)
    backend = get_backend("pallas-fused")
    mask_full = np.array(backend.solve(jnp.asarray(w), spec, full))
    mask_early = np.array(backend.solve(jnp.asarray(w), spec, early))
    for blk in mask_early:
        assert is_transposable_nm(blk, spec.n, spec.m)
    obj_full = sum(float(objective(mask_full[i], w[i])) for i in range(len(w)))
    obj_early = sum(float(objective(mask_early[i], w[i])) for i in range(len(w)))
    assert obj_early >= 0.999 * obj_full


def test_dense_jit_while_loop_early_exit_matches_semantics():
    """The dense path's tol mirrors the kernel: bounded iterations, reported
    count, and tol=0 bit-identical to the historical fori_loop."""
    w = jnp.asarray(_blocks(8, 8, seed=14))
    s_fixed = np.array(dykstra_log(w, 4, iters=60))
    s_tol0 = np.array(dykstra_log(w, 4, iters=60, tol=0.0))
    assert (s_fixed == s_tol0).all()
    _, it = dykstra_log(w, 4, iters=300, tol=0.3, return_iters=True)
    assert 0 < int(it) <= 300
    _, it_full = dykstra_log(w, 4, iters=300, return_iters=True)
    assert int(it_full) == 300
    # A loose tolerance must actually exit early on this batch.
    assert int(it) < 300


def test_fused_tile_iters_reported():
    w = jnp.asarray(_blocks(20, 8, seed=15))
    _, tile_iters = fused_solve_pallas(w, 4, iters=300, tol=5e-2, block_b=8)
    assert tile_iters.shape == (3,)  # ceil(20/8) tiles
    assert (np.array(tile_iters) <= 300).all() and (np.array(tile_iters) > 0).all()


@pytest.mark.parametrize("iters", [1, 2, 4, 5, 9])
def test_fused_adaptive_mode_honors_small_iteration_caps(iters):
    """The chunked convergence loop must land exactly on a cap smaller than
    (or not divisible by) its check stride, not skip the loop entirely."""
    w = jnp.asarray(_blocks(8, 8, seed=17))
    _, tile_iters = fused_solve_pallas(w, 4, iters=iters, tol=1e-9, block_b=8)
    assert int(np.array(tile_iters)[0]) == iters


# ---------------------------------------------------------------------------
# Bit-packed output.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 4, 8, 31, 32, 40, 64, 70])
def test_bitpack_roundtrip_exact(m):
    mask = RNG.random((3, m, m)) > 0.4
    words = bitpack.pack_rows_np(mask)
    assert words.dtype == np.uint32
    assert (bitpack.unpack_rows_np(words, m) == mask).all()
    words_j = np.array(bitpack.pack_rows(jnp.asarray(mask)))
    assert (words_j == words).all()
    assert (np.array(bitpack.unpack_rows(jnp.asarray(words), m)) == mask).all()


def test_fused_packed_output_unpacks_to_solve_mask():
    spec = PatternSpec(4, 8)
    config = SolverConfig(iters=60, backend="pallas-fused")
    blocks = jnp.asarray(_blocks(10, 8, seed=16))
    backend = get_backend("pallas-fused")
    words = np.array(backend.solve_packed(blocks, spec, config))
    assert words.shape == (10, 8) and words.dtype == np.uint32
    mask = np.array(backend.solve(blocks, spec, config))
    assert (bitpack.unpack_rows_np(words, 8) == mask).all()


def test_fused_backend_rejects_wide_blocks():
    spec = PatternSpec(2, 64)
    config = SolverConfig(iters=10, backend="pallas-fused")
    with pytest.raises(ValueError, match="M <= 32"):
        get_backend("pallas-fused").solve(
            jnp.asarray(_blocks(2, 64)), spec, config
        )


# ---------------------------------------------------------------------------
# nm_mask non-multiple rows (satellite regression).
# ---------------------------------------------------------------------------


def test_nm_mask_pads_non_multiple_rows():
    w = RNG.normal(size=(10, 6)).astype(np.float32)  # 10 % 4 != 0
    mask = np.array(nm_mask(jnp.asarray(w), 2, 4, axis=0))
    assert mask.shape == (10, 6)
    # Full groups keep exactly N; the partial 2-row group keeps min(n, size).
    assert (mask[:8].reshape(2, 4, 6).sum(1) == 2).all()
    assert (mask[8:].sum(0) == 2).all()
    # Real entries must win over the zero padding: padded result == computing
    # on the explicitly padded matrix then cropping.
    wp = np.concatenate([w, np.zeros((2, 6), np.float32)])
    want = np.array(nm_mask(jnp.asarray(wp), 2, 4, axis=0))[:10]
    assert (mask == want).all()
    # axis=1 goes through the same path via transpose
    mask1 = np.array(nm_mask(jnp.asarray(w.T), 2, 4, axis=1))
    assert (mask1 == mask.T).all()


def test_solve_mask_standard_pattern_non_multiple():
    w = RNG.normal(size=(13, 8)).astype(np.float32)
    mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(2, 4, False)))
    assert mask.shape == (13, 8)


# ---------------------------------------------------------------------------
# VMEM plan + VMEM-aware bucket ladder.
# ---------------------------------------------------------------------------


def test_vmem_plan_budget_and_alignment():
    for m in (4, 8, 16, 32):
        for live in (3, 4, 6):
            plan = vmem_plan(m, live_buffers=live)
            assert plan.block_b % VPU_ALIGN == 0
            assert plan.block_b & (plan.block_b - 1) == 0  # power of two
            assert plan.tile_bytes() <= plan.budget_bytes or \
                plan.block_b == VPU_ALIGN
    # More live buffers can never mean a bigger tile.
    assert vmem_plan(32, live_buffers=6).block_b <= \
        vmem_plan(32, live_buffers=4).block_b


def test_kernel_tiles_derive_from_vmem_plan():
    assert fused_block_b(32) == vmem_plan(32, live_buffers=6).block_b
    assert default_rounding_block_b(16) == vmem_plan(16, live_buffers=3).block_b


def test_bucket_policy_for_device_tile_aligned():
    policy = BucketPolicy.for_device(32)
    tile = fused_block_b(32)
    assert policy.base == tile
    for rung in policy.ladder():
        assert rung % tile == 0
    # |W| bytes per dispatch stay under the cap.
    assert policy.max_bucket * 32 * 32 * 4 <= 256 * 1024 * 1024


def test_bucket_policy_tail_decompose_bounds_padding():
    policy = BucketPolicy(base=8, growth=4, max_bucket=128, tail_decompose=True)
    plan = policy.plan(128 * 3 + 41)  # tail 41 -> 32 + 8 + 8 (padding 7 < 8)
    assert plan == [128, 128, 128, 32, 8, 8]
    assert sum(plan) - (128 * 3 + 41) < policy.base
    # Default (covering) behavior unchanged.
    assert BucketPolicy(base=8, growth=4, max_bucket=128).plan(9) == [32]


def test_bucket_policy_growth_adapts_to_observed_waste():
    wasteful = StreamStats()
    wasteful.note_batch(512, real=100, padded=412)  # 80% waste
    lean = StreamStats()
    lean.note_batch(512, real=512, padded=0)
    assert BucketPolicy.for_device(8, stats=wasteful).growth == 2
    assert BucketPolicy.for_device(8, stats=lean).growth == 4
    assert BucketPolicy.for_device(8, stats=None).growth == 4


# ---------------------------------------------------------------------------
# Packed service path (cache + scheduler round-trip).
# ---------------------------------------------------------------------------


def test_service_packed_path_bit_exact_with_fused_backend(tmp_path):
    from repro.service import MaskService

    w = RNG.normal(size=(40, 24)).astype(np.float32)
    spec = PatternSpec(4, 8)
    config = SolverConfig(iters=60, backend="pallas-fused")
    svc = MaskService(config, directory=str(tmp_path))
    got = np.array(svc.solve(w, spec, name="w"))
    want = np.array(solve_mask(jnp.asarray(w), spec, SolverConfig(iters=60)))
    assert (got == want).all()
    # The store payload is the packed-words v3 format, served back verbatim.
    svc2 = MaskService(config, directory=str(tmp_path))
    got2 = np.array(svc2.solve(w, spec, name="w"))
    assert (got2 == want).all()
    assert svc2.stats.blocks_solved == 0 and svc2.cache.disk_hits == 1
