"""Dynamic sparse training: schedules, support swaps, async refresh.

The load-bearing contracts:

  * ``recompress(sp, masks, pat)`` is *bit-identical* to compressing the
    decompressed tree from scratch — surviving slots carry their trained
    values, new slots start at zero;
  * ``remap_moments`` relays AdamW mu/nu across a support swap with the
    same surviving/zeroed semantics;
  * a ``mode="sync"`` :class:`MaskRefreshController` produces, at tol=0,
    exactly the TrainState you get from the manual
    ``sparsify_pytree`` + ``recompress`` + ``remap_moments`` path;
  * ``MaskService`` dedupes identical in-flight submissions and its
    ``flush_async`` resolves the same handles a blocking flush would;
  * a killed DST run resumes mid-schedule from checkpoint metadata,
    re-arming an in-flight refresh.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import PatternSpec, SolverConfig
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.dst import (
    MaskRefreshController,
    RefreshEvent,
    StaticSchedule,
    StepwiseSchedule,
    aggregate_flips,
    decaying_nm,
    mask_flip_stats,
    schedule_from_spec,
)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, remap_moments
from repro.service import MaskService
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.sparsity.params import (
    NMCompressed,
    compress_params,
    decompress_params,
    projection_prunable,
    recompress,
    remap_slots,
    remap_tree,
)
from repro.train import build_train_step, make_train_state
from repro.train.step import StepConfig

CFG = ModelConfig("dst", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, remat="none",
                  dtype="float32")
SOLVER = SolverConfig(iters=30)


def tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def small_sparse_model(seed=0, pattern=PatternSpec(24, 32)):
    params = lm.init_params(CFG, jax.random.PRNGKey(seed))
    masks = sparsify_pytree(params, pattern, config=SOLVER,
                            prunable=projection_prunable)
    pruned = apply_mask(params, masks)
    return pruned, masks, compress_params(pruned, masks, pattern)


def solve_tighter(sp, pattern):
    """Masks for ``pattern`` solved from the decompressed weights — the
    same scores a refresh uses."""
    return sparsify_pytree(decompress_params(sp), pattern, config=SOLVER,
                           prunable=projection_prunable)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def test_static_schedule_cadence():
    s = StaticSchedule("t2:4", every=50)
    assert s.initial.canonical == "t2:4"
    assert s.swap_at(0) is None and s.swap_at(49) is None
    assert s.swap_at(50).canonical == "t2:4"
    assert s.swap_at(75) is None and s.swap_at(100) is not None
    assert s.pattern_at(10_000).canonical == "t2:4"


def test_static_schedule_window():
    s = StaticSchedule("t2:4", every=10, start=30, stop=60)
    swaps = [t for t in range(100) if s.swap_at(t) is not None]
    assert swaps == [30, 40, 50, 60]


def test_stepwise_schedule():
    s = StepwiseSchedule(((0, "t24:32"), (100, "t20:32"), (200, "t16:32")))
    assert s.initial.canonical == "t24:32"
    assert s.final.canonical == "t16:32"
    assert s.pattern_at(99).canonical == "t24:32"
    assert s.pattern_at(100).canonical == "t20:32"
    assert s.pattern_at(10_000).canonical == "t16:32"
    assert s.swap_at(0) is None            # stage 0 is the initial prune
    assert s.swap_at(100).canonical == "t20:32"
    assert s.swap_at(150) is None
    assert s.swap_at(200).canonical == "t16:32"


def test_stepwise_schedule_validation():
    with pytest.raises(ValueError, match="start at step 0"):
        StepwiseSchedule(((10, "t2:4"),))
    with pytest.raises(ValueError, match="increase"):
        StepwiseSchedule(((0, "t24:32"), (100, "t20:32"), (100, "t16:32")))
    with pytest.raises(ValueError, match="share one M"):
        StepwiseSchedule(((0, "t24:32"), (100, "t8:16")))
    with pytest.raises(ValueError, match="transposable"):
        StepwiseSchedule(((0, "2:4"),))


def test_decaying_nm():
    s = decaying_nm(32, 24, 16, total_steps=300, stages=3)
    starts = [st for st, _ in s.stages]
    pats = [p.canonical for _, p in s.stages]
    assert starts == [0, 100, 200]
    assert pats == ["t24:32", "t20:32", "t16:32"]
    # Degenerate decay: constant N collapses to a single stage.
    flat = decaying_nm(4, 2, 2, total_steps=100)
    assert len(flat.stages) == 1


def test_schedule_spec_round_trip():
    for s in (StaticSchedule("t2:4", every=7, start=14, stop=70),
              decaying_nm(32, 24, 16, total_steps=120, stages=4)):
        back = schedule_from_spec(s.spec())
        assert back.spec() == s.spec()


# ---------------------------------------------------------------------------
# Telemetry primitives
# ---------------------------------------------------------------------------


def test_mask_flip_stats():
    old = np.zeros((4, 4), bool)
    old[:2] = True
    new = np.zeros((4, 4), bool)
    new[1:3] = True
    st = mask_flip_stats(old, new)
    assert st["kept"] == 4 and st["added"] == 4 and st["dropped"] == 4
    assert st["nnz_old"] == 8 and st["nnz_new"] == 8
    assert st["flip_rate"] == pytest.approx(0.5)
    agg = aggregate_flips({"a": st, "b": st})
    assert agg["flip_rate"] == pytest.approx(0.5)
    assert agg["size"] == 32


def test_refresh_event_json_round_trip():
    e = RefreshEvent(submit_step=5, swap_step=15, pattern="t16:32",
                     wait_seconds=0.01, solve_seconds=0.5, synchronous=False,
                     flips={"w": mask_flip_stats(np.ones((2, 2), bool),
                                                 np.ones((2, 2), bool))})
    e = e.finalize()
    back = RefreshEvent.from_json(e.to_json())
    assert back.to_json() == e.to_json()
    assert "t16:32" in e.summary()


# ---------------------------------------------------------------------------
# recompress / remap: the support-swap primitives
# ---------------------------------------------------------------------------


def test_recompress_bit_identical_to_fresh_compress():
    _, _, sp = small_sparse_model()
    pat = PatternSpec(16, 32)
    masks = solve_tighter(sp, pat)
    out, stats = recompress(sp, masks, pat)
    dense = decompress_params(sp)
    ref = compress_params(apply_mask(dense, masks), masks, pat, strict=False)
    assert tree_equal(out, ref)
    assert all(s["added"] >= 0 for s in stats.values())


def test_recompress_surviving_slots_keep_values():
    _, _, sp = small_sparse_model()
    pat = PatternSpec(16, 32)
    masks = solve_tighter(sp, pat)
    out, _ = recompress(sp, masks, pat)
    for name in ("wq", "wo"):
        old = sp["blocks"]["attn"][name]
        new = out["blocks"]["attn"][name]
        od, nd = np.asarray(old.decompress()), np.asarray(new.decompress())
        mk = np.asarray(masks["blocks"]["attn"][name])
        # On the new support, values are exactly the trained ones.
        np.testing.assert_array_equal(nd[mk], od[mk])
        np.testing.assert_array_equal(nd[~mk], 0)


def test_recompress_dense_ref_fills_new_slots():
    """With ``dense_ref``, slots *outside* the old support come back from
    the reference tree instead of zero (regrowth from a dense shadow)."""
    _, _, sp = small_sparse_model()
    dense_ref = jax.tree.map(
        lambda l: jnp.full(l.dense_shape, 7.0, l.values.dtype)
        if isinstance(l, NMCompressed) else l,
        sp, is_leaf=lambda x: isinstance(x, NMCompressed))
    # A shifted support: drop to 16:32 so some slots are new vs old.
    pat = PatternSpec(16, 32)
    masks = solve_tighter(sp, pat)
    out, _ = recompress(sp, masks, pat, dense_ref=dense_ref)
    old = sp["blocks"]["attn"]["wq"]
    new = out["blocks"]["attn"]["wq"]
    old_mask = np.asarray(old.decompress()) != 0
    nd = np.asarray(new.decompress())
    mk = np.asarray(masks["blocks"]["attn"]["wq"])
    fresh = mk & ~old_mask
    if fresh.any():
        np.testing.assert_array_equal(nd[fresh], 7.0)
    np.testing.assert_array_equal(
        nd[mk & old_mask], np.asarray(old.decompress())[mk & old_mask])


def test_recompress_strict_guards():
    _, _, sp = small_sparse_model()
    masks = solve_tighter(sp, PatternSpec(16, 32))
    with pytest.raises(ValueError, match="transposable"):
        recompress(sp, masks, PatternSpec(16, 32, transposable=False))
    # A mask over a leaf that is not compressed: strict raises.
    bad = jax.tree.map(lambda x: x, masks, is_leaf=lambda x: x is None)
    bad["embed"] = np.ones(np.asarray(sp["embed"]).shape, bool)
    with pytest.raises(ValueError, match="non-compressed"):
        recompress(sp, bad, PatternSpec(16, 32))
    out, _ = recompress(sp, bad, PatternSpec(16, 32), strict=False)
    assert isinstance(out["blocks"]["attn"]["wq"], NMCompressed)


def test_remap_slots_2d_and_stacked():
    rng = np.random.default_rng(3)
    m, g, f = 8, 4, 16
    w = rng.normal(size=(g * m, f)).astype(np.float32)
    masks = []
    for _ in range(2):
        mk = np.zeros((g * m, f), bool)
        for gi in range(g):
            for fi in range(f):
                rows = rng.choice(m, size=4, replace=False)
                mk[gi * m + rows, fi] = True
        masks.append(mk)
    from repro.sparsity.compressed import compress_nm

    v0, i0 = compress_nm(jnp.asarray(w), jnp.asarray(masks[0]), 4, m)
    _, i1 = compress_nm(jnp.asarray(w), jnp.asarray(masks[1]), 4, m)
    out = remap_slots(v0, i0, i1, m)
    from repro.sparsity.compressed import decompress_nm

    expect = np.asarray(decompress_nm(v0, i0, m)) * masks[1]
    np.testing.assert_array_equal(
        np.asarray(decompress_nm(out, i1, m)), expect)
    # Scan-stacked (L, G, N, F) leaves take the vmapped path.
    vs = jnp.stack([v0, v0])
    out2 = remap_slots(vs, jnp.stack([i0, i0]), jnp.stack([i1, i1]), m)
    np.testing.assert_array_equal(np.asarray(out2[0]), np.asarray(out))


def test_remap_tree_guards():
    _, _, sp = small_sparse_model()
    pat = PatternSpec(16, 32)
    new_sp, _ = recompress(sp, solve_tighter(sp, pat), pat)
    aux = jax.tree.map(lambda x: x, sp, is_leaf=lambda x: x is None)
    moved = remap_tree(aux, sp, new_sp)
    assert moved["blocks"]["attn"]["wq"].n == 16
    # Old compressed leaf paired with a dense new leaf: structural error.
    dense_new = decompress_params(new_sp)
    with pytest.raises(ValueError, match="compressed"):
        remap_tree(aux, sp, dense_new)


def test_remap_moments_preserves_surviving_and_zeroes_new():
    _, _, sp = small_sparse_model()
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
    st = opt.init(sp)
    # Give the moments recognizable values.
    st = st._replace(
        mu=jax.tree.map(lambda x: jnp.full_like(x, 3.0) if x.size else x,
                        st.mu),
        nu=jax.tree.map(lambda x: jnp.full_like(x, 5.0) if x.size else x,
                        st.nu))
    pat = PatternSpec(16, 32)
    masks = solve_tighter(sp, pat)
    new_sp, _ = recompress(sp, masks, pat)
    new_st = remap_moments(st, sp, new_sp)
    mu = new_st.mu["blocks"]["attn"]["wq"]
    assert isinstance(mu, NMCompressed) and mu.n == 16
    # Moment wrappers carry a placeholder indices child; their slots are
    # aligned with the *params'* indices, so decompress through those.
    idx = new_sp["blocks"]["attn"]["wq"].indices
    old_mask = np.asarray(sp["blocks"]["attn"]["wq"].decompress()) != 0
    mk = np.asarray(masks["blocks"]["attn"]["wq"])
    md = np.asarray(NMCompressed(mu.values, idx, mu.m).decompress())
    np.testing.assert_array_equal(md[mk & old_mask], 3.0)
    np.testing.assert_array_equal(md[mk & ~old_mask], 0.0)
    nu = new_st.nu["blocks"]["attn"]["wq"]
    nd = np.asarray(NMCompressed(nu.values, idx, nu.m).decompress())
    np.testing.assert_array_equal(nd[mk & old_mask], 5.0)
    # Dense leaves (embeddings, norms) pass through untouched.
    np.testing.assert_array_equal(np.asarray(new_st.mu["embed"]), 3.0)


# ---------------------------------------------------------------------------
# MaskService: in-flight dedupe + async flush
# ---------------------------------------------------------------------------


def test_service_dedupes_identical_inflight_submissions():
    svc = MaskService(SOLVER)
    w = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
    h1 = svc.submit("a", w, PatternSpec(2, 4))
    h2 = svc.submit("b", w, PatternSpec(2, 4))       # same content: dedup
    h3 = svc.submit("c", w, PatternSpec(1, 4))       # different pattern
    assert svc.stats.dedup_hits == 1
    svc.flush()
    np.testing.assert_array_equal(h1.result(), h2.result())
    assert h3.result().sum() < h1.result().sum()
    assert "dedup_hits=1" in svc.stats.summary()
    # Post-flush resubmit is a cache hit, not a dedup hit.
    h4 = svc.submit("d", w, PatternSpec(2, 4))
    assert h4.done and svc.stats.dedup_hits == 1


def test_service_flush_async_resolves_handles():
    svc = MaskService(SOLVER)
    rng = np.random.default_rng(1)
    hs = [svc.submit(f"w{i}", rng.normal(size=(64, 64)).astype(np.float32),
                     PatternSpec(2, 4)) for i in range(3)]
    ticket = svc.flush_async()
    ticket.wait(timeout=120.0)
    assert ticket.done and ticket.seconds >= 0.0
    for h in hs:
        assert h.done
        assert h.result().shape == (64, 64)
    # A second async flush with an empty queue is a no-op that still lands.
    svc.flush_async().wait(timeout=10.0)


def test_service_sync_flush_joins_background_drain():
    svc = MaskService(SOLVER)
    w = np.random.default_rng(2).normal(size=(64, 64)).astype(np.float32)
    h = svc.submit("x", w, PatternSpec(2, 4))
    svc.flush_async()
    svc.flush()   # must join the background drain, not race it
    assert h.done


# ---------------------------------------------------------------------------
# Controller end-to-end
# ---------------------------------------------------------------------------


def _train_state(sp, compression=False):
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
    return opt, make_train_state(CFG, opt, jax.random.PRNGKey(1), params=sp,
                                 compression=compression)


def _batches(n, batch=4, seq=16):
    data = SyntheticLM(vocab_size=CFG.vocab_size, seq_len=seq, global_batch=batch)
    return [{k: jnp.asarray(v) for k, v in data.batch(t).items()}
            for t in range(n)]


def test_sync_refresh_bit_identical_to_manual_path():
    """The acceptance oracle: mode="sync" == hand-rolled
    sparsify_pytree + recompress + remap_moments at the swap step, tol=0."""
    _, _, sp = small_sparse_model()
    sched = StepwiseSchedule(((0, "t24:32"), (3, "t16:32")))
    batches = _batches(6)

    # Controller-driven run.
    ctrl = MaskRefreshController(sched, solver=SOLVER, mode="sync")
    opt, state_a = _train_state(sp)
    step_a = build_train_step(
        CFG, opt, step_cfg=StepConfig(mask_mode="compressed", refresh=ctrl),
        donate=False)
    for b in batches:
        state_a, _ = step_a(state_a, b)

    # Manual run: identical steps, swap performed by hand before step 3.
    opt, state_b = _train_state(sp)
    step_b = build_train_step(
        CFG, opt, step_cfg=StepConfig(mask_mode="compressed"), donate=False)
    for t, b in enumerate(batches):
        if t == 3:
            pat = PatternSpec(16, 32)
            masks = solve_tighter(state_b.params, pat)
            new_params, _ = recompress(state_b.params, masks, pat)
            new_opt = remap_moments(state_b.opt_state, state_b.params,
                                    new_params)
            state_b = state_b._replace(params=new_params, opt_state=new_opt)
        state_b, _ = step_b(state_b, b)

    assert tree_equal(state_a.params, state_b.params)
    assert tree_equal(state_a.opt_state.mu, state_b.opt_state.mu)
    assert tree_equal(state_a.opt_state.nu, state_b.opt_state.nu)
    assert len(ctrl.events) == 1 and ctrl.events[0].synchronous
    assert ctrl.events[0].pattern == "t16:32"


def test_async_refresh_swaps_on_schedule():
    _, _, sp = small_sparse_model()
    sched = decaying_nm(32, 24, 16, total_steps=8, stages=3)
    ctrl = MaskRefreshController(sched, solver=SOLVER, mode="async",
                                 lookahead=2)
    opt, state = _train_state(sp)
    step = build_train_step(
        CFG, opt, step_cfg=StepConfig(mask_mode="compressed", refresh=ctrl),
        donate=False)
    losses = []
    for b in _batches(10):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert len(ctrl.events) == 2
    assert [e.pattern for e in ctrl.events] == ["t20:32", "t16:32"]
    assert all(not e.synchronous for e in ctrl.events)
    # Async refreshes snapshot *before* the swap step (lookahead staleness).
    for e in ctrl.events:
        assert e.submit_step < e.swap_step
    assert state.params["blocks"]["attn"]["wq"].n == 16
    assert np.isfinite(losses).all()
    tel = ctrl.telemetry()
    assert tel["refreshes"] == 2 and tel["stall_seconds"] >= 0.0


def test_refresh_requires_compressed_mode():
    ctrl = MaskRefreshController(StaticSchedule("t2:4", every=5), solver=SOLVER)
    opt = AdamW(learning_rate=1e-3)
    with pytest.raises(ValueError, match="compressed"):
        build_train_step(CFG, opt,
                         step_cfg=StepConfig(mask_mode="post", refresh=ctrl))
    with pytest.raises(ValueError, match="mode must be"):
        MaskRefreshController(StaticSchedule("t2:4", every=5), mode="later")


def test_controller_refresh_with_error_feedback_tree():
    """Compression's ef residuals ride the swap via remap_tree."""
    _, _, sp = small_sparse_model()
    sched = StepwiseSchedule(((0, "t24:32"), (2, "t16:32")))
    ctrl = MaskRefreshController(sched, solver=SOLVER, mode="sync")
    opt, state = _train_state(sp, compression=True)
    state = ctrl.on_step(2, state._replace(step=jnp.asarray(2, jnp.int32)))
    assert state.ef["blocks"]["attn"]["wq"].n == 16


def test_controller_state_dict_round_trip_and_rearm():
    _, _, sp = small_sparse_model()
    sched = decaying_nm(32, 24, 16, total_steps=8, stages=3)
    ctrl = MaskRefreshController(sched, solver=SOLVER, mode="async",
                                 lookahead=3)
    opt, state = _train_state(sp)
    # Stage boundaries land at steps 2 and 5.  Arm the step-2 refresh from
    # step 1 (within lookahead) but don't swap yet.
    ctrl._maybe_submit(1, state)
    assert ctrl._ticket is not None
    d = ctrl.state_dict()
    assert d["inflight"]["swap_step"] == 2
    assert d["inflight"]["pattern"] == "t20:32"

    # Fresh controller (post-restart) resumes and re-arms the refresh.
    svc = MaskService(SOLVER)
    ctrl2 = MaskRefreshController(sched, service=svc, mode="async",
                                  lookahead=3)
    ctrl2.load_state_dict(d)
    state2 = ctrl2.on_step(1, state._replace(step=jnp.asarray(1, jnp.int32)))
    assert ctrl2._ticket is not None and ctrl2._ticket.swap_step == 2
    assert len(ctrl2.events) == 0
    state2 = ctrl2.on_step(2, state2._replace(step=jnp.asarray(2, jnp.int32)))
    assert state2.params["blocks"]["attn"]["wq"].n == 20
    assert len(ctrl2.events) == 1

    # Schedule mismatch fails fast.
    other = MaskRefreshController(StaticSchedule("t2:4", every=5),
                                  solver=SOLVER)
    with pytest.raises(ValueError, match="different schedule"):
        other.load_state_dict(d)


def test_trainloop_checkpoints_and_resumes_dst(tmp_path):
    from repro.train.loop import TrainLoop, TrainLoopConfig

    _, _, sp = small_sparse_model()
    sched = StepwiseSchedule(((0, "t24:32"), (4, "t16:32")))
    data = SyntheticLM(vocab_size=CFG.vocab_size, seq_len=16, global_batch=4)

    def make(ctrl):
        opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
        state = make_train_state(CFG, opt, jax.random.PRNGKey(1), params=sp)
        step = build_train_step(
            CFG, opt,
            step_cfg=StepConfig(mask_mode="compressed", refresh=ctrl),
            donate=False)
        return state, step

    boom = {"armed": True}

    def injector(step):
        if step == 6 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("preempted")

    ctrl = MaskRefreshController(sched, solver=SOLVER, mode="sync")
    state, step = make(ctrl)
    ckpt = CheckpointManager(str(tmp_path), keep_n=3, async_save=False)
    loop = TrainLoop(step, data, ckpt,
                     TrainLoopConfig(total_steps=8, ckpt_every=2, log_every=100),
                     failure_injector=injector, log_fn=lambda s: None)
    with pytest.raises(RuntimeError):
        loop.run(state)
    meta = ckpt.user_metadata(ckpt.latest_step())
    assert len(meta["dst"]["events"]) == 1  # swap at 4 already happened

    # Restart: fresh controller + stage-0 template still restores the
    # decayed-N checkpoint (shapes come from the files, not the template).
    ctrl2 = MaskRefreshController(sched, solver=SOLVER, mode="sync")
    state2, step2 = make(ctrl2)
    loop2 = TrainLoop(step2, data, ckpt,
                      TrainLoopConfig(total_steps=8, ckpt_every=2,
                                      log_every=100),
                      log_fn=lambda s: None)
    final, _ = loop2.run(state2)
    assert int(np.asarray(final.step)) == 8
    assert final.params["blocks"]["attn"]["wq"].n == 16
    assert len(ctrl2.events) == 1  # restored, not re-run


def test_checkpoint_restore_rejects_mismatched_tree(tmp_path):
    _, _, sp = small_sparse_model()
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    ckpt.save(1, {"a": np.ones(3), "b": np.zeros(2)})
    with pytest.raises(ValueError, match="checkpoint-only"):
        ckpt.restore(1, {"a": np.ones(3)})
