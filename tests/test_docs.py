"""Docs stay true: link integrity + executable examples (tools/check_docs)."""
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_docs_exist():
    assert (REPO / "docs" / "architecture.md").exists()
    assert (REPO / "docs" / "solver_math.md").exists()


def test_markdown_links_resolve():
    problems = check_docs.check_links()
    assert not problems, "\n".join(problems)


def test_slugification_matches_github():
    assert check_docs.github_slug("The `tol` knob") == "the-tol-knob"
    assert (
        check_docs.github_slug("The `solve_plan` path (SparseGPT / ALPS)")
        == "the-solve_plan-path-sparsegpt--alps"
    )


def test_link_checker_catches_breakage(tmp_path):
    doc = tmp_path / "bad.md"
    doc.write_text(
        "[gone](no_such_file.md)\n"
        "[anchor](#missing-heading)\n\n# Real Heading\n"
    )
    problems = check_docs.check_links([doc])
    assert len(problems) == 2
    assert "no such file" in problems[0]
    assert "missing-heading" in problems[1]


def test_python_block_extraction(tmp_path):
    doc = tmp_path / "ex.md"
    doc.write_text(
        "intro\n```python\nx = 1\n```\n"
        "```text\nnot code\n```\n"
        "```python\nassert x == 1\n```\n"
    )
    blocks = check_docs.python_blocks(doc)
    assert [src for _, src in blocks] == ["x = 1", "assert x == 1"]
    assert check_docs.run_python_blocks(doc) == []  # shared namespace


@pytest.mark.parametrize("doc", sorted((REPO / "docs").glob("*.md")),
                         ids=lambda p: p.name)
def test_doc_examples_run(doc):
    problems = check_docs.run_python_blocks(doc)
    assert not problems, "\n".join(problems)
