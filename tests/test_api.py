"""Unified sparsity API: PatternSpec, registries, deprecation shims, mesh
dispatch (ISSUE 2 acceptance tests)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import (
    BucketPolicy,
    MaskService,
    PatternSpec,
    SolverConfig,
    available_backends,
    available_methods,
    get_backend,
    get_method,
    is_transposable_nm,
    register_backend,
    register_method,
    solve_blocks,
    solve_mask,
    sparsify_pytree,
    transposable_nm_mask,
    unregister_backend,
    unregister_method,
)

FAST = SolverConfig(iters=60)
TINY = BucketPolicy(base=8, growth=2, max_bucket=32)


# ---------------------------------------------------------------------------
# PatternSpec validation + parsing round-trip.
# ---------------------------------------------------------------------------


class TestPatternSpec:
    def test_round_trip(self):
        for spec in (PatternSpec(2, 4), PatternSpec(16, 32),
                     PatternSpec(4, 8, False), PatternSpec(1, 1)):
            assert PatternSpec.parse(str(spec)) == spec
            assert PatternSpec.parse(spec.canonical) == spec

    def test_canonical_form(self):
        assert str(PatternSpec(16, 32)) == "t16:32"
        assert str(PatternSpec(2, 4, False)) == "2:4"
        assert PatternSpec.parse("t2:4") == PatternSpec(2, 4, True)
        assert PatternSpec.parse(" 2:4 ") == PatternSpec(2, 4, False)

    def test_validation(self):
        with pytest.raises(ValueError):
            PatternSpec(5, 4)  # n > m
        with pytest.raises(ValueError):
            PatternSpec(0, 4)  # n < 1
        with pytest.raises(TypeError):
            PatternSpec(2.5, 4)  # non-integer
        with pytest.raises(TypeError):
            PatternSpec(True, 4)  # bool is not an int here
        with pytest.raises(ValueError):
            PatternSpec.parse("2-4")
        with pytest.raises(ValueError):
            PatternSpec.parse("t2:x")

    def test_coerce(self):
        spec = PatternSpec(2, 4)
        assert PatternSpec.coerce(spec) is spec
        assert PatternSpec.coerce("t2:4") == spec
        assert PatternSpec.coerce((2, 4)) == spec
        assert PatternSpec.coerce((2, 4, False)) == PatternSpec(2, 4, False)
        with pytest.raises(TypeError):
            PatternSpec.coerce(2)

    def test_helpers_and_hashability(self):
        spec = PatternSpec(2, 4)
        assert spec.density == 0.5 and spec.sparsity == 0.5
        assert spec.pad_amount(10) == 2 and spec.pad_amount(8) == 0
        assert spec.divides((8, 12)) and not spec.divides((8, 10))
        assert len({PatternSpec(2, 4), PatternSpec(2, 4), PatternSpec(4, 8)}) == 2

    def test_np_ints_accepted(self):
        spec = PatternSpec(np.int64(2), np.int32(4))
        assert spec == PatternSpec(2, 4)
        assert isinstance(spec.n, int) and isinstance(spec.m, int)


# ---------------------------------------------------------------------------
# Registry error paths.
# ---------------------------------------------------------------------------


class TestRegistries:
    def test_builtin_backends_present(self):
        assert {"dense-jit", "pallas", "exact", "greedy-baseline"} <= set(
            available_backends()
        )

    def test_unknown_backend(self):
        with pytest.raises(ValueError, match="unknown solver backend"):
            get_backend("nope")
        with pytest.raises(ValueError, match="dense-jit"):  # lists available
            get_backend("nope")

    def test_double_register_backend(self):
        class Dummy:
            name = "test-dummy-backend"
            traceable = False

            def solve(self, blocks, pattern, config):
                return np.zeros(blocks.shape, bool)

        try:
            register_backend(Dummy())
            with pytest.raises(ValueError, match="already registered"):
                register_backend(Dummy())
            register_backend(Dummy(), overwrite=True)  # explicit replace OK
        finally:
            unregister_backend("test-dummy-backend")

    def test_builtin_methods_present(self):
        assert {"magnitude", "wanda", "sparsegpt", "alps"} <= set(
            available_methods()
        )

    def test_unknown_method(self):
        with pytest.raises(ValueError, match="unknown pruning method"):
            get_method("nope")

    def test_double_register_method(self):
        def toy(w, gram, pattern, ctx):
            return w, jnp.ones_like(w, dtype=bool)

        try:
            register_method("test-toy-method")(toy)
            with pytest.raises(ValueError, match="already registered"):
                register_method("test-toy-method")(toy)
            register_method("test-toy-method", toy, overwrite=True)
        finally:
            unregister_method("test-toy-method")

    def test_custom_backend_usable_via_config(self):
        class AllTopLeft:
            """Keeps the lexicographically-first feasible support."""

            name = "test-topleft"
            traceable = False

            def solve(self, blocks, pattern, config):
                b, m, _ = blocks.shape
                base = np.zeros((m, m), bool)
                for i in range(m):
                    base[i, (np.arange(pattern.n) + i) % m] = True
                return jnp.asarray(np.broadcast_to(base, (b, m, m)))

        try:
            register_backend(AllTopLeft())
            w = np.random.default_rng(0).normal(size=(8, 8)).astype(np.float32)
            mask = np.array(
                solve_mask(w, PatternSpec(2, 4), SolverConfig(backend="test-topleft"))
            )
            assert is_transposable_nm(mask, 2, 4)
        finally:
            unregister_backend("test-topleft")


# ---------------------------------------------------------------------------
# Backend quality ordering: exact is the optimum.
# ---------------------------------------------------------------------------


def test_exact_backend_dominates():
    rng = np.random.default_rng(3)
    blocks = np.abs(rng.normal(size=(4, 8, 8))).astype(np.float32)
    masks = {
        name: np.array(solve_blocks(jnp.asarray(blocks), 4,
                                    SolverConfig(iters=80, backend=name)))
        for name in ("dense-jit", "greedy-baseline", "exact")
    }
    objs = {name: float((blocks * mk).sum()) for name, mk in masks.items()}
    for name, mk in masks.items():
        assert all(is_transposable_nm(b, 4, 8) for b in mk), name
        # the LP oracle is the optimum; every heuristic is bounded by it
        assert objs[name] <= objs["exact"] + 1e-4, objs


# ---------------------------------------------------------------------------
# Deprecation shims: warn AND stay bit-identical.
# ---------------------------------------------------------------------------


class TestShims:
    def test_transposable_nm_mask_shim(self):
        w = np.random.default_rng(1).normal(size=(24, 16)).astype(np.float32)
        want = np.array(solve_mask(jnp.asarray(w), PatternSpec(4, 8), FAST))
        with pytest.warns(DeprecationWarning, match="transposable_nm_mask"):
            got = np.array(transposable_nm_mask(jnp.asarray(w), 4, 8, FAST))
        assert (got == want).all()

    def test_use_kernel_shim(self):
        with pytest.warns(DeprecationWarning, match="use_kernel"):
            cfg = SolverConfig(iters=50, use_kernel=True)
        assert cfg.backend == "pallas"
        with pytest.warns(DeprecationWarning):
            cfg = SolverConfig(iters=50, use_kernel=False)
        assert cfg.backend == "dense-jit"
        # frozen-dataclass plumbing still works after the InitVar
        assert dataclasses.replace(cfg, iters=60).iters == 60

    def test_service_legacy_solve_and_submit(self):
        w = np.random.default_rng(2).normal(size=(16, 16)).astype(np.float32)
        svc = MaskService(FAST, policy=TINY)
        want = np.array(svc.solve(w, PatternSpec(4, 8), name="new"))
        with pytest.warns(DeprecationWarning, match="MaskService.solve"):
            got = np.array(svc.solve("legacy", w, 4, 8))
        assert (got == want).all()
        with pytest.warns(DeprecationWarning):
            h = svc.submit("legacy2", w, 4, 8)  # positional (n, m)
        assert (np.array(h.result()) == want).all()
        with pytest.warns(DeprecationWarning):
            h = svc.submit("legacy3", w, n=4, m=8)  # keyword (n, m)
        assert (np.array(h.result()) == want).all()

    def test_prune_fn_legacy_triples(self):
        from repro.pruning import magnitude_prune, wanda_prune

        rng = np.random.default_rng(4)
        w = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
        spec = PatternSpec(4, 8)

        wp_new, mk_new = magnitude_prune(w, spec, config=FAST)
        with pytest.warns(DeprecationWarning, match="magnitude_prune"):
            wp_old, mk_old = magnitude_prune(w, 4, 8, config=FAST)
        assert (np.array(mk_new) == np.array(mk_old)).all()
        np.testing.assert_array_equal(np.array(wp_new), np.array(wp_old))

        wp_new, mk_new = wanda_prune(w, x, spec, config=FAST)
        with pytest.warns(DeprecationWarning, match="wanda_prune"):
            wp_old, mk_old = wanda_prune(w, x, 4, 8, config=FAST)
        assert (np.array(mk_new) == np.array(mk_old)).all()

        # conflicting transposable= with a pattern object is an error
        with pytest.raises(ValueError, match="conflicts"):
            magnitude_prune(w, spec, transposable=False, config=FAST)

    def test_sparsify_pytree_legacy_positional(self):
        rng = np.random.default_rng(5)
        params = {"w": rng.normal(size=(16, 16)).astype(np.float32),
                  "ln": rng.normal(size=(16,)).astype(np.float32)}
        new = sparsify_pytree(params, PatternSpec(2, 4), config=FAST)
        with pytest.warns(DeprecationWarning, match="sparsify_pytree"):
            old = sparsify_pytree(params, 2, 4, FAST)
        assert old["ln"] is None
        assert (np.array(new["w"]) == np.array(old["w"])).all()

    def test_prune_transformer_legacy_kwargs(self):
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.pruning import prune_transformer

        cfg = ModelConfig("api-test", "dense", num_layers=1, d_model=16,
                          num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=32,
                          remat="none", dtype="float32")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jnp.asarray(
            np.random.default_rng(6).integers(0, 32, size=(1, 8))
        )
        solver = SolverConfig(iters=30)
        _, masks_new = prune_transformer(
            params, cfg, tokens=tokens, method="magnitude",
            pattern=PatternSpec(2, 4), solver=solver,
        )
        with pytest.warns(DeprecationWarning, match="prune_transformer"):
            _, masks_old = prune_transformer(
                params, cfg, tokens=tokens, method="magnitude", n=2, m=4,
                solver=solver,
            )
        for a, b in zip(jax.tree.leaves(masks_new), jax.tree.leaves(masks_old)):
            assert (np.array(a) == np.array(b)).all()


# ---------------------------------------------------------------------------
# Standard (non-transposable) patterns through the unified entry points.
# ---------------------------------------------------------------------------


def test_standard_pattern_paths():
    from repro.core.solver import nm_mask

    rng = np.random.default_rng(7)
    w = rng.normal(size=(16, 8)).astype(np.float32)
    spec = PatternSpec(2, 4, False)
    got = np.array(solve_mask(jnp.asarray(w), spec, FAST))
    want = np.array(nm_mask(jnp.asarray(w), 2, 4, axis=0))
    assert (got == want).all()

    params = {"w": w, "stack": rng.normal(size=(2, 8, 8)).astype(np.float32)}
    masks = sparsify_pytree(params, spec, config=FAST)
    assert (np.array(masks["w"]) == want).all()
    assert masks["stack"].shape == params["stack"].shape

    with pytest.raises(ValueError, match="transposable"):
        MaskService(FAST).submit("w", w, spec)


# ---------------------------------------------------------------------------
# Mesh-sharded dispatch: identical to single-device on 1 device.
# ---------------------------------------------------------------------------


class TestMeshDispatch:
    def test_sharded_equals_unsharded_policy(self):
        rng = np.random.default_rng(8)
        tensors = {f"t{i}": rng.normal(size=(24 + 8 * i, 16)).astype(np.float32)
                   for i in range(3)}
        spec = PatternSpec(4, 8)
        masks = {}
        for shard in (True, False):
            policy = BucketPolicy(base=8, growth=2, max_bucket=32,
                                  shard_devices=shard)
            svc = MaskService(FAST, policy=policy)
            handles = {k: svc.submit(k, v, spec) for k, v in tensors.items()}
            svc.flush()
            masks[shard] = {k: np.array(h.result()) for k, h in handles.items()}
        for k, v in tensors.items():
            ref = np.array(solve_mask(jnp.asarray(v), spec, FAST))
            assert (masks[True][k] == ref).all(), k
            assert (masks[False][k] == ref).all(), k

    def test_shard_map_wrapper_bit_identical(self):
        """Exercise the actual shard_map path on a 1-device mesh."""
        from repro.service.scheduler import _sharded_solver

        rng = np.random.default_rng(9)
        blocks = np.abs(rng.normal(size=(12, 8, 8))).astype(np.float32)
        fn = _sharded_solver(get_backend("dense-jit"), 4, 8, FAST.iters,
                             FAST.ls_steps, FAST.tau_scale, FAST.tol,
                             jax.local_device_count(), False)
        got = np.array(fn(blocks))
        want = np.array(get_backend("dense-jit").solve(
            jnp.asarray(blocks), PatternSpec(4, 8), FAST))
        assert (got == want).all()


# ---------------------------------------------------------------------------
# Scheduler satellites: ragged chunk padding + per-bucket waste stats.
# ---------------------------------------------------------------------------


def test_block_batch_ragged_chunk_padded_to_full():
    """The final ragged chunk is padded to block_batch (one compiled program)
    and the result is bit-identical."""
    shapes = []

    class Recording:
        name = "test-recording"
        traceable = False

        def solve(self, blocks, pattern, config):
            shapes.append(tuple(blocks.shape))
            return get_backend("dense-jit").solve(
                blocks, pattern, SolverConfig(iters=FAST.iters))

    rng = np.random.default_rng(10)
    blocks = np.abs(rng.normal(size=(20, 8, 8))).astype(np.float32)
    try:
        register_backend(Recording())
        got = np.array(solve_blocks(
            jnp.asarray(blocks), 4,
            SolverConfig(iters=FAST.iters, backend="test-recording",
                         block_batch=8)))
    finally:
        unregister_backend("test-recording")
    assert shapes == [(8, 8, 8)] * 3  # 20 blocks -> 8+8+(4 padded to 8)
    want = np.array(solve_blocks(jnp.asarray(blocks), 4, FAST))
    assert (got == want).all()


def test_stream_stats_padding_waste():
    rng = np.random.default_rng(11)
    svc = MaskService(FAST, policy=TINY)
    svc.solve(rng.normal(size=(8, 40)).astype(np.float32), PatternSpec(4, 8))
    stats = svc.stats.stream
    waste = stats.padding_waste()
    assert set(waste) <= set(TINY.ladder())
    assert all(0.0 <= v < 1.0 for v in waste.values())
    # bucket tallies are consistent with the global counters
    assert sum(stats.bucket_padded.values()) == stats.blocks_padded
    assert (sum(stats.bucket_blocks.values())
            == stats.blocks_solved + stats.blocks_padded)
    assert "waste_per_bucket=" in svc.stats.summary()


# ---------------------------------------------------------------------------
# Cache format: packbits payload + legacy raw-bool entries load.
# ---------------------------------------------------------------------------


def test_cache_packed_words_and_legacy_formats(tmp_path):
    from repro.checkpoint import ContentStore
    from repro.service.cache import MaskCache

    rng = np.random.default_rng(12)
    mask = rng.random(size=(5, 8, 8)) > 0.5
    store = ContentStore(str(tmp_path))
    cache = MaskCache(store)
    cache.put("k-new", mask)
    payload = dict(np.load(str(tmp_path / "k-new.npz")))
    assert "mask_words" in payload and int(payload["cache_format"]) == 3
    assert payload["mask_words"].shape == (5, 8)  # one uint32 word per row

    store.put("k-v1", mask=mask)  # a v1 raw-bool entry from an old run
    store.put(  # a v2 np.packbits entry from a PR-2-era run
        "k-v2",
        mask_bits=np.packbits(mask.reshape(-1)),
        shape=np.asarray(mask.shape, np.int64),
        cache_format=np.asarray(2, np.int64),
    )
    fresh = MaskCache(ContentStore(str(tmp_path)))
    assert (fresh.get("k-new") == mask).all()
    assert (fresh.get("k-v1") == mask).all()
    assert (fresh.get("k-v2") == mask).all()
    assert fresh.disk_hits == 3
    words, shape = fresh.get_packed("k-v1")
    assert shape == mask.shape and words.dtype == np.uint32


def test_prune_fn_legacy_n_keyword():
    """Old keyword spelling wanda_prune(w, x, n=4, m=8) still works."""
    from repro.pruning import magnitude_prune, wanda_prune

    rng = np.random.default_rng(20)
    w = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    want = np.array(wanda_prune(w, x, PatternSpec(4, 8), config=FAST)[1])
    with pytest.warns(DeprecationWarning):
        got = np.array(wanda_prune(w, x, n=4, m=8, config=FAST)[1])
    assert (got == want).all()
    with pytest.warns(DeprecationWarning):
        got = np.array(magnitude_prune(w, n=4, m=8, config=FAST)[1])
    assert (got == np.array(magnitude_prune(w, PatternSpec(4, 8), config=FAST)[1])).all()


def test_legacy_mask_fn_contract_shimmed():
    """Pre-registry mask_fn(scores, n, m) callbacks still work (with a
    warning); (scores, pattern) callbacks are called directly."""
    from repro.pruning import magnitude_prune

    w = jnp.asarray(np.random.default_rng(21).normal(size=(8, 8)).astype(np.float32))
    seen = {}

    def legacy_fn(scores, n, m):
        seen["legacy"] = (n, m)
        return jnp.ones_like(scores, dtype=bool)

    def new_fn(scores, pattern):
        seen["new"] = pattern
        return jnp.ones_like(scores, dtype=bool)

    with pytest.warns(DeprecationWarning, match="mask_fn"):
        magnitude_prune(w, PatternSpec(2, 4), mask_fn=legacy_fn)
    assert seen["legacy"] == (2, 4)
    magnitude_prune(w, PatternSpec(2, 4), mask_fn=new_fn)
    assert seen["new"] == PatternSpec(2, 4)


def test_repro_init_reexports_match_api():
    import repro
    import repro.api as api

    assert set(repro._API_NAMES) == set(api.__all__)
    assert repro.PatternSpec is api.PatternSpec


def test_repro_compat_attribute():
    import subprocess, sys

    # fresh interpreter: repro.compat must resolve without any prior imports
    code = "import repro; repro.compat.make_mesh"
    res = subprocess.run([sys.executable, "-c", code], capture_output=True)
    assert res.returncode == 0, res.stderr.decode()
