"""kernels.vmem planning + service BucketPolicy edge cases.

The roofline autotuner seeds its candidate ladders from ``vmem_plan``, and
the service scheduler's bucket ladder is built on the same plan — so the
alignment/budget invariants here protect both the tuner and the scheduler.
"""
import pytest

from repro.kernels.vmem import (
    _BUDGET_FRACTION,
    _DEFAULT_VMEM_BYTES,
    VPU_ALIGN,
    device_vmem_bytes,
    vmem_plan,
)
from repro.service.scheduler import BucketPolicy, StreamStats


def _dev(kind):
    return type("D", (), {"device_kind": kind})()


# ---------------------------------------------------------------------------
# vmem_plan.
# ---------------------------------------------------------------------------


def test_plan_m1_caps_at_max_block_b():
    # m=1 blocks are 4 bytes each: the budget allows millions, the cap wins.
    plan = vmem_plan(1, _dev("cpu"))
    assert plan.m == 1 and plan.block_b == 512
    assert vmem_plan(1, _dev("cpu"), max_block_b=64).block_b == 64


def test_plan_rejects_bad_args():
    with pytest.raises(ValueError, match="m >= 1"):
        vmem_plan(0)
    with pytest.raises(ValueError, match="live_buffers"):
        vmem_plan(8, live_buffers=0)


@pytest.mark.parametrize("m", [1, 4, 8, 16, 32, 64])
@pytest.mark.parametrize("live", [1, 4, 6, 16])
def test_plan_invariants(m, live):
    plan = vmem_plan(m, _dev("cpu"), live_buffers=live)
    # Power of two, VPU-sublane aligned, never above the dispatch cap.
    assert plan.block_b & (plan.block_b - 1) == 0
    assert plan.block_b % VPU_ALIGN == 0
    assert VPU_ALIGN <= plan.block_b <= 512
    assert plan.budget_bytes == int(plan.vmem_bytes * _BUDGET_FRACTION)
    assert plan.bytes_per_block == live * 4 * m * m
    # Within budget whenever the budget admits at least one aligned tile.
    if plan.block_b > VPU_ALIGN:
        assert plan.tile_bytes() <= plan.budget_bytes


def test_plan_tiny_budget_floors_at_vpu_align():
    # Huge blocks + many live buffers blow any budget: the plan floors at
    # one VPU sublane rather than going to zero (the kernel pads instead).
    plan = vmem_plan(1024, _dev("cpu"), live_buffers=64)
    assert plan.block_b == VPU_ALIGN
    assert plan.tile_bytes() > plan.budget_bytes  # over budget by design


def test_plan_large_live_buffers_shrinks_tile():
    lean = vmem_plan(16, _dev("cpu"), live_buffers=2)
    fat = vmem_plan(16, _dev("cpu"), live_buffers=32)
    assert fat.block_b <= lean.block_b


def test_device_vmem_kinds():
    assert device_vmem_bytes(_dev("TPU v5p")) == 128 * 1024 * 1024
    assert device_vmem_bytes(_dev("TPU v6 lite")) == 128 * 1024 * 1024
    assert device_vmem_bytes(_dev("cpu")) == _DEFAULT_VMEM_BYTES
    assert device_vmem_bytes(_dev("")) == _DEFAULT_VMEM_BYTES
    # More VMEM -> at-least-as-large tiles at the same m.
    assert (vmem_plan(32, _dev("TPU v5p")).block_b
            >= vmem_plan(32, _dev("cpu")).block_b)


# ---------------------------------------------------------------------------
# BucketPolicy ladders.
# ---------------------------------------------------------------------------


def test_ladder_geometric_and_capped():
    pol = BucketPolicy(base=512, growth=4, max_bucket=32768)
    assert pol.ladder() == (512, 2048, 8192, 32768)
    assert BucketPolicy(base=512, growth=4, max_bucket=512).ladder() == (512,)


def test_sub_rungs_descend_to_min_bucket():
    pol = BucketPolicy(base=512, min_bucket=8)
    rungs = pol.sub_rungs()
    assert rungs == (256, 128, 64, 32, 16, 8)
    assert BucketPolicy(base=512, min_bucket=0).sub_rungs() == ()
    # min_bucket at or above base means no sub-base rungs at all.
    assert BucketPolicy(base=16, min_bucket=64).sub_rungs() == ()


@pytest.mark.parametrize("total", [1, 7, 8, 511, 512, 513, 4096, 50000])
def test_plan_covers_total(total):
    for pol in (BucketPolicy(), BucketPolicy(tail_decompose=True, min_bucket=8)):
        sizes = pol.plan(total)
        assert sum(sizes) >= total
        legal = set(pol.ladder()) | set(pol.sub_rungs())
        assert set(sizes) <= legal
        # Padding bound: one covering rung at most, and with sub-rungs the
        # round-up is bounded by the smallest rung.
        if pol.min_bucket:
            assert sum(sizes) - total < pol.min_bucket


def test_tail_decompose_beats_covering_bucket():
    fat = BucketPolicy(tail_decompose=False)
    lean = BucketPolicy(tail_decompose=True, min_bucket=8)
    total = 512 + 9  # one base bucket + a 9-block tail
    assert sum(fat.plan(total)) - total >= 512 - 9  # tail rounds up to base
    assert sum(lean.plan(total)) - total < 8


def test_for_device_base_is_fused_tile():
    from repro.kernels.fused_solve import fused_block_b

    for m in (8, 16, 32):
        pol = BucketPolicy.for_device(m, _dev("cpu"))
        assert pol.base == fused_block_b(m, _dev("cpu"))
        assert pol.tail_decompose and pol.min_bucket == min(VPU_ALIGN, pol.base)
        # Every rung is a whole number of kernel tiles: no partial-tile pad.
        for rung in pol.ladder():
            assert rung % pol.base == 0
        assert pol.max_bucket * 4 * m * m <= 256 * 1024 * 1024 or \
            pol.max_bucket == pol.base


def test_for_device_waste_feedback_tightens_growth():
    stats = StreamStats()
    stats.note_batch(512, real=100, padded=412)  # 80% padding waste
    tight = BucketPolicy.for_device(16, _dev("cpu"), stats=stats)
    loose = BucketPolicy.for_device(16, _dev("cpu"))
    assert loose.growth == 4 and tight.growth == 2


def test_for_device_m1_edge():
    pol = BucketPolicy.for_device(1, _dev("cpu"))
    assert pol.base >= 1
    assert pol.plan(3)  # tiny stream on the tiniest block size still plans
