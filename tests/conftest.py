import os
import sys

# Tests see ONE device (the dry-run sets its own flags in a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# On a single-core host the XLA CPU client has one execution thread, so the
# io_callback escape hatch (solve_via="callback") deadlocks: the outer jitted
# computation holds the only thread while the callback waits on a nested
# dispatch.  A second host device gives that dispatch somewhere to run.
if os.cpu_count() == 1:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=2"
    )
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
