import os
import sys

# Tests see ONE device (the dry-run sets its own flags in a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Single-core hosts need a second XLA host device or solve_via="callback"
# deadlocks — shared helper (repro.hostenv imports neither jax nor numpy),
# also used by tools/check_docs.py.  Must run before the first jax import.
from repro.hostenv import single_core_xla_workaround  # noqa: E402

single_core_xla_workaround()
