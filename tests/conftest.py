import os
import sys

# Tests see ONE device (the dry-run sets its own flags in a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
