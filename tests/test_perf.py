"""repro.perf: roofline model, tuning table, autotuner, perf-check engine."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.kernels.vmem import VPU_ALIGN, vmem_plan
from repro.perf.checks import (
    CHECKS,
    Extractor,
    ExtractionError,
    PerfCheck,
    Trend,
    evaluate_all,
    evaluate_check,
    extract,
)
from repro.perf.roofline import (
    CC_DEFAULT_TILES,
    DEFAULT_TILES,
    fused_solve_candidates,
    nm_grad_cost,
    nm_sparsify_candidates,
    nm_sparsify_cost,
    nm_spmm_candidates,
    nm_spmm_cc_candidates,
    nm_spmm_cc_cost,
    nm_spmm_cost,
    profile_for,
)
from repro.perf.table import (
    GEMV_MAX_ROWS,
    TABLE_VERSION,
    TableEntry,
    TuningTable,
    fused_solve_block_b,
    nm_grad_tiles,
    nm_spmm_tiles,
    set_tuning_table,
    shape_class,
)

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture
def scratch_table():
    """Install an empty table for the test, restore lazy default after."""
    table = TuningTable()
    set_tuning_table(table)
    yield table
    set_tuning_table(None)


# ---------------------------------------------------------------------------
# Roofline cost model.
# ---------------------------------------------------------------------------


def test_cost_exact_fit_counts():
    # 256x256x256 tiles on a 256/512/512 shape: no padding anywhere.
    c = nm_spmm_cost(256, 512, 512, 8, 16, 256, 256, 256)
    assert c.grid_steps == 1 * 2 * 2
    assert c.mxu_flops == 2 * 256 * 512 * 512
    # X re-read once per F tile; W re-read once per B tile.
    assert c.hbm_bytes == (2 * 256 * 512 * 4) + (1 * 32 * 8 * 512 * 5) + 256 * 512 * 4


def test_cost_padding_is_charged():
    # 8 decode rows under a 256-row tile: padded work is 32x the real work.
    fat = nm_spmm_cost(8, 512, 512, 8, 16, 256, 256, 256)
    slim = nm_spmm_cost(8, 512, 512, 8, 16, 8, 256, 256)
    assert fat.mxu_flops == 32 * slim.mxu_flops
    prof = profile_for(object())  # unknown kind -> cpu fallback profile
    assert slim.model_seconds(prof) < fat.model_seconds(prof)


def test_cost_rejects_kt_not_multiple_of_m():
    with pytest.raises(ValueError, match="multiple of m"):
        nm_spmm_cost(8, 512, 512, 8, 16, 8, 100, 256)


def test_candidates_legal_and_include_default():
    for rows in (8, 1024):
        cands = nm_spmm_candidates(rows, 384, 1536, 8, 16)
        tiles = [c.tiles for c in cands]
        assert DEFAULT_TILES in tiles  # argmin can never lose to the default
        row_cap = max(VPU_ALIGN, -(-rows // VPU_ALIGN) * VPU_ALIGN)
        for c in cands:
            assert c.kt % 16 == 0
            if c.tiles != DEFAULT_TILES:  # default exempt from the clamp
                assert c.bt <= row_cap


def test_candidates_prefer_slim_bt_for_decode():
    best = nm_spmm_candidates(8, 384, 1536, 8, 16)[0]
    assert best.bt <= VPU_ALIGN  # model agrees with measurement on decode


def test_fused_solve_candidates_seeded_from_vmem_plan():
    cands = fused_solve_candidates(16)
    top = vmem_plan(16, live_buffers=6).block_b
    assert cands[0] == top
    assert cands[-1] == VPU_ALIGN
    assert all(a == 2 * b for a, b in zip(cands, cands[1:]))


# ---------------------------------------------------------------------------
# Structured-sparse backward cost model (nm_sparsify / nm_spmm_cc / nm_grad).
# ---------------------------------------------------------------------------


def test_nm_sparsify_cost_single_pass_counts():
    # 256x512 dY under exact-fit tiles: one dense read, one compressed write.
    c = nm_sparsify_cost(256, 512, 8, 16, 256, 256)
    assert c.grid_steps == 1 * 2
    read = 256 * 512 * 4
    write = (256 // 16) * 8 * 512 * 3  # bf16 values + int8 idx
    assert c.hbm_bytes == read + write
    assert c.mxu_flops == 0  # pure VPU op


def test_nm_sparsify_cost_rejects_partial_blocks():
    with pytest.raises(ValueError, match="multiple of m"):
        nm_sparsify_cost(256, 512, 8, 16, 200, 256)


def test_nm_spmm_cc_cost_revisit_structure():
    # Exact fit, single tile per axis: each operand read once, plus output.
    c = nm_spmm_cc_cost(256, 256, 512, 8, 16, 8, 16, 256, 256, 512)
    g = (256 // 16) * 8 * 512 * 3   # compressed dY: bf16 + idx
    w = (256 // 16) * 8 * 512 * 5   # compressed W: f32 + idx
    assert c.hbm_bytes == g + w + 256 * 256 * 4
    # Halving ft doubles grid steps but not operand traffic (revisits are
    # per B/K tile, not per F tile).
    c2 = nm_spmm_cc_cost(256, 256, 512, 8, 16, 8, 16, 256, 256, 256)
    assert c2.grid_steps == 2 * c.grid_steps
    assert c2.hbm_bytes == c.hbm_bytes


def test_nm_sparsify_candidates_legal_and_include_default():
    for rows in (8, 1024):
        cands = nm_sparsify_candidates(rows, 384, 8, 16)
        tiles = [(c.bt, c.ft) for c in cands]
        assert all(c.bt % 16 == 0 for c in cands)
        # The clamped default is always present so argmin can't lose to it.
        assert (256, 256) in tiles


def test_nm_spmm_cc_candidates_legal_and_include_default():
    cands = nm_spmm_cc_candidates(1024, 1536, 384, 8, 16, 8, 16)
    assert all(c.bt % 16 == 0 and c.kt % 16 == 0 for c in cands)
    assert CC_DEFAULT_TILES in [c.tiles for c in cands]


def test_nm_grad_cost_hits_bench_gate():
    # bench-30m down-proj at the BENCH_backward batch: the analytic model
    # itself must clear the 0.8x bytes gate the benchmark enforces.
    cost = nm_grad_cost(1024, 1536, 384, 8, 16, 8, 16)
    assert cost["sparse_bytes"] < cost["dense_bytes"]
    assert cost["ratio"] <= 0.8, cost["ratio"]
    # Every component is positive and the totals are consistent.
    assert cost["sparse_bytes"] == sum(cost["sparse"].values())
    assert cost["dense_bytes"] == sum(cost["dense"].values())
    assert all(v > 0 for v in cost["sparse"].values())
    assert all(v > 0 for v in cost["dense"].values())


def test_nm_grad_cost_honors_resolved_tiles():
    # Passing explicit tiles changes the revisit counts (the benchmark's
    # "measured" side evaluates the model at kernel-resolved tiles).
    base = nm_grad_cost(1024, 1536, 384, 8, 16, 8, 16)
    tall = nm_grad_cost(1024, 1536, 384, 8, 16, 8, 16,
                        cc_tiles=(256, 256, 256))
    # Shorter cc rows -> more W revisits -> strictly more sparse-path dX bytes.
    assert tall["sparse"]["dx"] > base["sparse"]["dx"]
    assert tall["dense"] == base["dense"]


# ---------------------------------------------------------------------------
# Tuning table.
# ---------------------------------------------------------------------------


def test_shape_class_buckets():
    assert shape_class(8, 384, 1536) == "gemv/k512/f2048"
    assert shape_class(GEMV_MAX_ROWS, 512, 2048) == "gemv/k512/f2048"
    assert shape_class(GEMV_MAX_ROWS + 1, 512, 2048) == "gemm/k512/f2048"
    # Test-model shapes land in different buckets than the bench shapes, so
    # committed cpu entries never retile the small bit-identity tests.
    assert shape_class(16, 64, 96) != shape_class(8, 384, 1536)


def test_table_round_trip(tmp_path):
    e = TableEntry("nm_spmm_fwd", "cpu", 16, "gemv/k512/f2048", (8, 128, 512),
                   measured_s=1e-3, default_s=2e-3, speedup_vs_default=2.0,
                   shape=(8, 384, 1536, 8))
    t = TuningTable([e])
    path = tmp_path / "table.json"
    t.save(path)
    loaded = TuningTable.load(path)
    assert loaded.entries() == [e]
    assert loaded.lookup(*e.key) == e
    assert loaded.lookup("nm_spmm_fwd", "cpu", 16, "gemm/k64/f128") is None


def test_table_version_gate(tmp_path):
    for bad in (TABLE_VERSION + 1, 0):
        path = tmp_path / f"v{bad}.json"
        path.write_text(json.dumps({"version": bad, "entries": []}))
        with pytest.raises(ValueError, match="version"):
            TuningTable.load(path)


def test_put_overwrites_same_key():
    t = TuningTable()
    a = TableEntry("fused_solve", "cpu", 16, "solve", (512,))
    b = TableEntry("fused_solve", "cpu", 16, "solve", (128,))
    t.put(a)
    t.put(b)
    assert len(t) == 1 and t.lookup(*a.key).tiles == (128,)


def test_trace_time_lookup_hits_and_misses(scratch_table):
    scratch_table.put(TableEntry(
        "nm_spmm_fwd", "cpu", 16, shape_class(8, 384, 1536), (8, 128, 512)))
    scratch_table.put(TableEntry("fused_solve", "cpu", 16, "solve", (128,)))
    dev = type("D", (), {"device_kind": "cpu"})()
    assert nm_spmm_tiles(8, 384, 1536, 16, False, dev) == (8, 128, 512)
    # Misses: wrong op variant, wrong shape class, wrong device kind.
    assert nm_spmm_tiles(8, 384, 1536, 16, True, dev) is None
    assert nm_spmm_tiles(64, 64, 96, 16, False, dev) is None
    tpu = type("D", (), {"device_kind": "TPU v5p"})()
    assert nm_spmm_tiles(8, 384, 1536, 16, False, tpu) is None
    assert fused_solve_block_b(16, dev) == 128
    assert fused_solve_block_b(8, dev) is None


class _CountingTable(TuningTable):
    """TuningTable that counts ``lookup`` calls (memoization regression)."""

    def __init__(self, entries=()):
        super().__init__(entries)
        self.lookups = 0

    def lookup(self, op, device_kind, m, shape_cls):
        self.lookups += 1
        return super().lookup(op, device_kind, m, shape_cls)


def test_tile_resolution_one_lookup_per_shape_class():
    # Kernels resolve tiles on every trace; the memo in table.py must hit
    # the table exactly once per distinct (op, device, m, shape class).
    dev = type("D", (), {"device_kind": "memo-kind"})()
    cls = shape_class(1024, 384, 1536)
    table = _CountingTable([
        TableEntry("nm_spmm_fwd", "memo-kind", 16, cls, (512, 256, 256)),
    ])
    set_tuning_table(table)
    try:
        for _ in range(5):
            assert nm_spmm_tiles(1024, 384, 1536, 16, False, dev) == (512, 256, 256)
        assert table.lookups == 1
        # Same shape class, different concrete rows: still the same memo slot.
        assert nm_spmm_tiles(768, 384, 1536, 16, False, dev) == (512, 256, 256)
        assert shape_class(768, 384, 1536) == cls
        assert table.lookups == 1
        # A new shape class costs exactly one more lookup — misses included.
        for _ in range(3):
            assert nm_spmm_tiles(8, 384, 1536, 16, False, dev) is None
        assert table.lookups == 2
        # Distinct ops are distinct memo slots.
        for _ in range(3):
            assert nm_grad_tiles("nm_sparsify", 1024, 384, 1536, 16, dev) is None
        assert table.lookups == 3
    finally:
        set_tuning_table(None)


def test_tile_resolution_invalidated_by_set_tuning_table():
    # Installing a table bumps the memo generation: identical queries
    # re-resolve against the new entries instead of serving stale tiles.
    dev = type("D", (), {"device_kind": "memo-kind"})()
    cls = shape_class(1024, 384, 1536)
    first = _CountingTable()
    set_tuning_table(first)
    try:
        assert nm_spmm_tiles(1024, 384, 1536, 16, False, dev) is None
        assert first.lookups == 1
        second = _CountingTable([
            TableEntry("nm_spmm_fwd", "memo-kind", 16, cls, (256, 512, 256)),
        ])
        set_tuning_table(second)
        assert nm_spmm_tiles(1024, 384, 1536, 16, False, dev) == (256, 512, 256)
        assert second.lookups == 1 and first.lookups == 1
    finally:
        set_tuning_table(None)


def test_env_var_override(tmp_path, monkeypatch):
    path = tmp_path / "env_table.json"
    TuningTable([TableEntry("fused_solve", "envkind", 4, "solve", (64,))]).save(path)
    monkeypatch.setenv("REPRO_TUNING_TABLE", str(path))
    set_tuning_table(None)  # re-arm lazy resolution so the env var is read
    try:
        dev = type("D", (), {"device_kind": "envkind"})()
        assert fused_solve_block_b(4, dev) == 64
    finally:
        set_tuning_table(None)


def test_committed_default_table_loads_and_gates():
    table = TuningTable.load(REPO / "src" / "repro" / "perf" / "default_table.json")
    assert len(table) >= 1
    for entry in table.entries():
        assert entry.speedup_vs_default >= 1.0, entry


# ---------------------------------------------------------------------------
# Autotuner (tiny live measurement — interpret mode, seconds not minutes).
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_autotune_tiny_nm_spmm(scratch_table):
    from repro.perf.autotune import autotune_nm_spmm

    res = autotune_nm_spmm(8, 32, 64, 2, 4, max_candidates=3, reps=1)
    assert res.speedup_vs_default >= 1.0  # default is in the measured set
    assert res.best_seconds <= res.default_seconds
    entry = res.table_entry()
    assert entry.key == ("nm_spmm_fwd", res.device_kind, 4, res.shape_class)
    scratch_table.put(entry)
    dev = type("D", (), {"device_kind": res.device_kind})()
    assert nm_spmm_tiles(8, 32, 64, 4, False, dev) == entry.tiles


# ---------------------------------------------------------------------------
# Declarative check engine.
# ---------------------------------------------------------------------------

DOC = {
    "meta": {"device": "cpu", "model": "tiny"},
    "headline": {
        "cells": {"a": {"speedup": 1.5}, "b": {"speedup": 1.1}},
        "tok_s": 100.0,
        "per_m": [10.0, 20.0],
    },
    "results": [{"mode": "dense", "s": 1.0}, {"mode": "sparse", "s": 0.5}],
}


def test_extract_paths_fanout_and_selector():
    assert extract(DOC, "headline.tok_s") == 100.0
    assert sorted(extract(DOC, "headline.cells.*.speedup")) == [1.1, 1.5]
    assert extract(DOC, "results.[mode=sparse].s") == 0.5
    with pytest.raises(ExtractionError):
        extract(DOC, "headline.nope")
    with pytest.raises(ExtractionError):
        extract(DOC, "results.[mode=missing].s")


def _check(**kw):
    base = dict(name="c", bench="BENCH_x.json",
                extract=(Extractor("tok_s", "headline.tok_s"),
                         Extractor("per_m", "headline.per_m")),
                trends=(Trend("tok_s", tolerance=0.15),))
    base.update(kw)
    return PerfCheck(**base)


def test_sanity_pass_fail_and_extraction_failure():
    ok = evaluate_check(_check(sanity=("tok_s > 50", "min(per_m) >= 10")), DOC)
    assert ok.status == "ok"
    bad = evaluate_check(_check(sanity=("tok_s > 500",)), DOC)
    assert bad.status == "sanity_failed" and bad.gating_failure
    assert "tok_s > 500" in bad.sanity_failures
    missing = evaluate_check(
        _check(extract=(Extractor("v", "headline.gone"),)), DOC)
    assert missing.status == "sanity_failed"


def test_trend_gate_and_warn():
    worse = json.loads(json.dumps(DOC))
    worse["headline"]["tok_s"] = 70.0  # -30% < -15% band
    res = evaluate_check(_check(), worse, DOC)
    assert res.status == "regressed"
    row = res.trend_rows[0]
    assert row["verdict"] == "regressed" and row["mode"] == "gate"
    warn = evaluate_check(
        _check(trends=(Trend("tok_s", tolerance=0.15, mode="warn"),)), worse, DOC)
    assert warn.status == "ok"  # warn trends report but never gate


def test_trend_list_valued_worst_element():
    worse = json.loads(json.dumps(DOC))
    worse["headline"]["per_m"] = [10.0, 14.0]  # second element -30%
    res = evaluate_check(
        _check(trends=(Trend("per_m", tolerance=0.15),)), worse, DOC)
    assert res.status == "regressed"
    assert res.trend_rows[0]["delta_frac"] == pytest.approx(-0.3)


def test_trend_lower_is_better():
    t = Trend("loss", direction="lower", tolerance=0.10)
    assert t.verdict(1.05, 1.0) == "ok"
    assert t.verdict(1.2, 1.0) == "regressed"
    assert t.verdict(0.8, 1.0) == "improved"


def test_incomparable_baseline_skips_trends():
    other = json.loads(json.dumps(DOC))
    other["meta"]["model"] = "smoke"
    other["headline"]["tok_s"] = 1.0  # would be a huge regression...
    res = evaluate_check(_check(compare_keys=("meta.model",)), other, DOC)
    assert res.status == "ok" and not res.trend_rows  # ...but isn't compared
    assert any("not comparable" in n for n in res.notes)


def test_evaluate_all_missing_vs_required(tmp_path):
    checks = (_check(), _check(name="opt", required=False))
    res = evaluate_all(tmp_path, checks=checks)
    assert [r.status for r in res] == ["skipped", "skipped"]
    res = evaluate_all(tmp_path, checks=checks, require_all=True)
    assert [r.status for r in res] == ["missing", "skipped"]
    assert res[0].gating_failure and not res[1].gating_failure


# ---------------------------------------------------------------------------
# The committed suite against the committed BENCH files + injected regression.
# ---------------------------------------------------------------------------


def test_committed_benches_pass_all_sanity():
    results = evaluate_all(REPO, REPO)
    by_name = {r.check: r for r in results}
    assert len(by_name) == len(CHECKS)
    for r in results:
        assert not r.gating_failure, (r.check, r.sanity_failures, r.notes)
    # Self-comparison trends are exactly flat.
    for row in by_name["train_compressed_exec"].trend_rows:
        assert row["verdict"] == "ok"


def test_injected_regression_fails_named_check(tmp_path):
    doc = json.loads((REPO / "BENCH_train.json").read_text())
    for key in ("headline",):
        doc[key]["tokens_per_sec"]["compressed"] *= 0.8  # -20% throughput
    (tmp_path / "BENCH_train.json").write_text(json.dumps(doc))
    results = evaluate_all(tmp_path, REPO)
    train = next(r for r in results if r.check == "train_compressed_exec")
    assert train.status == "regressed" and train.gating_failure
    row = next(t for t in train.trend_rows if t["var"] == "tok_s_compressed")
    assert row["verdict"] == "regressed"


def _run_perfcheck(*args):
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "perfcheck.py"), *args],
        capture_output=True, text=True,
    )


def test_perfcheck_cli_green_on_committed(tmp_path):
    report = tmp_path / "report.json"
    proc = _run_perfcheck("--report", str(report))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(report.read_text())
    assert doc["failed"] == []


def test_perfcheck_cli_exit_nonzero_names_regression(tmp_path):
    doc = json.loads((REPO / "BENCH_train.json").read_text())
    doc["headline"]["tokens_per_sec"]["compressed"] *= 0.8
    (tmp_path / "BENCH_train.json").write_text(json.dumps(doc))
    proc = _run_perfcheck("--current", str(tmp_path), "--baseline", str(REPO),
                          "--only", "train_compressed_exec")
    assert proc.returncode == 1
    assert "train_compressed_exec" in proc.stdout  # the failed check is named
