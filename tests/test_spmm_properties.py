"""nm_spmm property sweep: kernel vs oracle across tiles, patterns, shapes.

Covers the tile-resolution refactor: autotuned/default-resolved tiles
(``bt=kt=ft=None``) and adversarial explicit tiles must all agree bitwise
with each other and numerically with the pure-jnp oracle, for every pattern
the repo ships (2:4, 8:16, transposable 16:32), on square, non-square and
tall/skinny decode shapes.  Hypothesis widens the sweep when installed; the
parametrized cases below always run.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip cleanly; the rest of the module runs
    def given(*args, **kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(**kwargs):
        return lambda f: f

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        sampled_from = staticmethod(lambda *a, **k: None)
        integers = staticmethod(lambda *a, **k: None)

from repro.kernels.nm_spmm.kernel import _resolve_tiles, nm_spmm_pallas
from repro.kernels.nm_spmm.ref import nm_spmm_ref
from repro.kernels.vmem import VPU_ALIGN
from repro.sparsity.compressed import decompress_nm

# (n, m) for every shipped pattern family; the kernel only sees (n, m) —
# transposability (t16:32) constrains the mask, not the compressed layout.
PATTERNS = [(2, 4), (8, 16), (16, 32)]

# (B, K, F): square-ish GEMM, non-square, tall/skinny decode GEMV.
SHAPES = [(16, 64, 64), (5, 96, 32), (8, 32, 160), (1, 64, 96), (3, 128, 64)]

# Adversarial explicit tiles (scaled to the shape at use): minimum legal,
# deliberately misaligned-to-shape, and oversized-everything.
def adversarial_tiles(k, f, n, m):
    return [
        (VPU_ALIGN, m, 128),             # smallest legal everything
        (256, max(m, 2 * m), 128),       # fat batch tile on small batches
        (VPU_ALIGN, 4 * m, 512),         # kt and ft larger than K and F
        (VPU_ALIGN, 2 * m, 512),         # same kt as above pair, wide ft
        (256, 256 if 256 % m == 0 else 8 * m, 256),  # the historic default
    ]


def synth_compressed(k, f, n, m, seed=0):
    """Random valid compressed operand: sorted distinct indices per group."""
    rng = np.random.default_rng(seed)
    g = k // m
    vals = rng.normal(size=(g, n, f)).astype(np.float32)
    idx = np.empty((g, n, f), dtype=np.int8)
    for gi in range(g):
        for fi in range(f):
            idx[gi, :, fi] = np.sort(rng.choice(m, size=n, replace=False))
    return jnp.asarray(vals), jnp.asarray(idx)


def _check_shape(b, k, f, n, m, tiles, seed=0, transpose=False):
    vals, idx = synth_compressed(k, f, n, m, seed)
    cols = f if not transpose else k
    x = jnp.asarray(
        np.random.default_rng(seed + 1).normal(size=(b, k if not transpose else f))
    ).astype(jnp.float32)
    bt, kt, ft = tiles if tiles else (None, None, None)
    got = np.array(nm_spmm_pallas(x, vals, idx, m, transpose=transpose,
                                  bt=bt, kt=kt, ft=ft))
    want = np.array(nm_spmm_ref(x, vals, idx, m, transpose=transpose))
    assert got.shape == (b, cols)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    return got


# ---------------------------------------------------------------------------
# Always-run parametrized sweep (hypothesis is optional in this container).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("b,k,f", SHAPES)
def test_forward_resolved_tiles_match_ref(b, k, f, n, m):
    _check_shape(b, k, f, n, m, tiles=None)


@pytest.mark.parametrize("n,m", PATTERNS)
@pytest.mark.parametrize("b,k,f", [(16, 64, 64), (8, 32, 160), (1, 64, 96)])
def test_transpose_resolved_tiles_match_ref(b, k, f, n, m):
    _check_shape(b, k, f, n, m, tiles=None, transpose=True)


@pytest.mark.parametrize("n,m", PATTERNS)
def test_adversarial_tiles_consistent(n, m):
    """Every legal tiling matches the oracle; tilings that keep the same
    ``kt`` (identical K-reduction grouping, so identical f32 rounding) must
    be *bit-identical* — bt and ft only move independent rows/columns."""
    b, k, f = 5, 2 * m, 96
    by_kt: dict[int, list[np.ndarray]] = {}
    for bt, kt, ft in adversarial_tiles(k, f, n, m):
        if kt % m:
            continue
        out = _check_shape(b, k, f, n, m, tiles=(bt, kt, ft), seed=7)
        by_kt.setdefault(kt, []).append(out)
    bt_r, kt_r, ft_r = _resolve_tiles(b, k, f, m, False, None, None, None)
    by_kt.setdefault(kt_r, []).append(
        _check_shape(b, k, f, n, m, tiles=None, seed=7))
    assert any(len(v) > 1 for v in by_kt.values())  # the claim is exercised
    for outs in by_kt.values():
        for out in outs[1:]:
            np.testing.assert_array_equal(out, outs[0])


@pytest.mark.parametrize("transpose", [False, True])
def test_decode_clamp_bit_identity(transpose):
    """The bt clamp (None -> padded-rowcount tile at B=8 decode) must be a
    pure scheduling change: bit-identical to the unclamped bt=256 grid."""
    n, m, b, k, f = 8, 16, 8, 64, 128
    vals, idx = synth_compressed(k, f, n, m, seed=3)
    x = jnp.asarray(np.random.default_rng(4).normal(
        size=(b, f if transpose else k))).astype(jnp.float32)
    bt_resolved, kt, ft = _resolve_tiles(b, k, f, m, transpose, None, None, None)
    assert bt_resolved <= VPU_ALIGN  # the clamp actually engaged
    clamped = np.array(nm_spmm_pallas(x, vals, idx, m, transpose=transpose))
    unclamped = np.array(nm_spmm_pallas(x, vals, idx, m, transpose=transpose,
                                        bt=256, kt=kt, ft=ft))
    np.testing.assert_array_equal(clamped, unclamped)


@pytest.mark.parametrize("n,m", PATTERNS)
def test_decompress_transpose_consistency(n, m):
    """x @ decompress(vals, idx).T == kernel transpose product (numerics)."""
    b, k, f = 4, 2 * m, 64
    vals, idx = synth_compressed(k, f, n, m, seed=11)
    w = np.array(decompress_nm(vals, idx, m))  # (K, F)
    x = np.random.default_rng(12).normal(size=(b, f)).astype(np.float32)
    got = np.array(nm_spmm_pallas(jnp.asarray(x), vals, idx, m, transpose=True))
    np.testing.assert_allclose(got, x @ w.T, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis widening (runs only where hypothesis is installed).
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    nm=st.sampled_from(PATTERNS),
    b=st.integers(min_value=1, max_value=17),
    kg=st.integers(min_value=1, max_value=4),   # K = kg * m
    f=st.sampled_from([32, 96, 160]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_forward_property(nm, b, kg, f, seed):
    n, m = nm
    _check_shape(b, kg * m, f, n, m, tiles=None, seed=seed)


@settings(max_examples=10, deadline=None)
@given(
    nm=st.sampled_from(PATTERNS),
    b=st.integers(min_value=1, max_value=9),
    kg=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_transpose_property(nm, b, kg, seed):
    n, m = nm
    _check_shape(b, kg * m, 64, n, m, tiles=None, seed=seed, transpose=True)
