"""End-to-end behaviour: the paper's full workflow in miniature.

Dense pretrain -> one-shot transposable pruning (TSENOR+ALPS) -> sparse
fine-tune with fixed masks -> quality recovers; plus the compressed-format
equivalence the transposable masks enable (same buffer forward/backward).
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.solver import SolverConfig, is_transposable_nm
from repro.patterns import PatternSpec
from repro.data import SyntheticLM
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.pruning import alps_prune, gram_matrix, reconstruction_error
from repro.pruning.alps import AlpsConfig
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.train import TrainLoop, TrainLoopConfig, build_train_step, make_train_state

CFG = ModelConfig("e2e", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, remat="none",
                  dtype="float32")


def eval_loss(params, data, steps=4, offset=10_000):
    tot = 0.0
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(offset + i).items()}
        tot += float(lm.loss_fn(params, CFG, batch))
    return tot / steps


def test_pretrain_prune_finetune_recovers():
    data = SyntheticLM(vocab_size=64, seq_len=32, global_batch=8, seed=1)
    opt = AdamW(learning_rate=warmup_cosine(5e-3, 10, 120))
    state = make_train_state(CFG, opt, jax.random.PRNGKey(0))
    step = build_train_step(CFG, opt)
    loop = TrainLoop(step, data, None, TrainLoopConfig(total_steps=120, log_every=999),
                     log_fn=lambda s: None)
    state, hist = loop.run(state)
    dense_loss = eval_loss(state.params, data)
    assert dense_loss < hist[0]["loss"] * 0.7  # actually learned something

    # One-shot transposable 2:4 pruning.
    masks = sparsify_pytree(state.params, PatternSpec(2, 4),
                            config=SolverConfig(iters=60))
    pruned = apply_mask(state.params, masks)
    pruned_loss = eval_loss(pruned, data)
    assert pruned_loss > dense_loss  # pruning hurts before fine-tuning

    # Sparse fine-tune with fixed transposable masks (both-pass accelerable).
    opt_ft = AdamW(learning_rate=1e-3)
    st = make_train_state(CFG, opt_ft, jax.random.PRNGKey(1))
    st = st._replace(params=pruned)
    step_ft = build_train_step(CFG, opt_ft, masks=masks)
    loop_ft = TrainLoop(step_ft, data, None, TrainLoopConfig(total_steps=60, log_every=999),
                        log_fn=lambda s: None)
    st, _ = loop_ft.run(st)
    ft_loss = eval_loss(apply_mask(st.params, masks), data)
    assert ft_loss < pruned_loss, (dense_loss, pruned_loss, ft_loss)
    mq = np.array(masks["blocks"]["attn"]["wq"][0])
    assert is_transposable_nm(mq, 2, 4)


def test_alps_prunes_real_layer_activations():
    """ALPS on activations captured from a real (tiny) model layer."""
    data = SyntheticLM(vocab_size=64, seq_len=32, global_batch=8, seed=2)
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    from repro.models.layers import embed_tokens, rms_norm
    x = embed_tokens(params["embed"], batch["tokens"], jnp.float32)
    h = rms_norm(x, params["blocks"]["ln1"][0]).reshape(-1, CFG.d_model)
    w = params["blocks"]["attn"]["wq"][0]
    hmat = gram_matrix(h)
    wp, mask = alps_prune(w, hmat, PatternSpec(4, 8),
                          config=AlpsConfig(iters=40, solver=SolverConfig(iters=80)))
    assert is_transposable_nm(np.array(mask), 4, 8)
    err_alps = float(reconstruction_error(h, w, wp))
    # Fair baseline: the same transposable constraint, no ADMM updates.
    from repro.pruning import magnitude_prune
    w_mag, _ = magnitude_prune(w, PatternSpec(4, 8), config=SolverConfig(iters=80))
    err_mag = float(reconstruction_error(h, w, w_mag))
    assert err_alps < err_mag


def test_transposable_mask_serves_both_passes_compressed():
    """The transposable mask lets ONE compressed buffer do fwd and bwd."""
    from repro.core import solve_mask
    from repro.kernels.nm_spmm.ops import nm_linear
    from repro.sparsity.compressed import compress_nm

    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 64)).astype(np.float32)
    mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(4, 8)))
    vals, idx = compress_nm(jnp.asarray(w), jnp.asarray(mask), 4, 8)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    y, vjp = jax.vjp(lambda x: nm_linear(x, vals, idx, 8), x)
    (dx,) = vjp(jnp.ones_like(y))
    wd = jnp.asarray(w * mask)
    y2, vjp2 = jax.vjp(lambda x: x @ wd, x)
    (dx2,) = vjp2(jnp.ones_like(y2))
    np.testing.assert_allclose(np.array(y), np.array(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(dx), np.array(dx2), rtol=1e-4, atol=1e-4)
