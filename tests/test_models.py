"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES
from repro.data import SyntheticEmbeds, SyntheticLM
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.train import build_train_step, make_train_state


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step; shapes + finiteness."""
    cfg = get_smoke_config(arch)
    B, S = 2, 32
    if cfg.frontend != "none":
        data = SyntheticEmbeds(cfg.d_model, S, B, cfg.vocab_size)
    else:
        data = SyntheticLM(cfg.vocab_size, S, B)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    logits = lm.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds")
    )
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.array(logits, np.float32)).all()

    opt = AdamW(learning_rate=1e-3)
    state = make_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = build_train_step(cfg, opt)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    B, S = 2, 16
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    caches = lm.init_cache(cfg, B, 32)
    if cfg.frontend != "none":
        embeds = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
        logits, caches = lm.prefill(params, cfg, caches, embeds=embeds)
    else:
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
        logits, caches = lm.prefill(params, cfg, caches, tokens=toks)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches = lm.decode_step(params, cfg, tok, caches, jnp.asarray(S, jnp.int32))
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.array(logits2, np.float32)).all()


def test_full_configs_match_assignment():
    expected = {
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen3_moe_235b": (94, 4096, 64, 4, 1536, 151936),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llama32_3b": (28, 3072, 24, 8, 8192, 128256),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
    }
    for arch, (L, d, h, kv, ff, v) in expected.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), (arch, got)
    assert get_config("qwen3_moe_235b").num_experts == 128
    assert get_config("qwen3_moe_235b").top_k == 8
    assert get_config("mixtral_8x22b").num_experts == 8
    assert get_config("mixtral_8x22b").sliding_window > 0
    assert get_config("mamba2_370m").ssm_state == 128
    assert get_config("zamba2_7b").ssm_state == 64


def test_shapes_match_assignment():
    assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) == (4096, 256)
    assert (SHAPES["prefill_32k"].seq_len, SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (SHAPES["decode_32k"].seq_len, SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (SHAPES["long_500k"].seq_len, SHAPES["long_500k"].global_batch) == (524288, 1)


class TestDecodeConsistency:
    """Prefill+decode must reproduce the teacher-forced forward exactly."""

    CASES = [
        ModelConfig("d", "dense", num_layers=2, d_model=64, num_heads=4,
                    num_kv_heads=2, d_ff=128, vocab_size=256, remat="none",
                    dtype="float32"),
        ModelConfig("swa", "dense", num_layers=2, d_model=64, num_heads=4,
                    num_kv_heads=2, d_ff=128, vocab_size=256, sliding_window=6,
                    remat="none", dtype="float32"),
        ModelConfig("ssm", "ssm", num_layers=2, d_model=64, num_heads=0,
                    num_kv_heads=0, d_ff=0, vocab_size=256, ssm_state=16,
                    ssm_head_dim=16, ssm_chunk=4, remat="none", dtype="float32"),
        ModelConfig("hyb", "hybrid", num_layers=5, d_model=64, num_heads=4,
                    num_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16,
                    ssm_head_dim=16, ssm_chunk=4, hybrid_attn_every=2,
                    remat="none", dtype="float32"),
        ModelConfig("moe", "moe", num_layers=2, d_model=64, num_heads=4,
                    num_kv_heads=2, d_ff=96, vocab_size=256, num_experts=4,
                    top_k=2, moe_group=1, remat="none", dtype="float32"),
    ]

    @pytest.mark.parametrize("cfg", CASES, ids=lambda c: c.name)
    def test_decode_equals_forward(self, cfg):
        key = jax.random.PRNGKey(0)
        params = lm.init_params(cfg, key)
        toks = jax.random.randint(key, (1, 9), 0, cfg.vocab_size)
        full = lm.forward(params, cfg, tokens=toks)
        caches = lm.init_cache(cfg, 1, 16)
        lg, caches = lm.prefill(params, cfg, caches, tokens=toks[:, :8])
        np.testing.assert_allclose(np.array(lg), np.array(full[:, 7]),
                                   rtol=3e-3, atol=3e-3)
        lg2, _ = lm.decode_step(params, cfg, toks[:, 8], caches,
                                jnp.asarray(8, jnp.int32))
        np.testing.assert_allclose(np.array(lg2), np.array(full[:, 8]),
                                   rtol=3e-3, atol=3e-3)


def test_mrope_reduces_to_rope_for_text():
    from repro.models.attention import rope
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    a = rope(x, pos, 1e4)
    b = rope(x, pos, 1e4, mrope_sections=(2, 3, 3))
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-6, atol=1e-6)


def test_sliding_window_masks_out_far_context():
    """With SWA, tokens beyond the window cannot influence the output."""
    cfg = ModelConfig("swa", "dense", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64, sliding_window=4,
                      remat="none", dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 64)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % 64)  # differs outside last window
    l1 = lm.forward(params, cfg, tokens=t1)
    l2 = lm.forward(params, cfg, tokens=t2)
    np.testing.assert_allclose(np.array(l1[:, -1]), np.array(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)
