"""repro.kernels.nm_grad: MVU sparsify kernel, cc GEMM, sparse-grad wiring.

The kernel-vs-ref tests are *bitwise*: ``nm_sparsify_ref`` re-derives the
survivor set with an independent implementation sharing only the counter-PRNG
spec, so agreement pins the whole selection + rescale + packing pipeline.
The statistics tests check the MVU contract itself — elementwise
unbiasedness and the analytic variance ``a_j (S - a_j)`` — by tiling one
block across columns (each column draws an independent counter stream).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PatternSpec, SolverConfig
from repro.kernels.nm_grad.kernel import nm_sparsify_pallas, nm_spmm_cc_pallas
from repro.kernels.nm_grad.ops import (
    current_sparse_grad,
    nm_linear_sg,
    sparse_grad_context,
    sparse_grad_layer,
)
from repro.kernels.nm_grad.ref import (
    mvu_variance_ref,
    nm_sparsify_ref,
    nm_spmm_cc_ref,
)
from repro.kernels.nm_spmm.ops import nm_linear
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.perf.autotune import _synth_compressed
from repro.sparsity.compressed import decompress_nm
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.sparsity.params import (
    NMCompressed,
    compress_params,
    projection_prunable,
)
from repro.train import build_train_step, make_train_state
from repro.train.step import StepConfig

CFG = ModelConfig("sg-tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128, remat="none",
                  dtype="float32")


def _batch(seed=0, batch=4, seq=16, vocab=128):
    rng = np.random.default_rng(seed)
    tok = rng.integers(0, vocab, size=(batch, seq + 1))
    return {"tokens": jnp.asarray(tok[:, :-1]),
            "labels": jnp.asarray(tok[:, 1:])}


def _sparse_model(spec, seed=0, solver_iters=30):
    params = lm.init_params(CFG, jax.random.PRNGKey(seed))
    masks = sparsify_pytree(params, spec, config=SolverConfig(iters=solver_iters),
                            prunable=projection_prunable)
    return compress_params(apply_mask(params, masks), masks, spec)


def tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# Sparsify kernel vs the independent oracle — bitwise.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,f,n,m", [
    (32, 64, 2, 4),
    (48, 40, 4, 8),       # F not a multiple of the lane tile
    (30, 64, 8, 16),      # rows not a multiple of M — padded blocks
    (64, 96, 4, 16),      # 1:4 density
])
@pytest.mark.parametrize("seed", [0, 7])
def test_sparsify_matches_ref_bitwise(rows, f, n, m, seed):
    rng = np.random.default_rng(seed)
    dy = jnp.asarray(rng.normal(size=(rows, f)).astype(np.float32))
    kv, ki = nm_sparsify_pallas(dy, n, m, seed, salt=3)
    rv, ri = nm_sparsify_ref(dy, n, m, seed, salt=3)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))


def test_sparsify_bf16_stochastic_round_matches_ref():
    rng = np.random.default_rng(1)
    dy = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))
    kv, ki = nm_sparsify_pallas(dy, 2, 4, 5, out_dtype=jnp.bfloat16)
    rv, ri = nm_sparsify_ref(dy, 2, 4, 5, out_dtype=jnp.bfloat16)
    assert kv.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(
        np.asarray(kv).view(np.uint16), np.asarray(rv).view(np.uint16)
    )


def test_sparsify_tiling_independent():
    # Counters are GLOBAL (block-row, column) coordinates, so the draw — and
    # therefore the output — cannot depend on the grid decomposition.
    rng = np.random.default_rng(2)
    dy = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    ref = nm_sparsify_pallas(dy, 2, 4, 9)
    for bt, ft in [(4, 32), (16, 96), (64, 128)]:
        kv, ki = nm_sparsify_pallas(dy, 2, 4, 9, bt=bt, ft=ft)
        np.testing.assert_array_equal(np.asarray(kv), np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ref[1]))


def test_sparsify_seed_and_salt_determinism():
    rng = np.random.default_rng(3)
    dy = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    a = nm_sparsify_pallas(dy, 2, 4, 0)
    b = nm_sparsify_pallas(dy, 2, 4, 0)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    c = nm_sparsify_pallas(dy, 2, 4, 1)
    d = nm_sparsify_pallas(dy, 2, 4, 0, salt=1)
    assert not np.array_equal(np.asarray(a[1]), np.asarray(c[1]))
    assert not np.array_equal(np.asarray(a[1]), np.asarray(d[1]))


def test_sparsify_exact_when_block_already_fits():
    # <= N nonzeros per (M-block, column): the eligible set carries its own
    # mass, so MVU reproduces the input exactly — no stochastic error.
    rng = np.random.default_rng(4)
    n, m = 4, 8
    dy = rng.normal(size=(32, 16)).astype(np.float32)
    keep = np.zeros_like(dy, bool)
    for g in range(4):
        for c in range(16):
            keep[m * g + rng.choice(m, size=n, replace=False), c] = True
    dy = jnp.asarray(np.where(keep, dy, 0.0))
    kv, ki = nm_sparsify_pallas(dy, n, m, seed=11)
    np.testing.assert_array_equal(
        np.asarray(decompress_nm(kv, ki, m)), np.asarray(dy)
    )


# ---------------------------------------------------------------------------
# MVU statistics: unbiasedness + analytic variance.
# ---------------------------------------------------------------------------


def _mc_samples(block, n, m, f=256, seeds=12, out_dtype=jnp.float32):
    """Monte-Carlo MVU samples: one M-block tiled across ``f`` columns (each
    column is an independent counter stream), ``seeds`` independent seeds."""
    dy = jnp.asarray(np.tile(block.reshape(m, 1), (1, f)))
    outs = []
    for s in range(seeds):
        kv, ki = nm_sparsify_pallas(dy, n, m, s, out_dtype=out_dtype)
        outs.append(np.asarray(
            decompress_nm(kv, ki, m).astype(jnp.float32)
        ))
    return np.concatenate(outs, axis=1)  # (m, f * seeds)


@pytest.mark.parametrize("out_dtype,tol_sigma", [(jnp.float32, 6.0),
                                                 (jnp.bfloat16, 8.0)])
def test_mvu_unbiased(out_dtype, tol_sigma):
    rng = np.random.default_rng(5)
    n, m = 4, 8
    block = rng.normal(size=m).astype(np.float32)
    samples = _mc_samples(block, n, m, out_dtype=out_dtype)
    var = mvu_variance_ref(block.reshape(m, 1), n, m)[:, 0]
    mean_err = np.abs(samples.mean(axis=1) - block)
    # Deterministic positions are exact in f32; stochastic ones within
    # tol_sigma standard errors (bf16 adds the SR cast's quantization noise,
    # itself unbiased — the looser sigma covers its extra variance).
    budget = tol_sigma * np.sqrt(var / samples.shape[1]) + (
        0.0 if out_dtype == jnp.float32 else 2e-2 * np.abs(block)
    )
    assert (mean_err <= budget + 1e-6).all(), (mean_err, budget)


def test_mvu_variance_matches_analytic():
    rng = np.random.default_rng(6)
    n, m = 2, 8
    block = np.abs(rng.normal(size=m)).astype(np.float32) + 0.1
    samples = _mc_samples(block, n, m, seeds=16)
    mc_var = samples.var(axis=1)
    an_var = mvu_variance_ref(block.reshape(m, 1), n, m)[:, 0]
    # Aggregate over the block: per-element 4th-moment noise averages out.
    assert abs(mc_var.sum() - an_var.sum()) <= 0.15 * an_var.sum(), (
        mc_var, an_var
    )
    # Deterministic survivors have exactly zero spread.
    np.testing.assert_allclose(mc_var[an_var == 0.0], 0.0, atol=1e-12)


# ---------------------------------------------------------------------------
# Compressed x compressed GEMM.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,k,f,n_g,m_g,n_w,m_w", [
    (32, 48, 64, 2, 4, 4, 8),     # mixed patterns
    (64, 32, 80, 8, 16, 2, 4),    # F not a multiple of the lane tile
    (16, 64, 128, 4, 16, 8, 16),
])
def test_cc_gemm_matches_ref(b, k, f, n_g, m_g, n_w, m_w):
    gvals, gidx = _synth_compressed(b, f, n_g, m_g, seed=0)
    wvals, widx = _synth_compressed(k, f, n_w, m_w, seed=1)
    gvals = gvals.astype(jnp.bfloat16)
    out = nm_spmm_cc_pallas(gvals, gidx, wvals, widx, m_g, m_w)
    ref = nm_spmm_cc_ref(gvals, gidx, wvals, widx, m_g, m_w)
    assert out.shape == (b, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_cc_gemm_tile_shapes_only_reorder_accumulation():
    gvals, gidx = _synth_compressed(32, 96, 2, 4, seed=2)
    wvals, widx = _synth_compressed(48, 96, 2, 4, seed=3)
    ref = nm_spmm_cc_pallas(gvals, gidx, wvals, widx, 4, 4)
    out = nm_spmm_cc_pallas(gvals, gidx, wvals, widx, 4, 4,
                            bt=16, kt=16, ft=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# The custom-VJP op and the trace-time context.
# ---------------------------------------------------------------------------


def _compressed_weight(k, f, n, m, seed=0):
    vals, idx = _synth_compressed(k, f, n, m, seed)
    return vals, idx


def test_nm_linear_sg_forward_is_nm_linear_bitwise():
    vals, idx = _compressed_weight(32, 48, 2, 4)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 32)),
                    jnp.float32)
    y_sg = nm_linear_sg(x, vals, idx, 0, 4, 2, 4, 0, "bfloat16")
    y = nm_linear(x, vals, idx, 4)
    np.testing.assert_array_equal(np.asarray(y_sg), np.asarray(y))


def test_nm_linear_sg_backward_matches_ref_pipeline():
    """dx and dvals must equal the oracle pipeline: ref-sparsify the
    cotangent with the SAME (seed, salt), then dense GEMMs + support gather."""
    rng = np.random.default_rng(7)
    k, f, n, m = 32, 48, 2, 4
    n_g, m_g, seed, salt = 2, 4, 13, 2
    vals, idx = _compressed_weight(k, f, n, m)
    x = jnp.asarray(rng.normal(size=(24, k)).astype(np.float32))
    cot = jnp.asarray(rng.normal(size=(24, f)).astype(np.float32))

    def f_sg(x, vals):
        return nm_linear_sg(x, vals, idx, seed, m, n_g, m_g, salt, "bfloat16")

    _, vjp = jax.vjp(f_sg, x, vals)
    dx, dvals = vjp(cot)

    gv, gi = nm_sparsify_ref(cot, n_g, m_g, seed, salt=salt,
                             out_dtype=jnp.bfloat16)
    dy_s = np.asarray(decompress_nm(gv, gi, m_g).astype(jnp.float32))
    w = np.asarray(decompress_nm(vals, idx, m))
    np.testing.assert_allclose(np.asarray(dx), dy_s @ w.T,
                               rtol=1e-5, atol=1e-5)
    dw = np.asarray(x).T @ dy_s
    dwg = dw.reshape(k // m, m, f)
    idx_np = np.asarray(idx)
    dvals_ref = np.where(
        idx_np >= 0,
        np.take_along_axis(dwg, np.maximum(idx_np, 0).astype(np.int64), 1),
        0.0,
    )
    np.testing.assert_allclose(np.asarray(dvals), dvals_ref,
                               rtol=1e-5, atol=1e-5)
    # Dead slots never receive gradient.
    assert (np.asarray(dvals)[idx_np < 0] == 0.0).all()


def test_context_routes_proj_and_restores():
    assert current_sparse_grad() is None
    with sparse_grad_context("2:4", 0) as ctx:
        assert current_sparse_grad() is ctx
        s0 = ctx.call_key()
        s1 = ctx.call_key()
        assert s0[1] == 0 and s1[1] == 1       # fresh salt per call site
        with sparse_grad_layer(3):
            assert int(ctx.call_key()[0]) != int(s0[0])
        assert ctx.layer is None               # restored
    assert current_sparse_grad() is None
    with sparse_grad_layer(5):                 # no-op when inactive
        assert current_sparse_grad() is None


# ---------------------------------------------------------------------------
# Train-step integration.
# ---------------------------------------------------------------------------


def _run_steps(sp, scfg, steps=3, accum=1, seed=0):
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
    state = make_train_state(CFG, opt, jax.random.PRNGKey(9), params=sp)
    step = build_train_step(CFG, opt, step_cfg=scfg, donate=False)
    losses = []
    for i in range(steps):
        state, metrics = step(state, _batch(seed + i))
        losses.append(float(metrics["loss"]))
    return state, losses


def test_grad_sparsity_off_is_bit_identical_multi_step():
    sp = _sparse_model(PatternSpec(2, 4, transposable=True))
    base_state, base_losses = _run_steps(
        sp, StepConfig(mask_mode="compressed"))
    off_state, off_losses = _run_steps(
        sp, StepConfig(mask_mode="compressed", grad_sparsity="off"))
    assert base_losses == off_losses
    assert tree_equal(base_state.params, off_state.params)


def test_sparse_grad_step_deterministic_and_differs_from_exact():
    sp = _sparse_model(PatternSpec(2, 4, transposable=True))
    scfg = StepConfig(mask_mode="compressed", grad_sparsity="2:4")
    a_state, a_losses = _run_steps(sp, scfg, steps=2)
    b_state, b_losses = _run_steps(sp, scfg, steps=2)
    assert a_losses == b_losses and np.isfinite(a_losses).all()
    assert tree_equal(a_state.params, b_state.params)
    off_state, off_losses = _run_steps(
        sp, StepConfig(mask_mode="compressed"), steps=2)
    # First forward is identical (sparsification is backward-only)...
    assert a_losses[0] == off_losses[0]
    # ...but the params diverge through the sparsified gradients.
    assert not tree_equal(a_state.params, off_state.params)


def test_sparse_grad_step_with_accumulation():
    sp = _sparse_model(PatternSpec(2, 4, transposable=True))
    scfg = StepConfig(mask_mode="compressed", grad_sparsity="2:4", accum=2)
    a_state, a_losses = _run_steps(sp, scfg, steps=2)
    b_state, b_losses = _run_steps(sp, scfg, steps=2)
    assert a_losses == b_losses and np.isfinite(a_losses).all()
    assert tree_equal(a_state.params, b_state.params)


def test_grad_sparsity_requires_compressed_mode():
    opt = AdamW(learning_rate=1e-3)
    with pytest.raises(ValueError, match="compressed"):
        build_train_step(CFG, opt,
                         step_cfg=StepConfig(grad_sparsity="2:4"))


# ---------------------------------------------------------------------------
# Satellite surfaces: MoE expert einsums, Mamba projections, stacked leaves.
# ---------------------------------------------------------------------------

MOE_CFG = ModelConfig("sg-moe", "moe", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=96, vocab_size=128, num_experts=4,
                      top_k=2, moe_group=1, remat="none", dtype="float32")
SSM_CFG = ModelConfig("sg-ssm", "ssm", num_layers=2, d_model=64, num_heads=0,
                      num_kv_heads=0, d_ff=0, vocab_size=128, ssm_state=16,
                      ssm_head_dim=16, ssm_chunk=4, remat="none",
                      dtype="float32")


@pytest.mark.parametrize("cfg", [MOE_CFG, SSM_CFG], ids=lambda c: c.name)
def test_compressed_dispatch_bit_identical_on_arch(cfg):
    spec = PatternSpec(2, 4, transposable=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    masks = sparsify_pytree(params, spec, config=SolverConfig(iters=30),
                            prunable=projection_prunable)
    pruned = apply_mask(params, masks)
    sp = compress_params(pruned, masks, spec)
    n_comp = sum(isinstance(leaf, NMCompressed) for leaf in jax.tree.leaves(
        sp, is_leaf=lambda x: isinstance(x, NMCompressed)))
    assert n_comp >= 1, "no projection was compressed on this arch"
    batch = _batch(0, vocab=cfg.vocab_size)
    dense_loss = lm.loss_fn(pruned, cfg, batch)
    comp_loss = lm.loss_fn(sp, cfg, batch)
    # Tiny dims fit a single K tile: compressed == masked-dense bitwise.
    assert float(dense_loss) == float(comp_loss)


@pytest.mark.parametrize("cfg", [MOE_CFG, SSM_CFG], ids=lambda c: c.name)
def test_sparse_grad_step_runs_on_arch(cfg):
    spec = PatternSpec(2, 4, transposable=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    masks = sparsify_pytree(params, spec, config=SolverConfig(iters=30),
                            prunable=projection_prunable)
    sp = compress_params(apply_mask(params, masks), masks, spec)
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
    state = make_train_state(cfg, opt, jax.random.PRNGKey(1), params=sp)
    step = build_train_step(
        cfg, opt,
        step_cfg=StepConfig(mask_mode="compressed", grad_sparsity="2:4"),
        donate=False,
    )
    state, metrics = step(state, _batch(0, vocab=cfg.vocab_size))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("lead", [(3,), (2, 2)])
def test_stacked_leaf_compress_roundtrip(lead):
    # Expert-stacked (and deeper) projection leaves: masks, compression and
    # decompression all flatten the leading dims per-matrix.
    spec = PatternSpec(2, 4, transposable=True)
    rng = np.random.default_rng(8)
    tree = {"wq": jnp.asarray(rng.normal(size=(*lead, 32, 48)), jnp.float32)}
    masks = sparsify_pytree(tree, spec, config=SolverConfig(iters=30),
                            prunable=projection_prunable)
    pruned = apply_mask(tree, masks)
    sp = compress_params(pruned, masks, spec)
    leaf = sp["wq"]
    assert isinstance(leaf, NMCompressed)
    assert leaf.values.shape[: len(lead)] == lead
    np.testing.assert_array_equal(
        np.asarray(leaf.decompress()), np.asarray(pruned["wq"])
    )
