"""Distribution substrate tests — run in subprocesses with fake devices
(the device count is locked at first jax init, so each case gets its own
process)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.models.config import ModelConfig
        from repro.models import specs
        from repro.optim import AdamW
        from repro.train import build_train_step, make_train_state
        from repro.data import SyntheticLM
        from repro.distributed.sharding import set_mesh

        cfg = ModelConfig("t","dense",num_layers=2,d_model=64,num_heads=4,
                          num_kv_heads=2,d_ff=128,vocab_size=64,remat="none",
                          dtype="float32")
        opt = AdamW(learning_rate=1e-3)
        data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=8)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

        # single device
        s0 = make_train_state(cfg, opt, jax.random.PRNGKey(0))
        st0, m0 = build_train_step(cfg, opt, donate=False)(s0, batch)

        # 2x4 mesh with param sharding
        mesh = make_mesh((2, 4), ("data", "model"))
        set_mesh(mesh)
        s1 = make_train_state(cfg, opt, jax.random.PRNGKey(0))
        pspecs = specs.fit_param_specs(cfg, jax.eval_shape(lambda: s1.params), mesh)
        sh = specs.shardings_of(pspecs, mesh)
        s1 = s1._replace(params=jax.tree.map(jax.device_put, s1.params, sh))
        st1, m1 = build_train_step(cfg, opt, donate=False)(s1, batch)
        print("LOSS", float(m0["loss"]), float(m1["loss"]))
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(st0.params), jax.tree.leaves(st1.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_close_to_exact():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import set_mesh_context
        from repro.launch.mesh import make_mesh
        from repro.models.config import ModelConfig
        from repro.optim import AdamW
        from repro.train import build_train_step, make_train_state
        from repro.train.step import StepConfig
        from repro.data import SyntheticLM

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = ModelConfig("t","dense",num_layers=2,d_model=64,num_heads=4,
                          num_kv_heads=2,d_ff=128,vocab_size=64,remat="none",
                          dtype="float32")
        opt = AdamW(learning_rate=1e-3)
        data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=8)
        b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        sc = make_train_state(cfg, opt, jax.random.PRNGKey(0), compression=True)
        sn = make_train_state(cfg, opt, jax.random.PRNGKey(0))
        with set_mesh_context(mesh):
            stc, mc = build_train_step(cfg, opt, step_cfg=StepConfig(compression=True), mesh=mesh)(sc, b)
            stn, mn = build_train_step(cfg, opt)(sn, b)
        d = max(float(jnp.max(jnp.abs(a - b2)))
                for a, b2 in zip(jax.tree.leaves(stc.params), jax.tree.leaves(stn.params)))
        print("MAXDIFF", d)
        assert d < 5e-3
        # error feedback buffers are populated
        efn = sum(float(jnp.sum(jnp.abs(e))) for e in jax.tree.leaves(stc.ef))
        assert efn > 0
        print("OK")
    """)
    assert "OK" in out


def test_elastic_reshard_on_load():
    """Checkpoint saved from a 4-device mesh restores onto a 2-device mesh."""
    out = run_py("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.models.config import ModelConfig
        from repro.models import specs
        from repro.optim import AdamW
        from repro.train import make_train_state
        from repro.checkpoint import CheckpointManager

        cfg = ModelConfig("t","dense",num_layers=2,d_model=64,num_heads=4,
                          num_kv_heads=2,d_ff=128,vocab_size=64,remat="none",
                          dtype="float32")
        opt = AdamW(learning_rate=1e-3)
        state = make_train_state(cfg, opt, jax.random.PRNGKey(0))
        mesh_a = make_mesh((2, 2), ("data", "model"))
        pspecs = specs.fit_param_specs(cfg, jax.eval_shape(lambda: state.params), mesh_a)
        sh_a = specs.shardings_of(pspecs, mesh_a)
        state = state._replace(params=jax.tree.map(jax.device_put, state.params, sh_a))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(5, state)
            # "lose half the slice": restore onto a 1x2 mesh
            mesh_b = make_mesh((1, 2), ("data", "model"))
            pspecs_b = specs.fit_param_specs(cfg, jax.eval_shape(lambda: state.params), mesh_b)
            sh_b = specs.shardings_of(pspecs_b, mesh_b)
            tpl_shardings = state._replace(params=sh_b, opt_state=state.opt_state._replace(
                mu=sh_b, nu=sh_b, step=None), step=None, ef=None)
            restored = mgr.restore(5, state, tpl_shardings)
            w = restored.params["blocks"]["attn"]["wq"]
            assert len(w.sharding.device_set) == 2
            np.testing.assert_array_equal(np.asarray(w), np.asarray(state.params["blocks"]["attn"]["wq"]))
        print("OK")
    """, devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """End-to-end dry-run machinery on an 8-device mesh with a smoke arch."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import input_specs, roofline_terms
        from repro.launch.hlo_analysis import analyze_compiled
        from repro.configs.registry import get_smoke_config
        from repro.models.config import ShapeConfig
        from repro.distributed.sharding import set_mesh
        import dataclasses

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        set_mesh(mesh)
        cfg = dataclasses.replace(get_smoke_config("granite_8b"), remat="full")
        shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")
        fn, args = input_specs(cfg, shape, mesh, sparse=True, accum=2)
        compiled = fn.lower(*args).compile()
        a = analyze_compiled(compiled)
        assert a["dot_flops"] > 0 and a["collective_bytes"] > 0, a
        terms = roofline_terms(a, 8)
        assert terms["compute_s"] > 0
        print("OK", a["dot_flops"], a["collective_bytes"])
    """, devices=8)
    assert "OK" in out
