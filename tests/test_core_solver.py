"""Core TSENOR solver: correctness vs exact oracles + invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip cleanly; the rest of the module runs
    def given(**kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(**kwargs):
        return lambda f: f

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        sampled_from = staticmethod(lambda *a, **k: None)
        integers = staticmethod(lambda *a, **k: None)

from repro.core import (
    PatternSpec,
    SolverConfig,
    dykstra_log,
    greedy_round,
    is_transposable_nm,
    local_search,
    nm_mask,
    objective,
    simple_round,
    solve_blocks,
    solve_mask,
)
from repro.core.baselines import bi_nm, max_k_random, two_approx
from repro.core.exact import brute_force, lp_exact

RNG = np.random.default_rng(0)


def rand_blocks(b, m, seed=0):
    return jnp.asarray(
        np.abs(np.random.default_rng(seed).normal(size=(b, m, m))).astype(np.float32)
    )


# ---------------------------------------------------------------------------
# Exactness / quality.
# ---------------------------------------------------------------------------


def test_matches_brute_force_m4():
    for seed in range(10):
        w = np.abs(np.random.default_rng(seed).normal(size=(4, 4)))
        _, opt = brute_force(w, 2)
        mask = solve_blocks(jnp.asarray(w)[None], 2)[0]
        got = float(objective(mask, w))
        assert got >= opt - 1e-5, (seed, got, opt)


def test_lp_equals_brute_force():
    for seed in range(5):
        w = np.abs(np.random.default_rng(seed).normal(size=(4, 4)))
        _, v1 = brute_force(w, 2)
        _, v2 = lp_exact(w, 2)
        assert abs(v1 - v2) < 1e-8


@pytest.mark.parametrize("m,n", [(8, 4), (16, 8), (16, 4), (32, 16)])
def test_quality_vs_baselines(m, n):
    w = rand_blocks(6, m, seed=m * 31 + n)
    ts = solve_blocks(w, n, SolverConfig(iters=150))
    b2 = two_approx(w, n)
    bb = bi_nm(w, n)
    f = lambda mk: float(jnp.sum(jnp.where(mk, w, 0)))
    assert f(ts) >= f(b2) - 1e-4   # entropy+rounding >= plain greedy
    assert f(ts) >= f(bb) - 1e-4


def test_relative_error_band_vs_exact():
    """Paper Fig. 3: TSENOR within a few % of optimal for 16:32-ish blocks."""
    m, n = 16, 8
    w = np.abs(np.random.default_rng(7).normal(size=(8, m, m))).astype(np.float32)
    masks = solve_blocks(jnp.asarray(w), n)
    opts = [lp_exact(b, n)[1] for b in w]
    rel = [
        (opt - float(objective(masks[i], w[i]))) / opt for i, opt in enumerate(opts)
    ]
    assert np.mean(rel) < 0.02, rel  # paper reports 1-10%; we land ~0.2-2%


# ---------------------------------------------------------------------------
# Feasibility / invariants (hypothesis property tests).
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    mn=st.sampled_from([(4, 2), (8, 4), (8, 2), (16, 8), (16, 4)]),
    b=st.integers(1, 5),
    seed=st.integers(0, 2**16),
)
def test_solver_feasibility_property(mn, b, seed):
    m, n = mn
    w = rand_blocks(b, m, seed)
    mask = np.array(solve_blocks(w, n, SolverConfig(iters=60)))
    rs, cs = mask.sum(2), mask.sum(1)
    assert (rs <= n).all() and (cs <= n).all()
    # The solver saturates on generic (distinct-entry) inputs.
    assert (rs == n).all() and (cs == n).all()


@settings(max_examples=10, deadline=None)
@given(
    mn=st.sampled_from([(8, 4), (16, 8)]),
    seed=st.integers(0, 2**16),
)
def test_local_search_never_decreases_objective(mn, seed):
    m, n = mn
    w = rand_blocks(4, m, seed)
    g = greedy_round(w, n)
    ls = local_search(g, w, n, steps=8)
    fg = float(jnp.sum(jnp.where(g, w, 0)))
    fl = float(jnp.sum(jnp.where(ls, w, 0)))
    assert fl >= fg - 1e-5
    mask = np.array(ls)
    assert (mask.sum(1) <= n).all() and (mask.sum(2) <= n).all()


@settings(max_examples=10, deadline=None)
@given(
    mn=st.sampled_from([(8, 4), (16, 8)]),
    seed=st.integers(0, 2**16),
)
def test_dykstra_marginals_property(mn, seed):
    """Iterates stay in [0,1]; marginals approach N (the final iterate comes
    from the capacity projection, so sums are only asymptotically exact —
    the paper's Alg. 1 has the same property)."""
    m, n = mn
    w = rand_blocks(3, m, seed)
    s = np.array(dykstra_log(w, n, iters=300))
    assert (s >= -1e-6).all() and (s <= 1 + 1e-4).all()
    np.testing.assert_allclose(s.sum(2), n, rtol=0.25)
    np.testing.assert_allclose(s.sum(1), n, rtol=0.25)
    # More iterations never move the column marginals further from N.
    s2 = np.array(dykstra_log(w, n, iters=600))
    err1 = np.abs(s.sum(1) - n).mean()
    err2 = np.abs(s2.sum(1) - n).mean()
    assert err2 <= err1 + 1e-3


def test_transposable_matrix_level():
    w = np.random.default_rng(1).normal(size=(64, 48)).astype(np.float32)
    mask = solve_mask(jnp.asarray(w), PatternSpec(4, 8))
    assert mask.shape == w.shape
    assert is_transposable_nm(np.array(mask), 4, 8)
    # transposed view is N:M sparse too — the whole point
    assert is_transposable_nm(np.array(mask).T, 4, 8)


def test_padding_path():
    w = np.random.default_rng(2).normal(size=(20, 12)).astype(np.float32)
    mask = solve_mask(jnp.asarray(w), PatternSpec(2, 8))
    assert mask.shape == (20, 12)


def test_nm_mask_standard():
    w = np.random.default_rng(3).normal(size=(32, 16)).astype(np.float32)
    mask = np.array(nm_mask(jnp.asarray(w), 2, 4, axis=0))
    g = mask.reshape(8, 4, 16)
    assert (g.sum(1) == 2).all()


def test_simple_round_feasible():
    w = rand_blocks(4, 8, seed=5)
    s = dykstra_log(w, 4, iters=100)
    mask = np.array(simple_round(s, 4))
    assert (mask.sum(1) <= 4).all() and (mask.sum(2) <= 4).all()


def test_baselines_feasible():
    w = rand_blocks(4, 16, seed=6)
    for mk in (
        two_approx(w, 8),
        bi_nm(w, 8),
        max_k_random(jax.random.PRNGKey(0), w, 8, k=64),
    ):
        mk = np.array(mk)
        assert (mk.sum(1) <= 8).all() and (mk.sum(2) <= 8).all()
    mk = np.array(max_k_random(jax.random.PRNGKey(0), w, 8, k=16))
    assert (mk.sum(1) == 8).all() and (mk.sum(2) == 8).all()  # always saturated


def test_pallas_solver_path_matches_xla():
    w = rand_blocks(5, 16, seed=9)
    a = solve_blocks(w, 8, SolverConfig(iters=80, backend="dense-jit"))
    b = solve_blocks(w, 8, SolverConfig(iters=80, backend="pallas"))
    assert (np.array(a) == np.array(b)).all()
