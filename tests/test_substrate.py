"""Substrate: optimizer, data, checkpointing, fault-tolerant loop, serving."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.solver import SolverConfig, is_transposable_nm
from repro.data import SyntheticLM, calibration_batch
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.serve import ServeEngine
from repro.patterns import PatternSpec
from repro.sparsity.masks import mask_sparsity, sparsify_pytree
from repro.train import (
    TrainLoop,
    TrainLoopConfig,
    build_train_step,
    make_train_state,
)

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=64, remat="none",
                   dtype="float32")


def test_synthetic_data_deterministic_and_resumable():
    d1 = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=3)
    d2 = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=3)
    b1, b2 = d1.batch(17), d2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert (d1.batch(18)["tokens"] != b1["tokens"]).any()
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 64
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    cb = calibration_batch(64, 16, 4)
    assert cb.shape == (4, 16)


def test_adamw_converges_on_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) <= 0.11
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)


def test_grad_accumulation_matches_full_batch():
    from repro.train.step import StepConfig

    opt = AdamW(learning_rate=1e-2)
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    s1 = make_train_state(TINY, opt, jax.random.PRNGKey(0))
    s2 = make_train_state(TINY, opt, jax.random.PRNGKey(0))
    st1, m1 = build_train_step(TINY, opt, step_cfg=StepConfig(accum=1))(s1, batch)
    st2, m2 = build_train_step(TINY, opt, step_cfg=StepConfig(accum=4))(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-3, atol=1e-4)


class TestCheckpoint:
    def test_roundtrip(self):
        opt = AdamW(learning_rate=1e-3)
        state = make_train_state(TINY, opt, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep_n=2, async_save=False)
            mgr.save(7, state, {"note": "x"})
            assert mgr.latest_step() == 7
            restored = mgr.restore(7, state)
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.array(a), np.array(b))
            assert mgr.metadata(7)["user"]["note"] == "x"

    def test_keep_n_retention_and_atomicity(self):
        opt = AdamW(learning_rate=1e-3)
        state = make_train_state(TINY, opt, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep_n=2, async_save=False)
            for s in (1, 2, 3, 4):
                mgr.save(s, state)
            assert mgr.all_steps() == [3, 4]
            # a stale tmp dir must never be listed as a checkpoint
            os.makedirs(os.path.join(d, "step_0000000009.tmp"))
            assert mgr.latest_step() == 4

    def test_restore_casts_dtype(self):
        opt = AdamW(learning_rate=1e-3)
        state = make_train_state(TINY, opt, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(1, state)
            tpl = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.bfloat16)
                if x.dtype == jnp.float32 else x,
                state,
            )
            restored = mgr.restore(1, tpl)
            assert jax.tree.leaves(restored.params)[0].dtype == jnp.bfloat16


class TestFaultTolerance:
    def test_failure_injection_and_resume(self):
        opt = AdamW(learning_rate=3e-3)
        data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4)
        step_fn = build_train_step(TINY, opt)
        with tempfile.TemporaryDirectory() as d:
            ckpt = CheckpointManager(d, keep_n=3)
            crashed = {}

            def inject(step):
                if step == 13 and not crashed:
                    crashed["x"] = True
                    raise RuntimeError("node failure")

            loop = TrainLoop(step_fn, data, ckpt,
                             TrainLoopConfig(total_steps=20, ckpt_every=5,
                                             log_every=100),
                             failure_injector=inject, log_fn=lambda s: None)
            state = make_train_state(TINY, opt, jax.random.PRNGKey(0))
            with pytest.raises(RuntimeError):
                loop.run(state)
            # Emergency checkpoint was written at the crash step.
            assert 13 in ckpt.all_steps()
            # Fresh process restarts and resumes exactly at step 13.
            loop2 = TrainLoop(step_fn, data, ckpt,
                              TrainLoopConfig(total_steps=20, ckpt_every=5,
                                              log_every=100),
                              log_fn=lambda s: None)
            state2 = make_train_state(TINY, opt, jax.random.PRNGKey(0))
            state2, hist = loop2.run(state2)
            assert hist[0]["step"] == 14
            assert int(state2.step) == 20

    def test_loss_decreases(self):
        opt = AdamW(learning_rate=3e-3)
        data = SyntheticLM(vocab_size=64, seq_len=32, global_batch=8)
        step_fn = build_train_step(TINY, opt)
        state = make_train_state(TINY, opt, jax.random.PRNGKey(0))
        loop = TrainLoop(step_fn, data, None,
                         TrainLoopConfig(total_steps=30, log_every=100),
                         log_fn=lambda s: None)
        state, hist = loop.run(state)
        assert hist[-1]["loss"] < hist[0]["loss"]


class TestSparseFinetune:
    def test_masks_enforced_through_updates(self):
        opt = AdamW(learning_rate=1e-2)
        data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4)
        state = make_train_state(TINY, opt, jax.random.PRNGKey(0))
        masks = sparsify_pytree(state.params, PatternSpec(2, 4),
                                config=SolverConfig(iters=30))
        assert 0.4 < mask_sparsity(masks) < 0.6
        step = build_train_step(TINY, opt, masks=masks)
        for i in range(3):
            state, _ = step(state, {k: jnp.asarray(v) for k, v in data.batch(i).items()})
        wq = np.array(state.params["blocks"]["attn"]["wq"][0])
        mq = np.array(masks["blocks"]["attn"]["wq"][0])
        assert (wq[~mq] == 0).all()  # support never drifts
        assert is_transposable_nm(mq, 2, 4)


def test_serve_engine_generates():
    cfg = TINY
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    out = eng.generate(prompts, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert int(out.min()) >= 0 and int(out.max()) < 64
    # greedy decoding is deterministic
    out2 = eng.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(np.array(out), np.array(out2))


def test_prefetcher_matches_source_and_resumes():
    from repro.data.pipeline import Prefetcher

    src = SyntheticLM(vocab_size=64, seq_len=8, global_batch=2, seed=9)
    pf = Prefetcher(src, start_step=0, prefetch=2)
    try:
        for step in (0, 1, 2):
            got = pf.batch(step)
            np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                          src.batch(step)["tokens"])
        # resume from an arbitrary (earlier) step still works
        got = pf.batch(1)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      src.batch(1)["tokens"])
    finally:
        pf.close()
