"""HLO cost analyzer + sharding-spec unit tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_module


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_scaling_exact():
    L, D, B = 7, 64, 16

    def f(x, ws):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    hlo = _compile_text(
        f,
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    )
    r = analyze_hlo(hlo)
    assert r["dot_flops"] == pytest.approx(2 * B * D * D * L)
    assert r["max_trip"] == L


def test_nested_scan_trip_scaling():
    L, D, B, A = 5, 32, 8, 3

    def f(xs, ws):
        def micro(acc, xb):
            h, _ = jax.lax.scan(lambda h, w: (h @ w, None), xb, ws)
            return acc + h.sum(), None
        out, _ = jax.lax.scan(micro, 0.0, xs)
        return out

    hlo = _compile_text(
        f,
        jax.ShapeDtypeStruct((A, B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    )
    r = analyze_hlo(hlo)
    assert r["dot_flops"] == pytest.approx(2 * B * D * D * L * A)


def test_unscanned_dot_exact():
    def f(a, b):
        return a @ b

    hlo = _compile_text(
        f,
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 64), jnp.float32),
    )
    r = analyze_hlo(hlo)
    assert r["dot_flops"] == pytest.approx(2 * 128 * 256 * 64)
    # HBM traffic at least the operands + output once.
    assert r["hbm_bytes"] >= (128 * 256 + 256 * 64 + 128 * 64) * 4


def test_parse_module_finds_entry():
    hlo = _compile_text(lambda x: x * 2 + 1, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps, entry = parse_module(hlo)
    assert entry is not None and entry in comps


class TestSpecs:
    def _mesh(self, shape=(2, 2), axes=("data", "model")):
        # AbstractMesh: spec fitting needs only axis names/sizes, so these
        # tests run on the 1-CPU-device container.
        from repro.compat import abstract_mesh

        return abstract_mesh(shape, axes)

    def test_param_specs_2d_sharding(self):
        from repro.configs.registry import get_smoke_config
        from repro.models import lm, specs

        cfg = get_smoke_config("granite_8b")
        mesh = self._mesh()
        shape = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
        ps = specs.fit_param_specs(cfg, shape, mesh)
        wq = ps["blocks"]["attn"]["wq"]
        assert tuple(wq) == (None, "data", "model")
        assert tuple(ps["embed"]) == ("model", "data")

    def test_moe_fallback_when_experts_dont_divide(self):
        from repro.configs.registry import get_smoke_config
        from repro.models import lm, specs

        cfg = get_smoke_config("mixtral_8x22b")  # 4 experts in smoke
        mesh = self._mesh((1, 8), ("data", "model"))  # 4 % 8 != 0
        shape = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
        ps = specs.fit_param_specs(cfg, shape, mesh)
        gate = tuple(ps["blocks"]["moe"]["gate"])
        assert gate[1] != "model"  # experts axis NOT on model
        assert "model" in gate  # but the matrices are still TP-sharded

    def test_pure_dp_drops_model_from_params(self):
        from repro.configs.registry import get_smoke_config
        from repro.models import lm, specs

        cfg = get_smoke_config("mamba2_370m")
        mesh = self._mesh()
        shape = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
        ps = specs.fit_param_specs(cfg, shape, mesh, pure_dp=True)
        for leaf in jax.tree.leaves(
            ps, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        ):
            assert "model" not in tuple(leaf), leaf
        assert "model" in specs.batch_axes(mesh, pure_dp=True)

    def test_cache_specs_seq_fallback(self):
        """kv=2 heads on a 4-wide model axis -> cache seq takes 'model'."""
        from repro.models import lm, specs
        from repro.models.config import ModelConfig

        cfg = ModelConfig("t", "dense", num_layers=2, d_model=32, num_heads=4,
                          num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
                          remat="none")
        mesh = self._mesh((2, 4), ("data", "model"))
        caches = jax.eval_shape(lambda: lm.init_cache(cfg, 8, 64))
        cs = specs.cache_specs(cfg, caches, mesh)
        k_spec = tuple(cs[0].k)
        assert k_spec[1] == "model"  # seq dim
        assert k_spec[2] is None     # kv heads not shardable


def test_shard_unconstrained_for_nondividing_dims():
    from jax.sharding import PartitionSpec as P
    from repro.compat import abstract_mesh
    from repro.distributed.sharding import _fit_spec_to_shape

    mesh = abstract_mesh((2, 4), ("data", "model"))
    spec = _fit_spec_to_shape(P("data", "model"), (8, 10), mesh)
    assert spec[0] == "data"
    assert spec[1] is P.UNCONSTRAINED  # 10 % 4 != 0 -> let XLA choose
