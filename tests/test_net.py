"""repro.service.net: wire codec, MaskServer scheduling, MaskClient drop-in.

The PR contract: a ``MaskClient`` pointed at a live ``MaskServer`` is a
drop-in for ``MaskService`` everywhere the repo consumes the service seam —
``prune_transformer(service=...)``, the solve-plan lockstep driver, the DST
refresh controller — and the masks that come back are *bit-identical* to an
in-process solve under the same SolverConfig.  Multi-tenant behavior
(weighted scheduling, shared cache tier, rate limits) is covered white-box
here and under load in ``benchmarks/service_load.py``.
"""
import socket
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec
from repro.service import BucketPolicy, MaskService
from repro.service.net import (
    MaskClient,
    MaskServer,
    RemoteError,
    TenantConfig,
    TokenBucket,
    WireError,
    wire,
)
from repro.service.net.server import _Request, _Tenant

FAST = SolverConfig(iters=60)
TINY = BucketPolicy(base=8, growth=2, max_bucket=32)


# ---------------------------------------------------------------------------
# Wire codec.
# ---------------------------------------------------------------------------


def _sock_pair():
    a, b = socket.socketpair()
    return a, b


def test_frame_round_trip_header_and_blobs():
    a, b = _sock_pair()
    blobs = [
        np.arange(12, dtype=np.float32).reshape(3, 4),
        np.array([[7, 9]], dtype=np.uint32),
        np.zeros((0, 8), np.float32),  # empty blob survives
    ]
    wire.send_frame(a, {"op": "submit", "reqs": [{"id": "x"}]}, blobs)
    header, got = wire.recv_frame(b)
    assert header == {"op": "submit", "reqs": [{"id": "x"}]}  # blobs key eaten
    assert len(got) == 3
    for want, have in zip(blobs, got):
        assert have.dtype == want.dtype and have.shape == want.shape
        np.testing.assert_array_equal(have, want)
    a.close()
    assert wire.recv_frame(b) is None  # clean EOF at frame boundary
    b.close()


def test_frame_errors_fail_loudly():
    a, b = _sock_pair()
    a.sendall(b"\xff\xff\xff\xff")  # length prefix past MAX_FRAME
    with pytest.raises(WireError):
        wire.recv_frame(b)
    a.close()
    b.close()

    a, b = _sock_pair()
    wire.send_frame(a, {"op": "ping"})
    payload = b.recv(1 << 16)
    a.close()
    b.close()
    a, b = _sock_pair()
    a.sendall(payload[: len(payload) - 2])  # truncated mid-frame
    a.close()
    with pytest.raises(WireError):
        wire.recv_frame(b)
    b.close()


def test_frame_rejects_non_object_header():
    a, b = _sock_pair()
    import json
    import struct

    hbytes = json.dumps([1, 2]).encode()
    payload = struct.pack(">I", len(hbytes)) + hbytes
    a.sendall(struct.pack(">I", len(payload)) + payload)
    with pytest.raises(WireError):
        wire.recv_frame(b)
    a.close()
    b.close()


# ---------------------------------------------------------------------------
# Server + client round trips (one live server per module).
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def server():
    srv = MaskServer(
        MaskService(FAST, policy=TINY),
        batch_window_s=0.001,
        tenants={"limited": TenantConfig(quota=1.0, rate=200.0, burst=8.0)},
    )
    with srv:
        yield srv


@pytest.fixture()
def client(server):
    with MaskClient(server.address, tenant="tests") as c:
        yield c


def test_hello_advertises_solver_config(client):
    assert client.config == FAST
    assert client.server_name and client.quota == 1.0
    assert client.ping()


def test_remote_solve_bit_identical_mixed_shapes(client):
    """The acceptance gate: remote masks == in-process masks at tol=0,
    across shapes that pad, stack, and span buckets."""
    local = MaskService(FAST, policy=TINY)
    rng = np.random.default_rng(0)
    tensors = {
        "big": rng.normal(size=(64, 48)).astype(np.float32),
        "pad_both": rng.normal(size=(20, 12)).astype(np.float32),
        "stacked": rng.normal(size=(3, 16, 16)).astype(np.float32),
        "tiny": rng.normal(size=(4, 4)).astype(np.float32),
    }
    for spec in (PatternSpec(4, 8), PatternSpec(2, 4)):
        handles = {k: client.submit(f"{spec.n}:{k}", v, spec)
                   for k, v in tensors.items()}
        client.flush()
        for k, v in tensors.items():
            want = np.array(local.solve(v, spec))
            got = np.array(handles[k].result())
            assert got.shape == v.shape
            np.testing.assert_array_equal(got, want), (spec, k)
            assert handles[k].server_latency_s is not None


def test_client_local_cache_and_dedup(server):
    rng = np.random.default_rng(1)
    w = rng.normal(size=(16, 16)).astype(np.float32)
    with MaskClient(server.address, tenant="tests-dedup") as c:
        h1 = c.submit("a", w, PatternSpec(4, 8))
        h2 = c.submit("b", w, PatternSpec(4, 8))  # identical, in flight
        assert c.stats.dedup_hits == 1
        c.flush()
        np.testing.assert_array_equal(np.array(h1.result()),
                                      np.array(h2.result()))
        h3 = c.submit("c", w, PatternSpec(4, 8))  # identical, post-flush
        assert h3.done and c.stats.cache_hits == 1  # never hit the wire
        assert c.stats.submitted == 3


def test_submit_many_and_results(client):
    rng = np.random.default_rng(2)
    items = [(f"t{i}", rng.normal(size=(8, 8)).astype(np.float32))
             for i in range(4)]
    handles = client.submit_many(items, PatternSpec(4, 8))
    masks = client.results(handles)
    local = MaskService(FAST, policy=TINY)
    for (name, w), mask in zip(items, masks):
        np.testing.assert_array_equal(np.array(mask),
                                      np.array(local.solve(w, "t4:8")))


def test_flush_async_ticket(client):
    rng = np.random.default_rng(3)
    h = client.submit("async", rng.normal(size=(16, 8)).astype(np.float32),
                      PatternSpec(4, 8))
    ticket = client.flush_async()
    assert ticket.wait(timeout=120)
    assert h.done


def test_results_rejects_foreign_handles(server, client):
    local = MaskService(FAST, policy=TINY)
    h = local.submit("w", np.ones((8, 8), np.float32), PatternSpec(4, 8))
    with pytest.raises(ValueError, match="different MaskService"):
        client.results([h])


def test_non_transposable_pattern_rejected_client_side(client):
    with pytest.raises(ValueError, match="transposable"):
        client.submit("w", np.ones((8, 8), np.float32), PatternSpec(4, 8, False))


def test_two_tenants_share_the_cache_tier(server):
    """Tenant B's first submit of content tenant A already solved is a
    server-side cache hit — the shared-tier guarantee of the issue."""
    rng = np.random.default_rng(4)
    w = rng.normal(size=(24, 16)).astype(np.float32)
    with MaskClient(server.address, tenant="share-a") as ca:
        ma = np.array(ca.solve(w, "t4:8"))
    with MaskClient(server.address, tenant="share-b") as cb:
        h = cb.submit("same-content", w, PatternSpec(4, 8))
        cb.flush()
        np.testing.assert_array_equal(np.array(h.result()), ma)
        assert h.server_cached is True
        rows = cb.server_stats()["tenants"]
        assert rows["share-b"]["cache_hits"] == 1
        assert rows["share-a"]["cache_hits"] == 0


def test_server_stats_snapshot(client):
    client.solve(np.random.default_rng(5).normal(size=(8, 8))
                 .astype(np.float32), "t4:8")
    stats = client.server_stats()
    assert stats["service"]["blocks_solved"] >= 1
    assert stats["rounds"] >= 1
    assert "tests" in stats["tenants"]


def test_rate_limited_tenant_backpressures(server):
    """A tenant over its blocks/sec budget blocks in submit (token bucket)
    rather than flooding the queue."""
    rng = np.random.default_rng(6)
    with MaskClient(server.address, tenant="limited") as c:
        # burst=8 funds the first submits; rate=200 blocks/s meters refills.
        t0 = time.monotonic()
        for i in range(3):
            w = rng.normal(size=(16, 32)).astype(np.float32)  # 8 blocks @ M=8
            c.submit(f"r{i}", w, PatternSpec(4, 8))
        elapsed = time.monotonic() - t0
        c.flush()
    # 24 blocks at 200 blocks/s with an 8-block burst: >= ~0.04s of
    # enforced waiting (generous floor to stay timing-robust).
    assert elapsed > 0.03


def test_token_bucket_unit():
    tb = TokenBucket(rate=1000.0, burst=10.0)
    assert tb.acquire(10.0)  # burst funds it instantly
    t0 = time.monotonic()
    assert tb.acquire(5.0)  # must wait ~5ms for refill
    assert time.monotonic() - t0 < 1.0
    assert not tb.acquire(5.0, timeout=0.0)  # empty bucket + no wait
    big = TokenBucket(rate=1e6, burst=4.0)
    assert big.acquire(100.0)  # > burst: admitted via debt, not deadlock
    assert big._tokens < 0


def test_tenant_config_validation():
    with pytest.raises(ValueError, match="quota"):
        TenantConfig(quota=0)
    with pytest.raises(ValueError, match="rate"):
        TenantConfig(rate=-1)


def test_strict_tenants_reject_unknown():
    with MaskServer(MaskService(FAST, policy=TINY),
                    tenants={"known": TenantConfig()},
                    strict_tenants=True) as srv:
        with pytest.raises(RemoteError, match="unknown tenant"):
            MaskClient(srv.address, tenant="stranger")
        c = MaskClient(srv.address, tenant="known")
        assert c.ping()
        c.close()


def test_raw_protocol_errors(server):
    """Ops before hello, unknown ops, and bad submits get error replies —
    the connection survives (strict request/response framing)."""
    sock = socket.create_connection((server.host, server.port), timeout=10)
    try:
        reply, _ = wire.request(sock, {"op": "submit", "reqs": []})
        assert not reply["ok"] and "hello" in reply["error"]
        reply, _ = wire.request(sock, {"op": "nope"})
        assert not reply["ok"]
        reply, _ = wire.request(
            sock, {"op": "hello", "proto": wire.PROTO_VERSION, "tenant": "raw"}
        )
        assert reply["ok"]
        # wrong blob shape for the declared pattern
        reply, _ = wire.request(
            sock,
            {"op": "submit",
             "reqs": [{"id": "1", "name": "w", "pattern": "t4:8"}]},
            [np.zeros((2, 4, 4), np.float32)],
        )
        assert not reply["ok"] and "block" in reply["error"]
        # waiting on an id that was never submitted
        reply, _ = wire.request(sock, {"op": "wait", "ids": ["ghost"]})
        assert not reply["ok"] and "unknown request ids" in reply["error"]
        # protocol version mismatch is rejected at hello
        reply, _ = wire.request(sock, {"op": "hello", "proto": 999,
                                       "tenant": "raw"})
        assert not reply["ok"] and "protocol mismatch" in reply["error"]
    finally:
        sock.close()


def test_shutdown_op_and_pending_failure():
    srv = MaskServer(MaskService(FAST, policy=TINY)).start()
    c = MaskClient(srv.address, tenant="t")
    assert c.ping()
    c.shutdown_server()
    deadline = time.monotonic() + 10
    while srv._running and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not srv._running
    c.close()


# ---------------------------------------------------------------------------
# Deficit round-robin scheduling (white-box).
# ---------------------------------------------------------------------------


def _mk_tenant(name, quota, nblocks_list, round_blocks=32):
    t = _Tenant(name, TenantConfig(quota=quota), round_blocks)
    for i, nb in enumerate(nblocks_list):
        t.queue.append(_Request(
            f"{name}-{i}", f"{name}-{i}", "t4:8", False,
            np.zeros((nb, 8, 8), np.float32), t,
        ))
    return t


def test_take_round_splits_by_quota():
    srv = MaskServer(MaskService(FAST), round_blocks=32)
    a = _mk_tenant("a", 3.0, [8] * 12)
    b = _mk_tenant("b", 1.0, [8] * 12)
    srv._tenants = {"a": a, "b": b}
    taken = srv._take_round()
    by = {"a": 0, "b": 0}
    for r in taken:
        by[r.tenant.name] += r.nblocks
    assert by["a"] == 24 and by["b"] == 8  # 3:1 quota split of 32 blocks


def test_take_round_forces_progress_on_oversized_head():
    """A request bigger than round_blocks still gets served (credit
    accrues across rounds; force-pop breaks the deadlock)."""
    srv = MaskServer(MaskService(FAST), round_blocks=8)
    a = _mk_tenant("a", 1.0, [100], round_blocks=8)
    srv._tenants = {"a": a}
    taken = srv._take_round()
    assert len(taken) == 1 and taken[0].nblocks == 100
    assert a.deficit == 0.0


def test_take_round_no_starvation_under_skew():
    """A heavy tenant flooding the queue cannot starve a light one: the
    light tenant appears in every round."""
    srv = MaskServer(MaskService(FAST), round_blocks=16)
    heavy = _mk_tenant("heavy", 1.0, [4] * 64, round_blocks=16)
    light = _mk_tenant("light", 1.0, [4] * 8, round_blocks=16)
    srv._tenants = {"heavy": heavy, "light": light}
    rounds_with_light = 0
    while light.queue:
        taken = srv._take_round()
        assert taken
        if any(r.tenant.name == "light" for r in taken):
            rounds_with_light += 1
    assert rounds_with_light >= 4  # served steadily, not in one late burst


def test_idle_tenant_does_not_bank_credit():
    srv = MaskServer(MaskService(FAST), round_blocks=32)
    a = _mk_tenant("a", 1.0, [8])
    srv._tenants = {"a": a}
    srv._take_round()
    assert a.deficit == 0.0  # drained queue resets credit


# ---------------------------------------------------------------------------
# Drop-in: the three service consumers against a live server.
# ---------------------------------------------------------------------------


def _tiny_lm():
    from repro.models.config import ModelConfig
    from repro.models import lm

    cfg = ModelConfig("net-test", "dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(7).integers(0, 64, size=(2, 16)))
    return cfg, params, tokens


def test_prune_transformer_against_live_server(server):
    """End-to-end acceptance: a full layer-wise prune through the wire,
    bit-identical to the same prune on a local service."""
    from repro.pruning.runner import prune_transformer

    cfg, params, tokens = _tiny_lm()
    kw = dict(tokens=tokens, method="wanda", pattern=PatternSpec(2, 4),
              solver=FAST)
    with MaskClient(server.address, tenant="prune-job") as c:
        pruned_r, masks_r = prune_transformer(params, cfg, service=c, **kw)
        assert c.stats.submitted > 0
    pruned_l, masks_l = prune_transformer(
        params, cfg, service=MaskService(FAST, policy=TINY), **kw)
    for a, b in zip(jax.tree.leaves(masks_r), jax.tree.leaves(masks_l)):
        np.testing.assert_array_equal(np.array(a), np.array(b))
    for a, b in zip(jax.tree.leaves(pruned_r), jax.tree.leaves(pruned_l)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_solve_plan_driver_against_live_server(server):
    """SparseGPT's lockstep solve-plan driver duck-types the service; a
    MaskClient satisfies it and reproduces the inline masks exactly."""
    from repro.pruning.calib import gram_matrix
    from repro.pruning.sparsegpt import sparsegpt_prune

    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))
    h = gram_matrix(x)
    spec = PatternSpec(4, 8)
    wi, mi = sparsegpt_prune(w, h, spec, config=FAST, solve_via="inline")
    with MaskClient(server.address, tenant="plan-job") as c:
        ws, ms = sparsegpt_prune(w, h, spec, config=FAST,
                                 solve_via="service", service=c)
        assert c.stats.submitted == w.shape[0] // spec.m
    np.testing.assert_array_equal(np.array(mi), np.array(ms))
    np.testing.assert_array_equal(np.array(wi), np.array(ws))


def test_dst_refresh_controller_against_live_server(server):
    """The async DST refresh path — submit at s-k, train on, swap at s —
    runs against a remote solver with identical swap telemetry."""
    from repro.data import SyntheticLM
    from repro.dst import MaskRefreshController, decaying_nm
    from repro.optim import AdamW
    from repro.sparsity.masks import sparsify_pytree, apply_mask
    from repro.sparsity.params import compress_params, projection_prunable
    from repro.train import build_train_step, make_train_state
    from repro.train.step import StepConfig
    from repro.models.config import ModelConfig
    from repro.models import lm

    cfg = ModelConfig("dst-net", "dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      remat="none", dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    pattern = PatternSpec(24, 32)
    masks = sparsify_pytree(params, pattern, config=FAST,
                            prunable=projection_prunable)
    sp = compress_params(apply_mask(params, masks), masks, pattern)
    sched = decaying_nm(32, 24, 16, total_steps=8, stages=3)
    with MaskClient(server.address, tenant="dst-job") as c:
        ctrl = MaskRefreshController(sched, service=c, mode="async",
                                     lookahead=2)
        opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
        state = make_train_state(cfg, opt, jax.random.PRNGKey(1), params=sp)
        step = build_train_step(
            cfg, opt,
            step_cfg=StepConfig(mask_mode="compressed", refresh=ctrl),
            donate=False)
        data = SyntheticLM(cfg.vocab_size, 16, 2)
        losses = []
        for i in range(10):
            state, m = step(state, {
                k: jnp.asarray(v) for k, v in data.batch(i).items()})
            losses.append(float(m["loss"]))
        assert len(ctrl.events) == 2
        assert [e.pattern for e in ctrl.events] == ["t20:32", "t16:32"]
        assert state.params["blocks"]["attn"]["wq"].n == 16
        assert np.isfinite(losses).all()
        tel = ctrl.telemetry()
        assert tel["refreshes"] == 2
        assert tel["service"]["submitted"] > 0


# ---------------------------------------------------------------------------
# Concurrency: many threads, one client.
# ---------------------------------------------------------------------------


def test_concurrent_client_submits_one_flush(server):
    rng = np.random.default_rng(9)
    tensors = [rng.normal(size=(16, 16)).astype(np.float32)
               for _ in range(12)]
    local = MaskService(FAST, policy=TINY)
    want = [np.array(local.solve(w, "t4:8")) for w in tensors]
    with MaskClient(server.address, tenant="threads") as c:
        handles = [None] * len(tensors)
        errors = []

        def submit(i):
            try:
                handles[i] = c.submit(f"w{i}", tensors[i], PatternSpec(4, 8))
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(len(tensors))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        c.flush()
        for h, m in zip(handles, want):
            np.testing.assert_array_equal(np.array(h.result()), m)
        assert c.stats.submitted == len(tensors)
