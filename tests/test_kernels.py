"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PatternSpec, solve_mask
from repro.core.rounding import greedy_round as greedy_ref
from repro.kernels.dykstra.kernel import dykstra_pallas
from repro.kernels.dykstra.ref import dykstra_ref
from repro.kernels.nm_spmm.kernel import nm_spmm_pallas
from repro.kernels.nm_spmm.ops import nm_linear
from repro.kernels.nm_spmm.ref import nm_spmm_ref
from repro.kernels.rounding.kernel import greedy_round_pallas
from repro.sparsity.compressed import compress_nm, decompress_nm

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# dykstra kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,m,n", [
    (3, 4, 2), (7, 8, 4), (16, 8, 2), (5, 16, 8), (9, 16, 4), (4, 32, 16),
])
def test_dykstra_kernel_matches_ref(b, m, n):
    w = np.abs(RNG.normal(size=(b, m, m))).astype(np.float32)
    tlw = jnp.asarray(w) * (200.0 / w.max(axis=(1, 2), keepdims=True))
    out_k = dykstra_pallas(tlw, n, iters=60, block_b=4)
    out_r = dykstra_ref(tlw, n, iters=60)
    np.testing.assert_allclose(np.array(out_k), np.array(out_r), rtol=1e-5, atol=1e-5)


def test_dykstra_kernel_block_padding():
    w = np.abs(RNG.normal(size=(11, 8, 8))).astype(np.float32)
    tlw = jnp.asarray(w) * 30.0
    out_k = dykstra_pallas(tlw, 4, iters=40, block_b=8)  # 11 % 8 != 0
    out_r = dykstra_ref(tlw, 4, iters=40)
    np.testing.assert_allclose(np.array(out_k), np.array(out_r), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# nm_spmm kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,K,F,n,m", [
    (16, 64, 96, 8, 16), (8, 128, 64, 16, 32), (5, 64, 64, 2, 4), (4, 96, 32, 4, 8),
])
def test_nm_spmm_fwd_and_transpose(B, K, F, n, m, dtype):
    w = RNG.normal(size=(K, F)).astype(np.float32)
    mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(n, m)))
    vals, idx = compress_nm(jnp.asarray(w, dtype), jnp.asarray(mask), n, m)
    x = jnp.asarray(RNG.normal(size=(B, K)), dtype)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    y_k = nm_spmm_pallas(x, vals, idx, m, bt=8, kt=32, ft=32)
    y_r = nm_spmm_ref(x, vals, idx, m)
    np.testing.assert_allclose(np.array(y_k), np.array(y_r), rtol=tol, atol=tol)
    g = jnp.asarray(RNG.normal(size=(B, F)), dtype)
    d_k = nm_spmm_pallas(g, vals, idx, m, transpose=True, bt=8, kt=32, ft=32)
    d_r = nm_spmm_ref(g, vals, idx, m, transpose=True)
    np.testing.assert_allclose(np.array(d_k), np.array(d_r), rtol=tol, atol=tol)


def test_compress_decompress_roundtrip():
    for (K, F, n, m) in [(64, 32, 4, 8), (32, 64, 8, 16), (64, 64, 16, 32)]:
        w = RNG.normal(size=(K, F)).astype(np.float32)
        mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(n, m)))
        vals, idx = compress_nm(jnp.asarray(w), jnp.asarray(mask), n, m)
        assert idx.dtype == jnp.int8
        dense = np.array(decompress_nm(vals, idx, m))
        np.testing.assert_allclose(dense, w * mask, rtol=1e-6, atol=1e-6)


def test_nm_linear_grads_match_dense():
    K, F, n, m = 64, 64, 4, 8
    w = RNG.normal(size=(K, F)).astype(np.float32)
    mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(n, m)))
    vals, idx = compress_nm(jnp.asarray(w), jnp.asarray(mask), n, m)
    x = jnp.asarray(RNG.normal(size=(4, K)).astype(np.float32))

    f_sparse = lambda x, v: jnp.sum(jnp.tanh(nm_linear(x, v, idx, m)))
    gx, gv = jax.grad(f_sparse, argnums=(0, 1))(x, vals)
    wd = jnp.asarray(w * mask)
    f_dense = lambda x, wd: jnp.sum(jnp.tanh(x @ wd))
    gx_d, gw_d = jax.grad(f_dense, argnums=(0, 1))(x, wd)
    np.testing.assert_allclose(np.array(gx), np.array(gx_d), rtol=1e-4, atol=1e-4)
    # dVals gathered from dense dW at the mask support.
    gw_gathered = np.array(gw_d).reshape(K // m, m, F)
    got = np.array(gv)
    idxn = np.array(idx).astype(int)
    for gblk in range(K // m):
        for slot in range(n):
            for f in range(F):
                if mask.reshape(K // m, m, F)[gblk, idxn[gblk, slot, f], f]:
                    assert abs(got[gblk, slot, f] - gw_gathered[gblk, idxn[gblk, slot, f], f]) < 1e-4


# ---------------------------------------------------------------------------
# rounding kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,m,n", [(5, 4, 2), (17, 16, 8), (9, 32, 16), (12, 8, 3)])
def test_greedy_kernel_matches_ref(b, m, n):
    s = jnp.asarray(RNG.random((b, m, m)).astype(np.float32))
    a = greedy_round_pallas(s, n, block_b=8)
    r = greedy_ref(s, n)
    assert (np.array(a) == np.array(r)).all()


# ---------------------------------------------------------------------------
# flash attention kernel (fwd + custom-VJP bwd).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bkv,g,s,hd,causal,window", [
    (2, 2, 64, 32, True, 0),
    (1, 4, 128, 16, True, 0),
    (2, 1, 64, 32, False, 0),
    (1, 2, 128, 32, True, 48),
])
def test_flash_attention_fwd_bwd(bkv, g, s, hd, causal, window):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = jnp.asarray(RNG.normal(size=(bkv, g, s, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(bkv, s, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(bkv, s, hd)).astype(np.float32))
    o = flash_attention_pallas(q, k, v, causal, window, 32, 32)
    o_ref = flash_attention_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.array(o), np.array(o_ref), rtol=2e-5, atol=2e-5)

    f_k = lambda *a: jnp.sum(jnp.sin(flash_attention_pallas(*a, causal, window, 32, 32)))
    f_r = lambda *a: jnp.sum(jnp.sin(flash_attention_ref(*a, causal, window)))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=5e-5)


def test_flash_attention_matches_model_path():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.attention import _flash_attention

    B, S, KV, G, HD = 2, 64, 2, 2, 32
    qg = jnp.asarray(RNG.normal(size=(B, S, KV, G, HD)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, S, KV, HD)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, S, KV, HD)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ref = _flash_attention(qg, k, v, pos, pos, 0, 16)
    got = flash_attention(qg, k, v, causal=True, q_tile=16, kv_tile=16)
    np.testing.assert_allclose(np.array(ref), np.array(got), rtol=2e-5, atol=3e-5)
