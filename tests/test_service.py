"""repro.service: bucketed scheduling, caching, journaled resume."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.solver import SolverConfig, nm_mask, solve_mask
from repro.patterns import PatternSpec
from repro.service import BucketPolicy, Journal, MaskService
from repro.service.cache import content_key

FAST = SolverConfig(iters=60)
TINY = BucketPolicy(base=8, growth=2, max_bucket=32)  # exercise multi-bucket paths


def mixed_tensors(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "big": rng.normal(size=(64, 48)).astype(np.float32),
        "pad_both": rng.normal(size=(20, 12)).astype(np.float32),
        "one_block": rng.normal(size=(8, 8)).astype(np.float32),
        "stacked": rng.normal(size=(3, 16, 16)).astype(np.float32),
        "tiny": rng.normal(size=(4, 4)).astype(np.float32),
    }


def direct_mask(w, n, m, config=FAST):
    if w.ndim == 3:
        return np.stack([
            np.array(solve_mask(jnp.asarray(w[i]), PatternSpec(n, m), config))
            for i in range(w.shape[0])
        ])
    return np.array(solve_mask(jnp.asarray(w), PatternSpec(n, m), config))


# ---------------------------------------------------------------------------
# Scheduler: bucketing round-trips bit-exact vs the per-tensor path.
# ---------------------------------------------------------------------------


def test_mixed_shapes_bit_exact_vs_direct():
    svc = MaskService(FAST, policy=TINY)
    tensors = mixed_tensors()
    handles = {k: svc.submit(k, v, PatternSpec(4, 8)) for k, v in tensors.items()}
    svc.flush()
    for k, v in tensors.items():
        got = np.array(handles[k].result())
        want = direct_mask(v, 4, 8)
        assert got.shape == want.shape, k
        assert (got == want).all(), k
    assert svc.stats.batches >= 2  # tiny policy forces several mega-batches
    assert svc.stats.blocks_solved == sum(
        np.prod([s // 8 + (s % 8 > 0) for s in t.shape[-2:]]) * (t.shape[0] if t.ndim == 3 else 1)
        for t in tensors.values()
    )


def test_mixed_nm_groups_one_service():
    rng = np.random.default_rng(1)
    svc = MaskService(FAST, policy=TINY)
    a = rng.normal(size=(16, 16)).astype(np.float32)
    b = rng.normal(size=(16, 24)).astype(np.float32)
    ha = svc.submit("a", a, PatternSpec(2, 4))
    hb = svc.submit("b", b, PatternSpec(4, 8))
    svc.flush()
    assert (np.array(ha.result()) == direct_mask(a, 2, 4)).all()
    assert (np.array(hb.result()) == direct_mask(b, 4, 8)).all()


def test_lazy_result_flushes():
    svc = MaskService(FAST, policy=TINY)
    h = svc.submit("w", np.ones((8, 8), np.float32), PatternSpec(4, 8))
    assert not h.done
    mask = np.array(h.result())  # implicit flush
    assert h.done and mask.sum(0).max() <= 4 and mask.sum(1).max() <= 4


def test_bucket_plan_ladder():
    p = BucketPolicy(base=8, growth=4, max_bucket=128)
    assert p.ladder() == (8, 32, 128)
    assert p.plan(128 * 3 + 40) == [128, 128, 128, 128]
    assert p.plan(7) == [8]
    assert p.plan(8) == [8]
    assert p.plan(9) == [32]


def test_zero_magnitude_blocks_are_safe():
    svc = MaskService(FAST, policy=TINY)
    w = np.zeros((8, 8), np.float32)
    mask = np.array(svc.solve(w, PatternSpec(4, 8), name="z"))
    assert mask.sum(0).max() <= 4 and mask.sum(1).max() <= 4


# ---------------------------------------------------------------------------
# Cache: hit/miss accounting + disk persistence.
# ---------------------------------------------------------------------------


def test_cache_hits_skip_solving():
    svc = MaskService(FAST, policy=TINY)
    w = np.random.default_rng(2).normal(size=(16, 16)).astype(np.float32)
    m1 = np.array(svc.solve(w, PatternSpec(4, 8), name="w"))
    solved = svc.stats.blocks_solved
    m2 = np.array(svc.solve(w, PatternSpec(4, 8), name="w-again"))  # same content, new name
    assert (m1 == m2).all()
    assert svc.stats.blocks_solved == solved  # nothing re-solved
    assert svc.stats.cache_hits == 1


def test_cache_key_sensitivity():
    w = np.abs(np.random.default_rng(3).normal(size=(2, 8, 8))).astype(np.float32)
    base = content_key(w, PatternSpec(4, 8), FAST)
    assert content_key(w, PatternSpec(2, 8), FAST) != base
    assert content_key(w, PatternSpec(4, 8), SolverConfig(iters=61)) != base
    assert content_key(w + 1e-6, PatternSpec(4, 8), FAST) != base
    # block_batch only chunks dispatch — must NOT invalidate the cache
    assert content_key(w, PatternSpec(4, 8), SolverConfig(iters=60, block_batch=7)) == base


def test_cache_max_bytes_bounds_disk_store(tmp_path):
    """The optional cache bound GC's the disk store after each flush: total
    size stays under the bound, most-recently-accessed entries survive."""
    rng = np.random.default_rng(11)
    svc = MaskService(FAST, policy=TINY, directory=str(tmp_path),
                      cache_max_bytes=1)  # evict everything but the newest
    svc.solve(rng.normal(size=(16, 16)).astype(np.float32),
              PatternSpec(4, 8), name="a")
    svc.solve(rng.normal(size=(16, 16)).astype(np.float32),
              PatternSpec(4, 8), name="b")
    store = svc.cache.store
    assert svc.stats.cache_evictions >= 1
    assert "cache_evictions=" in svc.stats.summary()
    assert store.size_bytes() <= 1  # bound enforced (here: store drained)

    # Unbounded service on the same directory keeps everything.
    svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    svc2.solve(rng.normal(size=(16, 16)).astype(np.float32),
               PatternSpec(4, 8), name="c")
    n_before = len(svc2.cache.store.keys())
    svc2.solve(rng.normal(size=(16, 16)).astype(np.float32),
               PatternSpec(4, 8), name="d")
    assert len(svc2.cache.store.keys()) == n_before + 1
    assert svc2.stats.cache_evictions == 0


def test_mask_cache_prune_without_store_is_noop():
    from repro.service import MaskCache

    assert MaskCache().prune(0) == []


def test_disk_persistence_across_services(tmp_path):
    w = np.random.default_rng(4).normal(size=(24, 16)).astype(np.float32)
    svc1 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    m1 = np.array(svc1.solve(w, PatternSpec(4, 8), name="w"))
    assert svc1.stats.blocks_solved > 0

    svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path))  # fresh process, same dir
    m2 = np.array(svc2.solve(w, PatternSpec(4, 8), name="w"))
    assert (m1 == m2).all()
    assert svc2.stats.blocks_solved == 0  # fully served from disk
    assert svc2.cache.disk_hits == 1


# ---------------------------------------------------------------------------
# Journal + resume-after-interrupt.
# ---------------------------------------------------------------------------


def test_journal_records_and_tolerates_torn_tail(tmp_path):
    j = Journal(str(tmp_path / "j.jsonl"))
    j.record("a", "k1", n=2, m=4)
    j.record("b", "k2", n=2, m=4)
    with open(j.path, "a") as f:
        f.write('{"name": "c", "key"')  # torn mid-append by a crash
    j2 = Journal(j.path)
    done = j2.completed()
    assert set(done) == {"a", "b"}
    assert done["a"]["key"] == "k1"
    # Appending after the tear must not glue onto the torn fragment.
    j2.record("d", "k4", n=2, m=4)
    assert set(Journal(j.path).completed()) == {"a", "b", "d"}


def test_resume_after_interrupt(tmp_path):
    """A run killed mid-model re-solves only the unfinished tensors."""
    tensors = mixed_tensors(seed=5)
    names = list(tensors)

    svc1 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    for k in names[:2]:  # "run" dies after two tensors complete
        svc1.solve(tensors[k], PatternSpec(4, 8), name=k)
    first_solved = svc1.stats.blocks_solved
    assert first_solved > 0

    svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    handles = {k: svc2.submit(k, v, PatternSpec(4, 8)) for k, v in tensors.items()}
    svc2.flush()
    for k, v in tensors.items():
        assert (np.array(handles[k].result()) == direct_mask(v, 4, 8)).all(), k
    assert svc2.stats.cache_hits == 2  # the finished prefix came from disk
    total_blocks = first_solved + svc2.stats.blocks_solved
    svc3 = MaskService(FAST, policy=TINY)  # no cache: counts the full workload
    for k, v in tensors.items():
        svc3.submit(k, v, PatternSpec(4, 8))
    svc3.flush()
    assert total_blocks == svc3.stats.blocks_solved  # no tensor solved twice


def test_sparsify_pytree_routes_through_service_bit_exact():
    from repro.sparsity.masks import sparsify_pytree

    rng = np.random.default_rng(6)
    params = {
        "embed": rng.normal(size=(64, 32)).astype(np.float32),  # exempt? no: 2-D divisible
        "blocks": {
            "wq": rng.normal(size=(2, 16, 16)).astype(np.float32),
            "ln": rng.normal(size=(16,)).astype(np.float32),
        },
    }
    svc = MaskService(SolverConfig(iters=60), policy=TINY)
    masks = sparsify_pytree(params, PatternSpec(2, 4),
                            config=SolverConfig(iters=60), service=svc)
    assert masks["blocks"]["ln"] is None
    assert (np.array(masks["embed"]) == direct_mask(params["embed"], 2, 4,
                                                    SolverConfig(iters=60))).all()
    assert (np.array(masks["blocks"]["wq"]) == direct_mask(
        params["blocks"]["wq"], 2, 4, SolverConfig(iters=60))).all()
    # stacked tensor was ONE submission, not one per layer
    assert svc.stats.submitted == 2


# ---------------------------------------------------------------------------
# Runner: journaled pruning resumes without re-solving.
# ---------------------------------------------------------------------------


def _tiny_lm():
    from repro.models.config import ModelConfig
    from repro.models import lm

    cfg = ModelConfig("svc-test", "dense", num_layers=2, d_model=32, num_heads=4,
                      num_kv_heads=2, d_ff=64, vocab_size=64, remat="none",
                      dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.default_rng(7).integers(0, 64, size=(2, 16)))
    return cfg, params, tokens


def test_prune_transformer_journal_resume(tmp_path):
    from repro.pruning.runner import prune_transformer

    cfg, params, tokens = _tiny_lm()
    jd = str(tmp_path / "run")

    class Interrupted(Exception):
        pass

    calls = []

    def dying_log(s):
        calls.append(s)
        if len(calls) == 5:  # die mid-model
            raise Interrupted(s)

    with pytest.raises(Interrupted):
        prune_transformer(params, cfg, tokens=tokens, method="wanda",
                          pattern=PatternSpec(2, 4),
                          solver=SolverConfig(iters=40), journal_dir=jd,
                          log=dying_log)

    # Resumed run: completes, restores the finished prefix from the journal.
    restored = []
    pruned, masks = prune_transformer(
        params, cfg, tokens=tokens, method="wanda", pattern=PatternSpec(2, 4),
        solver=SolverConfig(iters=40), journal_dir=jd,
        log=lambda s: restored.append(s),
    )
    assert any("restored from journal" in s for s in restored)

    # And matches a clean single-shot run exactly.
    pruned2, masks2 = prune_transformer(
        params, cfg, tokens=tokens, method="wanda", pattern=PatternSpec(2, 4),
        solver=SolverConfig(iters=40),
    )
    for a, b in zip(jax.tree.leaves(masks), jax.tree.leaves(masks2)):
        assert (np.array(a) == np.array(b)).all()
    for a, b in zip(jax.tree.leaves(pruned), jax.tree.leaves(pruned2)):
        np.testing.assert_array_equal(np.array(a), np.array(b))

    # Third run: fully journaled, zero new solves.
    svc = MaskService(SolverConfig(iters=40), directory=jd)
    prune_transformer(params, cfg, tokens=tokens, method="wanda",
                      pattern=PatternSpec(2, 4),
                      solver=SolverConfig(iters=40), service=svc, journal_dir=jd)
    assert svc.stats.blocks_solved == 0


# ---------------------------------------------------------------------------
# nm_mask tie-break on duplicate magnitudes (satellite).
# ---------------------------------------------------------------------------


def test_nm_mask_tie_break_duplicate_magnitudes():
    w = jnp.ones((8, 8), jnp.float32)  # every entry ties
    for axis in (0, 1):
        mask = np.array(nm_mask(w, 2, 4, axis=axis))
        sums = mask.reshape(2, 4, 8).sum(1) if axis == 0 else mask.reshape(8, 2, 4).sum(2)
        assert (sums == 2).all(), (axis, sums)


def test_nm_mask_tie_break_partial_duplicates():
    w = np.array([[3.0, 1.0, 1.0, 1.0],
                  [2.0, 2.0, 2.0, 0.0]] * 2, dtype=np.float32).T  # (4, 4)
    mask = np.array(nm_mask(jnp.asarray(w), 2, 4, axis=0))
    assert (mask.sum(0) == 2).all()
    assert mask[0, 0] and mask[0, 2]  # strict max always kept
