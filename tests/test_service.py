"""repro.service: bucketed scheduling, caching, journaled resume."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.solver import SolverConfig, nm_mask, solve_mask
from repro.patterns import PatternSpec
from repro.service import BucketPolicy, Journal, MaskService
from repro.service.cache import content_key

FAST = SolverConfig(iters=60)
TINY = BucketPolicy(base=8, growth=2, max_bucket=32)  # exercise multi-bucket paths


def mixed_tensors(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "big": rng.normal(size=(64, 48)).astype(np.float32),
        "pad_both": rng.normal(size=(20, 12)).astype(np.float32),
        "one_block": rng.normal(size=(8, 8)).astype(np.float32),
        "stacked": rng.normal(size=(3, 16, 16)).astype(np.float32),
        "tiny": rng.normal(size=(4, 4)).astype(np.float32),
    }


def direct_mask(w, n, m, config=FAST):
    if w.ndim == 3:
        return np.stack([
            np.array(solve_mask(jnp.asarray(w[i]), PatternSpec(n, m), config))
            for i in range(w.shape[0])
        ])
    return np.array(solve_mask(jnp.asarray(w), PatternSpec(n, m), config))


# ---------------------------------------------------------------------------
# Scheduler: bucketing round-trips bit-exact vs the per-tensor path.
# ---------------------------------------------------------------------------


def test_mixed_shapes_bit_exact_vs_direct():
    svc = MaskService(FAST, policy=TINY)
    tensors = mixed_tensors()
    handles = {k: svc.submit(k, v, PatternSpec(4, 8)) for k, v in tensors.items()}
    svc.flush()
    for k, v in tensors.items():
        got = np.array(handles[k].result())
        want = direct_mask(v, 4, 8)
        assert got.shape == want.shape, k
        assert (got == want).all(), k
    assert svc.stats.batches >= 2  # tiny policy forces several mega-batches
    assert svc.stats.blocks_solved == sum(
        np.prod([s // 8 + (s % 8 > 0) for s in t.shape[-2:]]) * (t.shape[0] if t.ndim == 3 else 1)
        for t in tensors.values()
    )


def test_mixed_nm_groups_one_service():
    rng = np.random.default_rng(1)
    svc = MaskService(FAST, policy=TINY)
    a = rng.normal(size=(16, 16)).astype(np.float32)
    b = rng.normal(size=(16, 24)).astype(np.float32)
    ha = svc.submit("a", a, PatternSpec(2, 4))
    hb = svc.submit("b", b, PatternSpec(4, 8))
    svc.flush()
    assert (np.array(ha.result()) == direct_mask(a, 2, 4)).all()
    assert (np.array(hb.result()) == direct_mask(b, 4, 8)).all()


def test_lazy_result_flushes():
    svc = MaskService(FAST, policy=TINY)
    h = svc.submit("w", np.ones((8, 8), np.float32), PatternSpec(4, 8))
    assert not h.done
    mask = np.array(h.result())  # implicit flush
    assert h.done and mask.sum(0).max() <= 4 and mask.sum(1).max() <= 4


def test_bucket_plan_ladder():
    p = BucketPolicy(base=8, growth=4, max_bucket=128)
    assert p.ladder() == (8, 32, 128)
    assert p.plan(128 * 3 + 40) == [128, 128, 128, 128]
    assert p.plan(7) == [8]
    assert p.plan(8) == [8]
    assert p.plan(9) == [32]


def test_zero_magnitude_blocks_are_safe():
    svc = MaskService(FAST, policy=TINY)
    w = np.zeros((8, 8), np.float32)
    mask = np.array(svc.solve(w, PatternSpec(4, 8), name="z"))
    assert mask.sum(0).max() <= 4 and mask.sum(1).max() <= 4


# ---------------------------------------------------------------------------
# Cache: hit/miss accounting + disk persistence.
# ---------------------------------------------------------------------------


def test_cache_hits_skip_solving():
    svc = MaskService(FAST, policy=TINY)
    w = np.random.default_rng(2).normal(size=(16, 16)).astype(np.float32)
    m1 = np.array(svc.solve(w, PatternSpec(4, 8), name="w"))
    solved = svc.stats.blocks_solved
    m2 = np.array(svc.solve(w, PatternSpec(4, 8), name="w-again"))  # same content, new name
    assert (m1 == m2).all()
    assert svc.stats.blocks_solved == solved  # nothing re-solved
    assert svc.stats.cache_hits == 1


def test_cache_key_sensitivity():
    w = np.abs(np.random.default_rng(3).normal(size=(2, 8, 8))).astype(np.float32)
    base = content_key(w, PatternSpec(4, 8), FAST)
    assert content_key(w, PatternSpec(2, 8), FAST) != base
    assert content_key(w, PatternSpec(4, 8), SolverConfig(iters=61)) != base
    assert content_key(w + 1e-6, PatternSpec(4, 8), FAST) != base
    # block_batch only chunks dispatch — must NOT invalidate the cache
    assert content_key(w, PatternSpec(4, 8), SolverConfig(iters=60, block_batch=7)) == base


def test_cache_max_bytes_bounds_disk_store(tmp_path):
    """The optional cache bound GC's the disk store after each flush: total
    size stays under the bound, most-recently-accessed entries survive."""
    rng = np.random.default_rng(11)
    svc = MaskService(FAST, policy=TINY, directory=str(tmp_path),
                      cache_max_bytes=1)  # evict everything but the newest
    svc.solve(rng.normal(size=(16, 16)).astype(np.float32),
              PatternSpec(4, 8), name="a")
    svc.solve(rng.normal(size=(16, 16)).astype(np.float32),
              PatternSpec(4, 8), name="b")
    store = svc.cache.store
    assert svc.stats.cache_evictions >= 1
    assert "cache_evictions=" in svc.stats.summary()
    assert store.size_bytes() <= 1  # bound enforced (here: store drained)

    # Unbounded service on the same directory keeps everything.
    svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    svc2.solve(rng.normal(size=(16, 16)).astype(np.float32),
               PatternSpec(4, 8), name="c")
    n_before = len(svc2.cache.store.keys())
    svc2.solve(rng.normal(size=(16, 16)).astype(np.float32),
               PatternSpec(4, 8), name="d")
    assert len(svc2.cache.store.keys()) == n_before + 1
    assert svc2.stats.cache_evictions == 0


def test_mask_cache_prune_without_store_is_noop():
    from repro.service import MaskCache

    assert MaskCache().prune(0) == []


def test_disk_persistence_across_services(tmp_path):
    w = np.random.default_rng(4).normal(size=(24, 16)).astype(np.float32)
    svc1 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    m1 = np.array(svc1.solve(w, PatternSpec(4, 8), name="w"))
    assert svc1.stats.blocks_solved > 0

    svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path))  # fresh process, same dir
    m2 = np.array(svc2.solve(w, PatternSpec(4, 8), name="w"))
    assert (m1 == m2).all()
    assert svc2.stats.blocks_solved == 0  # fully served from disk
    assert svc2.cache.disk_hits == 1


# ---------------------------------------------------------------------------
# Journal + resume-after-interrupt.
# ---------------------------------------------------------------------------


def test_journal_records_and_tolerates_torn_tail(tmp_path):
    j = Journal(str(tmp_path / "j.jsonl"))
    j.record("a", "k1", n=2, m=4)
    j.record("b", "k2", n=2, m=4)
    with open(j.path, "a") as f:
        f.write('{"name": "c", "key"')  # torn mid-append by a crash
    j2 = Journal(j.path)
    done = j2.completed()
    assert set(done) == {"a", "b"}
    assert done["a"]["key"] == "k1"
    # Appending after the tear must not glue onto the torn fragment.
    j2.record("d", "k4", n=2, m=4)
    assert set(Journal(j.path).completed()) == {"a", "b", "d"}


def test_resume_after_interrupt(tmp_path):
    """A run killed mid-model re-solves only the unfinished tensors."""
    tensors = mixed_tensors(seed=5)
    names = list(tensors)

    svc1 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    for k in names[:2]:  # "run" dies after two tensors complete
        svc1.solve(tensors[k], PatternSpec(4, 8), name=k)
    first_solved = svc1.stats.blocks_solved
    assert first_solved > 0

    svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    handles = {k: svc2.submit(k, v, PatternSpec(4, 8)) for k, v in tensors.items()}
    svc2.flush()
    for k, v in tensors.items():
        assert (np.array(handles[k].result()) == direct_mask(v, 4, 8)).all(), k
    assert svc2.stats.cache_hits == 2  # the finished prefix came from disk
    total_blocks = first_solved + svc2.stats.blocks_solved
    svc3 = MaskService(FAST, policy=TINY)  # no cache: counts the full workload
    for k, v in tensors.items():
        svc3.submit(k, v, PatternSpec(4, 8))
    svc3.flush()
    assert total_blocks == svc3.stats.blocks_solved  # no tensor solved twice


def test_sparsify_pytree_routes_through_service_bit_exact():
    from repro.sparsity.masks import sparsify_pytree

    rng = np.random.default_rng(6)
    params = {
        "embed": rng.normal(size=(64, 32)).astype(np.float32),  # exempt? no: 2-D divisible
        "blocks": {
            "wq": rng.normal(size=(2, 16, 16)).astype(np.float32),
            "ln": rng.normal(size=(16,)).astype(np.float32),
        },
    }
    svc = MaskService(SolverConfig(iters=60), policy=TINY)
    masks = sparsify_pytree(params, PatternSpec(2, 4),
                            config=SolverConfig(iters=60), service=svc)
    assert masks["blocks"]["ln"] is None
    assert (np.array(masks["embed"]) == direct_mask(params["embed"], 2, 4,
                                                    SolverConfig(iters=60))).all()
    assert (np.array(masks["blocks"]["wq"]) == direct_mask(
        params["blocks"]["wq"], 2, 4, SolverConfig(iters=60))).all()
    # stacked tensor was ONE submission, not one per layer
    assert svc.stats.submitted == 2


# ---------------------------------------------------------------------------
# Runner: journaled pruning resumes without re-solving.
# ---------------------------------------------------------------------------


def _tiny_lm():
    from repro.models.config import ModelConfig
    from repro.models import lm

    cfg = ModelConfig("svc-test", "dense", num_layers=2, d_model=32, num_heads=4,
                      num_kv_heads=2, d_ff=64, vocab_size=64, remat="none",
                      dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.default_rng(7).integers(0, 64, size=(2, 16)))
    return cfg, params, tokens


def test_prune_transformer_journal_resume(tmp_path):
    from repro.pruning.runner import prune_transformer

    cfg, params, tokens = _tiny_lm()
    jd = str(tmp_path / "run")

    class Interrupted(Exception):
        pass

    calls = []

    def dying_log(s):
        calls.append(s)
        if len(calls) == 5:  # die mid-model
            raise Interrupted(s)

    with pytest.raises(Interrupted):
        prune_transformer(params, cfg, tokens=tokens, method="wanda",
                          pattern=PatternSpec(2, 4),
                          solver=SolverConfig(iters=40), journal_dir=jd,
                          log=dying_log)

    # Resumed run: completes, restores the finished prefix from the journal.
    restored = []
    pruned, masks = prune_transformer(
        params, cfg, tokens=tokens, method="wanda", pattern=PatternSpec(2, 4),
        solver=SolverConfig(iters=40), journal_dir=jd,
        log=lambda s: restored.append(s),
    )
    assert any("restored from journal" in s for s in restored)

    # And matches a clean single-shot run exactly.
    pruned2, masks2 = prune_transformer(
        params, cfg, tokens=tokens, method="wanda", pattern=PatternSpec(2, 4),
        solver=SolverConfig(iters=40),
    )
    for a, b in zip(jax.tree.leaves(masks), jax.tree.leaves(masks2)):
        assert (np.array(a) == np.array(b)).all()
    for a, b in zip(jax.tree.leaves(pruned), jax.tree.leaves(pruned2)):
        np.testing.assert_array_equal(np.array(a), np.array(b))

    # Third run: fully journaled, zero new solves.
    svc = MaskService(SolverConfig(iters=40), directory=jd)
    prune_transformer(params, cfg, tokens=tokens, method="wanda",
                      pattern=PatternSpec(2, 4),
                      solver=SolverConfig(iters=40), service=svc, journal_dir=jd)
    assert svc.stats.blocks_solved == 0


# ---------------------------------------------------------------------------
# nm_mask tie-break on duplicate magnitudes (satellite).
# ---------------------------------------------------------------------------


def test_nm_mask_tie_break_duplicate_magnitudes():
    w = jnp.ones((8, 8), jnp.float32)  # every entry ties
    for axis in (0, 1):
        mask = np.array(nm_mask(w, 2, 4, axis=axis))
        sums = mask.reshape(2, 4, 8).sum(1) if axis == 0 else mask.reshape(8, 2, 4).sum(2)
        assert (sums == 2).all(), (axis, sums)


def test_nm_mask_tie_break_partial_duplicates():
    w = np.array([[3.0, 1.0, 1.0, 1.0],
                  [2.0, 2.0, 2.0, 0.0]] * 2, dtype=np.float32).T  # (4, 4)
    mask = np.array(nm_mask(jnp.asarray(w), 2, 4, axis=0))
    assert (mask.sum(0) == 2).all()
    assert mask[0, 0] and mask[0, 2]  # strict max always kept


# ---------------------------------------------------------------------------
# Size-aware cache admission (disk tier skips entries cheaper to re-solve).
# ---------------------------------------------------------------------------


def test_cache_admission_pinned_floor_skips_small_entries(tmp_path):
    svc = MaskService(FAST, policy=TINY, directory=str(tmp_path),
                      cache_min_blocks=3)
    rng = np.random.default_rng(20)
    small = rng.normal(size=(8, 16)).astype(np.float32)   # 2 blocks @ M=8
    big = rng.normal(size=(32, 32)).astype(np.float32)    # 16 blocks
    m_small = np.array(svc.solve(small, "t4:8", name="small"))
    m_big = np.array(svc.solve(big, "t4:8", name="big"))
    assert svc.stats.cache_skips == 1
    assert "cache_skips=1" in svc.stats.summary()
    assert len(svc.cache.store.keys()) == 1  # only the big entry persisted

    # The memory front still caches the skipped entry within this process.
    solved = svc.stats.blocks_solved
    np.testing.assert_array_equal(
        np.array(svc.solve(small, "t4:8", name="small2")), m_small)
    assert svc.stats.blocks_solved == solved and svc.stats.cache_hits == 1

    # A fresh service on the same dir re-solves small, reads big from disk.
    svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path),
                       cache_min_blocks=3)
    np.testing.assert_array_equal(
        np.array(svc2.solve(big, "t4:8", name="big")), m_big)
    assert svc2.cache.disk_hits == 1 and svc2.stats.blocks_solved == 0
    np.testing.assert_array_equal(
        np.array(svc2.solve(small, "t4:8", name="small")), m_small)
    assert svc2.stats.blocks_solved == 2  # re-solved, as designed


def test_cache_admission_zero_floor_admits_everything(tmp_path):
    svc = MaskService(FAST, policy=TINY, directory=str(tmp_path),
                      cache_min_blocks=0)
    svc.solve(np.random.default_rng(21).normal(size=(8, 8))
              .astype(np.float32), "t4:8", name="w")
    assert svc.stats.cache_skips == 0
    assert len(svc.cache.store.keys()) == 1


def test_cache_admission_auto_floor_derives_from_observed_rates(tmp_path):
    svc = MaskService(FAST, policy=TINY, directory=str(tmp_path))
    # No observations yet -> floor 0 (admit everything).
    assert svc.cache_admission_min_blocks() == 0
    # Fabricate observed rates: 1000 blocks/s solve, 50 ms per store read
    # -> entries under 50 blocks are cheaper to re-solve than to read back.
    svc.stats.solve_seconds = 2.0
    svc.stats.stream.blocks_solved = 2000
    svc.cache.read_seconds = 0.1
    svc.cache.disk_reads = 2
    assert svc.cache_admission_min_blocks() == 50
    # Explicit floor overrides the derivation.
    svc.cache_min_blocks = 7
    assert svc.cache_admission_min_blocks() == 7


# ---------------------------------------------------------------------------
# ContentStore under concurrent processes sharing a cache directory.
# ---------------------------------------------------------------------------


def test_store_get_or_none_tolerates_eviction_mid_read(tmp_path):
    import os

    from repro.checkpoint import ContentStore

    store = ContentStore(str(tmp_path))
    store.put("k", w=np.ones(4, np.float32))
    assert store.get_or_none("missing") is None
    # Evict between has() and the read — the exact race prune() creates.
    assert store.has("k")
    os.remove(store.path("k"))
    assert store.get_or_none("k") is None


def test_store_readers_race_pruner_without_errors(tmp_path):
    """One thread reads/writes while another prunes to zero bytes: every
    get_or_none returns a valid payload or None, never raises."""
    import threading

    from repro.checkpoint import ContentStore

    store = ContentStore(str(tmp_path))
    stop = threading.Event()
    errors = []

    def reader():
        i = 0
        try:
            while not stop.is_set():
                key = f"k{i % 8}"
                store.put(key, w=np.full(64, i, np.float32))
                data = store.get_or_none(key)
                assert data is None or data["w"].shape == (64,)
                i += 1
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def pruner():
        try:
            while not stop.is_set():
                store.prune(0)
                store.size_bytes()
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=reader),
               threading.Thread(target=pruner)]
    for t in threads:
        t.start()
    import time

    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors


def test_cache_get_packed_miss_on_foreign_payload(tmp_path):
    """A store entry under our key with someone else's schema is a miss,
    not a crash (shared volumes can hold other producers' entries)."""
    from repro.checkpoint import ContentStore
    from repro.service import MaskCache

    store = ContentStore(str(tmp_path))
    store.put("weird", not_mask_data=np.ones(3))
    cache = MaskCache(store)
    assert cache.get_packed("weird") is None
    assert cache.misses == 1


# ---------------------------------------------------------------------------
# Thread-safety: concurrent submit / flush_async / results (satellite).
# ---------------------------------------------------------------------------


def test_concurrent_submit_and_flush_stress():
    """Hammer one service from many threads mixing submit, flush,
    flush_async and results: every handle resolves to the right mask, no
    submission is lost, nothing is solved twice.

    The counter invariant is the tight one: submitted - cache_hits -
    dedup_hits == number of DISTINCT tensors, and blocks_solved equals the
    distinct tensors' block count exactly (a double-solve would overshoot).
    """
    import threading

    svc = MaskService(FAST, policy=TINY)
    rng = np.random.default_rng(22)
    distinct = [rng.normal(size=(16, 16)).astype(np.float32)
                for _ in range(6)]
    want = {
        i: np.array(direct_mask(w, 4, 8)) for i, w in enumerate(distinct)
    }
    n_threads, per_thread = 8, 6
    results, errors = {}, []
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        try:
            barrier.wait()
            handles = []
            for j in range(per_thread):
                i = (tid + j) % len(distinct)
                handles.append(
                    (i, svc.submit(f"t{tid}-{j}", distinct[i],
                                   PatternSpec(4, 8))))
                if j == 2:
                    if tid % 3 == 0:
                        svc.flush()
                    elif tid % 3 == 1:
                        svc.flush_async()
            if tid % 2:
                svc.flush()
                out = [(i, np.array(h.result())) for i, h in handles]
            else:
                masks = svc.results([h for _, h in handles])
                out = [(i, np.array(mk))
                       for (i, _), mk in zip(handles, masks)]
            results[tid] = out
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(tid,))
               for tid in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(results) == n_threads
    for tid, out in results.items():
        assert len(out) == per_thread
        for i, got in out:
            np.testing.assert_array_equal(got, want[i]), (tid, i)
    s = svc.stats
    assert s.submitted == n_threads * per_thread
    assert s.submitted - s.cache_hits - s.dedup_hits == len(distinct)
    assert s.blocks_solved == len(distinct) * 4  # (16/8)^2 blocks each
