"""Fault tolerance across the mask-service stack.

The PR contract: the networked mask path degrades, never corrupts — every
recovery mode (reconnect + re-submission, endpoint failover, server
restart, degraded local fallback) produces masks *bit-identical* to an
uninterrupted in-process solve, the DST controller survives a dead service
without raising into the train loop, and a SIGTERM'd server drains
gracefully.  The chaos harness itself (``ChaosProxy``) is exercised here
and at scale in ``benchmarks/service_chaos.py``.
"""
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.solver import SolverConfig
from repro.patterns import PatternSpec
from repro.service import BucketPolicy, MaskService
from repro.service.engine import FlushTicket
from repro.service.journal import Journal
from repro.service.net import (
    ChaosProxy,
    MaskClient,
    MaskServer,
    NO_RETRY,
    RemoteError,
    RetryBudgetExceeded,
    RetryPolicy,
)
from repro.service.net.server import _Request, _Tenant, TenantConfig

FAST = SolverConfig(iters=60)
TINY = BucketPolicy(base=8, growth=2, max_bucket=32)
#: Fast-recovery policy for tests: generous attempts, tiny sleeps.
QUICK = RetryPolicy(max_attempts=10, base_s=0.01, cap_s=0.05,
                    deadline_s=20.0, seed=0)


def make_server(**kw):
    kw.setdefault("batch_window_s", 0.001)
    return MaskServer(MaskService(FAST, policy=TINY), **kw).start()


def rng_tensors(seed=0, n=3):
    rng = np.random.default_rng(seed)
    return {
        f"t{i}": rng.standard_normal((8 * (i + 1), 16)).astype(np.float32)
        for i in range(n)
    }


def reference_masks(tensors, pattern=PatternSpec(2, 4)):
    local = MaskService(FAST, policy=TINY)
    return {k: np.array(local.solve(w, pattern)) for k, w in tensors.items()}


# ---------------------------------------------------------------------------
# RetryPolicy / Backoff.
# ---------------------------------------------------------------------------


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=1.0, cap_s=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(deadline_s=-1.0)


def test_backoff_attempt_budget_and_cause():
    policy = RetryPolicy(max_attempts=3, base_s=0.001, cap_s=0.002,
                         deadline_s=None, seed=1)
    episode = policy.backoff()
    cause = OSError("boom")
    episode.step(cause)
    episode.step(cause)
    with pytest.raises(RetryBudgetExceeded) as ei:
        episode.step(cause)
    assert ei.value.last_error is cause
    assert episode.attempts == 3


def test_backoff_deadline_budget():
    policy = RetryPolicy(max_attempts=100, base_s=0.001, cap_s=0.005,
                         deadline_s=0.05, seed=2)
    episode = policy.backoff()
    with pytest.raises(RetryBudgetExceeded):
        for _ in range(1000):
            episode.step(OSError("down"))
    assert episode.elapsed_s() >= 0.05


def test_backoff_is_deterministic_under_seed_and_honors_hints():
    draws = []
    for _ in range(2):
        ep = RetryPolicy(max_attempts=50, base_s=0.01, cap_s=1.0,
                         deadline_s=None, seed=7).backoff()
        draws.append([ep.next_delay() for _ in range(5)])
    assert draws[0] == draws[1]  # same seed, same jitter schedule
    assert all(0.01 <= d <= 1.0 for d in draws[0])
    ep = RetryPolicy(seed=7).backoff()
    assert ep.next_delay(retry_after=0.4) == 0.4  # server hint wins
    assert ep.next_delay(retry_after=99.0) == ep.policy.cap_s  # but capped
    assert NO_RETRY.max_attempts == 1


# ---------------------------------------------------------------------------
# Journal: torn-tail replay (the crash-mid-append regression).
# ---------------------------------------------------------------------------


def test_journal_replay_skips_torn_final_record(tmp_path, caplog):
    path = str(tmp_path / "journal.jsonl")
    j = Journal(path)
    j.record("a", "k1")
    j.record("b", "k2")
    # Byte-truncate the file mid-record, exactly what a kill mid-append
    # leaves behind.
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[:-9])
    with caplog.at_level("WARNING", logger="repro.service.journal"):
        done = Journal(path).completed()
    assert done.keys() == {"a"}
    assert any("torn final record" in r.message for r in caplog.records)
    # The torn tail does not poison subsequent appends either.
    j2 = Journal(path)
    j2.record("c", "k3")
    assert Journal(path).completed().keys() == {"a", "c"}


def test_journal_replay_warns_on_mid_file_corruption(tmp_path, caplog):
    path = str(tmp_path / "journal.jsonl")
    with open(path, "w") as f:
        f.write('{"name": "a", "key": "k1"}\n')
        f.write("NOT JSON AT ALL\n")
        f.write('{"name": "b", "key": "k2"}\n')
    with caplog.at_level("WARNING", logger="repro.service.journal"):
        done = Journal(path).completed()
    assert done.keys() == {"a", "b"}
    assert any("corrupt record at line 2" in r.message
               for r in caplog.records)


def test_journal_sync_is_safe_without_file(tmp_path):
    Journal(str(tmp_path / "never-written.jsonl")).sync()  # no-op, no raise


# ---------------------------------------------------------------------------
# Client recovery: reconnect, re-submission, failover, degraded fallback.
# ---------------------------------------------------------------------------


def test_flush_recovers_from_severed_connections():
    """Kill every connection after submit: flush must reconnect, re-submit
    the in-flight payloads, and produce bit-identical masks."""
    tensors = rng_tensors(seed=3)
    want = reference_masks(tensors)
    srv = make_server()
    try:
        with ChaosProxy((srv.host, srv.port), seed=0) as proxy:
            with MaskClient(proxy.address, tenant="chaos",
                            retry=QUICK) as c:
                handles = {k: c.submit(k, w, "t2:4")
                           for k, w in tensors.items()}
                time.sleep(0.05)  # let the submits hit the wire
                proxy.kill_connections()
                c.flush()
                for k, h in handles.items():
                    np.testing.assert_array_equal(np.array(h.result()),
                                                  want[k])
                assert c.stats.retries >= 1
                assert not c.stats.degraded
    finally:
        srv.stop()


def test_server_restart_loses_queue_client_resubmits_bit_identical():
    """Hard server kill + restart on a fresh port mid-flight: the retried
    wait reports unknown ids, the client re-submits, masks match exactly."""
    tensors = rng_tensors(seed=4)
    want = reference_masks(tensors)
    srv1 = make_server(batch_window_s=0.5)  # linger: requests stay queued
    proxy = ChaosProxy((srv1.host, srv1.port), seed=1)
    try:
        with MaskClient(proxy.address, tenant="restart",
                        retry=QUICK) as c:
            handles = {k: c.submit(k, w, "t2:4")
                       for k, w in tensors.items()}
            # Kill the server with the queue unsolved, then restart "it"
            # (fresh process, no shared state) behind the same address.
            srv1.stop()
            proxy.kill_connections()
            srv2 = make_server()
            try:
                proxy.retarget((srv2.host, srv2.port))
                c.flush()
                for k, h in handles.items():
                    np.testing.assert_array_equal(np.array(h.result()),
                                                  want[k])
                assert c.stats.resubmitted >= len(tensors)
                assert not c.stats.degraded
                assert "retries=" in c.stats.summary()
            finally:
                srv2.stop()
    finally:
        proxy.stop()


def test_failover_to_second_endpoint():
    srv1 = make_server()
    srv2 = make_server()
    tensors = rng_tensors(seed=5, n=2)
    want = reference_masks(tensors)
    try:
        with MaskClient([srv1.address, srv2.address], tenant="ha",
                        retry=QUICK) as c:
            first = next(iter(tensors))
            np.testing.assert_array_equal(
                np.array(c.solve(tensors[first], "t2:4")), want[first])
            srv1.stop()  # primary dies between requests
            for k, w in tensors.items():
                np.testing.assert_array_equal(
                    np.array(c.solve(w, "t2:4")), want[k])
            assert c.stats.failovers >= 1
            assert c.port == srv2.port
            assert not c.stats.degraded
    finally:
        srv1.stop()
        srv2.stop()


def test_degraded_fallback_solves_locally_bit_identical():
    """Every endpooint down past the budget: the client finishes the flush
    through a local MaskService built from the advertised SolverConfig."""
    tensors = rng_tensors(seed=6)
    want = reference_masks(tensors)
    srv = make_server(batch_window_s=0.5)
    c = MaskClient(srv.address, tenant="degraded",
                   retry=RetryPolicy(max_attempts=2, base_s=0.01,
                                     cap_s=0.02, deadline_s=5.0, seed=0))
    try:
        handles = {k: c.submit(k, w, "t2:4") for k, w in tensors.items()}
        srv.stop()  # and nothing comes back
        c.flush()
        assert c.stats.degraded and c.degraded
        for k, h in handles.items():
            np.testing.assert_array_equal(np.array(h.result()), want[k])
        # Once degraded, later work solves locally too (no dead-wire stalls).
        k0 = next(iter(tensors))
        np.testing.assert_array_equal(
            np.array(c.solve(tensors[k0], "t2:4")), want[k0])
        assert "DEGRADED" in c.stats.summary()
    finally:
        c.close()
        srv.stop()


def test_construction_with_all_endpoints_down():
    # Without a pinned config the client cannot promise bit-identity -> up
    # to the caller.
    with pytest.raises(OSError):
        MaskClient("127.0.0.1:9", retry=NO_RETRY)
    # With one, construction degrades immediately and solves locally.
    tensors = rng_tensors(seed=7, n=1)
    want = reference_masks(tensors)
    with MaskClient("127.0.0.1:9", retry=NO_RETRY,
                    fallback_config=FAST) as c:
        assert c.degraded
        k0 = next(iter(tensors))
        np.testing.assert_array_equal(
            np.array(c.solve(tensors[k0], "t2:4")), want[k0])


def test_fallback_none_fails_outstanding_with_cause():
    srv = make_server(batch_window_s=0.5)
    c = MaskClient(srv.address, tenant="strict", fallback="none",
                   retry=RetryPolicy(max_attempts=2, base_s=0.01,
                                     cap_s=0.02, deadline_s=5.0, seed=0))
    try:
        h = c.submit("t", rng_tensors(seed=8, n=1)["t0"], "t2:4")
        srv.stop()
        with pytest.raises((OSError, RemoteError)):
            c.flush()
        with pytest.raises((OSError, RemoteError)):
            h.result()  # the root cause, not a hang
        assert not c.stats.degraded
    finally:
        c.close()


def test_health_op_and_draining_flag():
    srv = make_server()
    try:
        with MaskClient(srv.address, tenant="probe") as c:
            h = c.health()
            assert h["accepting"] and not h["draining"]
            assert h["queued"] == 0 and h["uptime_seconds"] >= 0.0
    finally:
        srv.stop()


def test_close_joins_background_flush():
    """Satellite regression: close() must join an active flush_async drain
    before yanking the pooled sockets out from under it."""
    srv = make_server()
    try:
        c = MaskClient(srv.address, tenant="bg")
        h = c.submit("t", rng_tensors(seed=9, n=1)["t0"], "t2:4")
        ticket = c.flush_async()
        c.close()  # must not race the drain
        assert ticket.wait(timeout=30)
        assert ticket._error is None
        assert h.done
    finally:
        srv.stop()


def test_config_mismatch_endpoint_is_skipped():
    srv_a = make_server()
    srv_b = MaskServer(MaskService(SolverConfig(iters=61), policy=TINY),
                       batch_window_s=0.001).start()
    try:
        with MaskClient([srv_a.address, srv_b.address],
                        retry=RetryPolicy(max_attempts=3, base_s=0.01,
                                          cap_s=0.02, deadline_s=5.0,
                                          seed=0),
                        fallback="none") as c:
            srv_a.stop()
            # The only live endpoint advertises a different SolverConfig:
            # failing over to it would silently change every mask, so the
            # client must refuse rather than fail over.
            with pytest.raises(RemoteError) as ei:
                c.solve(rng_tensors(seed=10, n=1)["t0"], "t2:4")
            assert ei.value.kind == "config-mismatch"
            assert c.stats.failovers == 0
            assert c.stats.degraded is False
    finally:
        srv_a.stop()
        srv_b.stop()


# ---------------------------------------------------------------------------
# Server: load shedding, deadlines, graceful drain.
# ---------------------------------------------------------------------------


def test_overload_shedding_structured_reply():
    srv = make_server(max_queue_blocks=4, batch_window_s=1.0)
    try:
        with MaskClient(srv.address, tenant="flood", retry=NO_RETRY,
                        fallback="none") as c:
            big = np.random.default_rng(0).standard_normal(
                (64, 16)).astype(np.float32)
            c.submit("a", big, "t2:4")  # fills the queue past the bound
            time.sleep(0.05)
            with pytest.raises(RemoteError) as ei:
                c.submit("b", big + 1.0, "t2:4")
            assert ei.value.kind == "overloaded"
            assert ei.value.retry_after is not None
            assert ei.value.transient
    finally:
        srv.stop()


def test_expire_overdue_fails_with_deadline_kind():
    # White-box: the sweep itself, without racing the live drain thread.
    srv = MaskServer(MaskService(FAST, policy=TINY), request_deadline_s=0.01)
    tenant = _Tenant("t", TenantConfig(), 64)
    blocks = np.zeros((1, 4, 4), np.float32)
    old = _Request("r1", "old", "t2:4", False, blocks, tenant)
    old.enqueued_at -= 1.0
    new = _Request("r2", "new", "t2:4", False, blocks, tenant)
    tenant.queue.extend([old, new])
    tenant.results = {"r1": old, "r2": new}
    srv._tenants["t"] = tenant
    srv._expire_overdue()
    assert old.event.is_set() and old.error_kind == "deadline"
    assert not new.event.is_set()
    assert list(tenant.queue) == [new]
    assert tenant.failed == 1


def test_duplicate_submits_are_idempotent():
    srv = make_server(batch_window_s=0.2)
    try:
        with MaskClient(srv.address, tenant="dup", retry=NO_RETRY) as c:
            h = c.submit("t", rng_tensors(seed=11, n=1)["t0"], "t2:4")
            assert c._resubmit_outstanding() == 1  # same id, same payload
            c.flush()
            assert h.done
            row = c.server_stats()["tenants"]["dup"]
            assert row["submitted"] == 1  # the duplicate was absorbed
            assert row["resubmitted"] == 1
            assert row["resolved"] == 1
    finally:
        srv.stop()


def test_graceful_drain_finishes_inflight_work():
    tensors = rng_tensors(seed=12)
    want = reference_masks(tensors)
    srv = make_server(batch_window_s=0.1)
    with MaskClient(srv.address, tenant="drainee", retry=NO_RETRY) as c:
        handles = {k: c.submit(k, w, "t2:4") for k, w in tensors.items()}
        drainer = threading.Thread(target=srv.drain, kwargs={"grace_s": 30})
        drainer.start()
        try:
            c.flush()  # in-flight work still completes and is claimable
            for k, h in handles.items():
                np.testing.assert_array_equal(np.array(h.result()), want[k])
        finally:
            drainer.join(timeout=60)
        assert not srv._running
    # A draining/stopped server rejects new connections entirely.
    with pytest.raises(OSError):
        MaskClient(srv.address, retry=NO_RETRY)


def test_submit_during_drain_rejected_with_draining_kind():
    srv = make_server(batch_window_s=0.001)
    try:
        with MaskClient(srv.address, tenant="late", retry=NO_RETRY,
                        fallback="none") as c:
            c.solve(rng_tensors(seed=13, n=1)["t0"], "t2:4")  # warm conn
            with srv._cv:
                srv._draining = True  # drain flag only; keep serving
            with pytest.raises(RemoteError) as ei:
                c.submit("x", rng_tensors(seed=14, n=1)["t0"], "t2:4")
            assert ei.value.kind == "draining"
            assert ei.value.retry_after is not None
            assert c.health()["draining"]
    finally:
        with srv._cv:
            srv._draining = False
        srv.stop()


def test_sigterm_drains_and_exits_cleanly(tmp_path):
    """The CLI's SIGTERM contract: stop accepting, drain, exit 0."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_masks",
         "--port", "0", "--iters", "8", "--drain-grace", "10",
         "--dir", str(tmp_path)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env,
    )
    try:
        line = proc.stdout.readline()
        assert "listening on" in line, line
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=60)
        assert proc.returncode == 0, out
        assert "drained, exiting" in out
    finally:
        if proc.poll() is None:
            proc.kill()


# ---------------------------------------------------------------------------
# DST: refresh failure keeps the old mask and re-arms.
# ---------------------------------------------------------------------------


class FlakyService(MaskService):
    """Fails the first ``fail_times`` background flushes outright."""

    def __init__(self, fail_times: int):
        super().__init__(FAST, policy=TINY)
        self.fail_times = fail_times

    def flush_async(self) -> FlushTicket:
        if self.fail_times > 0:
            self.fail_times -= 1
            ticket = FlushTicket()
            ticket._error = RuntimeError("injected mask-service outage")
            ticket._event.set()
            return ticket
        return super().flush_async()


def _compressed_state():
    from repro.models import lm
    from repro.models.config import ModelConfig
    from repro.optim import AdamW
    from repro.sparsity.masks import apply_mask, sparsify_pytree
    from repro.sparsity.params import compress_params, projection_prunable
    from repro.train import make_train_state

    cfg = ModelConfig("resil", "dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    pattern = PatternSpec(24, 32)
    masks = sparsify_pytree(params, pattern, config=FAST,
                            prunable=projection_prunable)
    sp = compress_params(apply_mask(params, masks), masks, pattern)
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
    return make_train_state(cfg, opt, jax.random.PRNGKey(1), params=sp,
                            compression=False)


def test_dst_refresh_failure_keeps_old_mask_then_retries():
    from repro.dst import MaskRefreshController, StepwiseSchedule

    state = _compressed_state()
    sched = StepwiseSchedule(((0, "t24:32"), (3, "t16:32")))
    svc = FlakyService(fail_times=1)
    ctrl = MaskRefreshController(sched, service=svc, mode="async",
                                 lookahead=2)
    before = jax.tree.leaves(state.params)
    for t in range(8):
        state = ctrl.on_step(t, state._replace(
            step=jnp.asarray(t, jnp.int32)))
        if t == 3:
            # The swap-step flush failed: old support kept, nothing raised.
            failed = [e for e in ctrl.events if e.failed]
            assert len(failed) == 1
            assert "injected mask-service outage" in failed[0].error
            assert "FAILED" in failed[0].summary()
            after = jax.tree.leaves(state.params)
            assert all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(before, after))
    # The re-armed retry landed on a later step and swapped for real.
    done = [e for e in ctrl.events if not e.failed]
    assert len(done) == 1 and done[0].pattern == "t16:32"
    assert state.params["blocks"]["attn"]["wq"].n == 16
    tel = ctrl.telemetry()
    assert tel["failed_refreshes"] == 1 and tel["refreshes"] == 2


def test_dst_refresh_abandoned_past_retry_cap():
    from repro.dst import MaskRefreshController, StepwiseSchedule

    state = _compressed_state()
    sched = StepwiseSchedule(((0, "t24:32"), (2, "t16:32")))
    svc = FlakyService(fail_times=100)  # never recovers
    ctrl = MaskRefreshController(sched, service=svc, mode="async",
                                 lookahead=1, max_refresh_retries=2)
    for t in range(12):
        state = ctrl.on_step(t, state._replace(
            step=jnp.asarray(t, jnp.int32)))
    assert state.params["blocks"]["attn"]["wq"].n == 24  # old mask kept
    failed = [e for e in ctrl.events if e.failed]
    assert len(failed) == 1 + 2  # first attempt + max_refresh_retries
    assert ctrl._rearm is None  # abandoned, not looping forever


def test_dst_failed_retry_state_survives_checkpoint_round_trip():
    from repro.dst import MaskRefreshController, StepwiseSchedule

    sched = StepwiseSchedule(((0, "t24:32"), (3, "t16:32")))
    state = _compressed_state()
    svc = FlakyService(fail_times=100)
    ctrl = MaskRefreshController(sched, service=svc, mode="async",
                                 lookahead=2)
    for t in range(4):
        state = ctrl.on_step(t, state._replace(
            step=jnp.asarray(t, jnp.int32)))
    # A failure re-arm is pending; it must ride state_dict like an
    # in-flight refresh does.
    snap = ctrl.state_dict()
    assert snap["inflight"] is not None
    assert snap["inflight"]["retries"] >= 1
    ctrl2 = MaskRefreshController(sched, service=FlakyService(0),
                                  mode="async", lookahead=2)
    ctrl2.load_state_dict(snap)
    state2 = _compressed_state()
    for t in range(4, 8):
        state2 = ctrl2.on_step(t, state2._replace(
            step=jnp.asarray(t, jnp.int32)))
    done = [e for e in ctrl2.events if not e.failed]
    assert len(done) == 1 and done[0].pattern == "t16:32"
    assert state2.params["blocks"]["attn"]["wq"].n == 16


# ---------------------------------------------------------------------------
# ChaosProxy sanity.
# ---------------------------------------------------------------------------


def test_chaos_proxy_passthrough_and_counters():
    srv = make_server()
    try:
        with ChaosProxy(srv.address, seed=0, latency_s=0.001) as proxy:
            tensors = rng_tensors(seed=15, n=1)
            want = reference_masks(tensors)
            with MaskClient(proxy.address, retry=NO_RETRY) as c:
                k0 = next(iter(tensors))
                np.testing.assert_array_equal(
                    np.array(c.solve(tensors[k0], "t2:4")), want[k0])
            assert proxy.connections >= 1
            assert proxy.forwarded_bytes > 0
            assert proxy.killed == 0 and proxy.torn == 0
    finally:
        srv.stop()


def test_chaos_proxy_blackhole_times_out_client():
    srv = make_server()
    try:
        with ChaosProxy(srv.address, seed=0) as proxy:
            with MaskClient(proxy.address, retry=NO_RETRY,
                            fallback="none", timeout=0.2) as c:
                proxy.blackhole(True)
                with pytest.raises(OSError):  # socket.timeout
                    c.ping()
                assert proxy.swallowed_bytes > 0
    finally:
        srv.stop()


def test_prune_transformer_survives_flaky_network():
    """End-to-end: a full layer-wise prune through a lossy proxy with
    mid-run connection kills is bit-identical to a local prune."""
    from repro.models import lm
    from repro.models.config import ModelConfig
    from repro.pruning.runner import prune_transformer

    cfg = ModelConfig("chaos-net", "dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(7).integers(0, 64, size=(2, 16)))
    kw = dict(tokens=tokens, method="wanda", pattern=PatternSpec(2, 4),
              solver=FAST)
    pruned_l, masks_l = prune_transformer(
        params, cfg, service=MaskService(FAST, policy=TINY), **kw)

    srv = make_server()
    stop_chaos = threading.Event()
    try:
        with ChaosProxy(srv.address, seed=3, latency_s=0.0005) as proxy:
            def sever_periodically():
                while not stop_chaos.wait(0.15):
                    proxy.kill_connections()

            chaos = threading.Thread(target=sever_periodically, daemon=True)
            chaos.start()
            try:
                with MaskClient(proxy.address, tenant="chaos-prune",
                                retry=QUICK) as c:
                    pruned_r, masks_r = prune_transformer(
                        params, cfg, service=c, **kw)
                    assert not c.stats.degraded
            finally:
                stop_chaos.set()
                chaos.join(timeout=5)
    finally:
        srv.stop()
    for a, b in zip(jax.tree.leaves(masks_r), jax.tree.leaves(masks_l)):
        np.testing.assert_array_equal(np.array(a), np.array(b))
    for a, b in zip(jax.tree.leaves(pruned_r), jax.tree.leaves(pruned_l)):
        np.testing.assert_array_equal(np.array(a), np.array(b))
