"""Compressed execution end-to-end: SparseParams through models/train/serve.

The contract under test is *bit-identity*: executing from ``NMCompressed``
buffers (values + int8 indices through the nm_spmm kernel) must produce — at
``tol=0``, after decompression — exactly the numbers the dense masked path
produces: forward logits, multi-step training trajectories across all three
``mask_mode``s, serving tokens, and checkpoint round-trips.
"""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import PatternSpec, SolverConfig
from repro.checkpoint import CheckpointManager
from repro.core import solve_mask
from repro.data import SyntheticLM
from repro.kernels.nm_spmm.ops import nm_linear, nm_linear_nd
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.pruning import prune_transformer
from repro.serve import ServeEngine
from repro.sparsity.compressed import compress_nm, decompress_nm
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.sparsity.params import (
    NMCompressed,
    compress_params,
    decompress_params,
    is_sparse_params,
    masks_from_params,
    projection_prunable,
    sparse_param_bytes,
)
from repro.train import build_train_step, make_train_state
from repro.train.step import StepConfig

RNG = np.random.default_rng(7)

CFG = ModelConfig("cx", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, remat="none",
                  dtype="float32")


def tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def small_sparse_model(seed=0, solver_iters=40):
    params = lm.init_params(CFG, jax.random.PRNGKey(seed))
    masks = sparsify_pytree(params, PatternSpec(2, 4),
                            config=SolverConfig(iters=solver_iters),
                            prunable=projection_prunable)
    pruned = apply_mask(params, masks)
    sp = compress_params(pruned, masks, PatternSpec(2, 4))
    return pruned, masks, sp


# ---------------------------------------------------------------------------
# nm_linear gradient checks vs the dense jnp oracle (dx via the transpose
# path, dvals via support gather) — patterns incl. M>16, non-square shapes.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,K,F,n,m", [
    (8, 64, 96, 2, 4),       # non-square, wide
    (8, 96, 32, 4, 8),       # non-square, narrow
    (4, 64, 128, 8, 16),
    (4, 64, 128, 16, 32),    # M > 16
    (4, 128, 64, 8, 32),     # M > 16, 1:4 density, non-square
])
@pytest.mark.parametrize("seed", [0, 1])
def test_nm_linear_gradcheck_vs_dense_oracle(B, K, F, n, m, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(K, F)).astype(np.float32)
    mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(n, m),
                               SolverConfig(iters=60)))
    vals, idx = compress_nm(jnp.asarray(w), jnp.asarray(mask), n, m)
    wd = jnp.asarray(w * mask)
    x = jnp.asarray(rng.normal(size=(B, K)).astype(np.float32))
    dy = jnp.asarray(rng.normal(size=(B, F)).astype(np.float32))

    y, vjp = jax.vjp(lambda x, v: nm_linear(x, v, idx, m), x, vals)
    dx, dvals = vjp(dy)
    y_d, vjp_d = jax.vjp(lambda x, w: x @ w, x, wd)
    dx_d, dw_d = vjp_d(dy)

    np.testing.assert_array_equal(np.array(y), np.array(y_d))
    np.testing.assert_array_equal(np.array(dx), np.array(dx_d))
    # dvals == dense dW gathered at the support, exactly (0 at dead slots).
    dwg = np.array(dw_d).reshape(K // m, m, F)
    idxn = np.array(idx, np.int32)
    expect = np.take_along_axis(dwg, np.maximum(idxn, 0), axis=1)
    expect = np.where(idxn >= 0, expect, 0.0)
    np.testing.assert_array_equal(np.array(dvals), expect.astype(np.float32))


def test_nm_linear_dead_slots_get_zero_gradient():
    """Groups with fewer than N nonzeros mark dead slots idx=-1: they must
    neither scatter on decompress nor gather gradient on backward."""
    K, F, n, m = 8, 8, 2, 4
    rng = np.random.default_rng(0)
    w = rng.normal(size=(K, F)).astype(np.float32)
    mask = np.zeros((K, F), bool)
    mask[0, :] = True          # group 0: one nonzero per column (< n)
    mask[4:6, :] = True        # group 1: exactly n nonzeros per column
    vals, idx = compress_nm(jnp.asarray(w), jnp.asarray(mask), n, m)
    idxn = np.array(idx, np.int32)
    assert (idxn[0, 1, :] == -1).all()  # dead slot marked
    np.testing.assert_array_equal(
        np.array(decompress_nm(vals, idx, m)), w * mask
    )
    x = jnp.asarray(rng.normal(size=(4, K)).astype(np.float32))
    dy = jnp.asarray(rng.normal(size=(4, F)).astype(np.float32))
    _, vjp = jax.vjp(lambda v: nm_linear(x, v, idx, m), vals)
    (dvals,) = vjp(dy)
    assert (np.array(dvals)[0, 1, :] == 0.0).all()  # dead slot: zero grad
    # Live slots carry the dense gradient at their positions.
    dw = np.array(x.T @ dy)
    np.testing.assert_array_equal(np.array(dvals)[0, 0, :], dw[0, :])


def test_nm_linear_nd_matches_2d_flatten():
    K, F, n, m = 64, 96, 4, 8
    w = RNG.normal(size=(K, F)).astype(np.float32)
    mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(n, m),
                               SolverConfig(iters=40)))
    vals, idx = compress_nm(jnp.asarray(w), jnp.asarray(mask), n, m)
    x = jnp.asarray(RNG.normal(size=(2, 3, K)).astype(np.float32))
    y = nm_linear_nd(x, vals, idx, m)
    assert y.shape == (2, 3, F)
    y2 = nm_linear(x.reshape(-1, K), vals, idx, m).reshape(2, 3, F)
    np.testing.assert_array_equal(np.array(y), np.array(y2))


# ---------------------------------------------------------------------------
# SparseParams representation.
# ---------------------------------------------------------------------------


def test_compress_params_roundtrip_and_surface():
    pruned, masks, sp = small_sparse_model()
    assert is_sparse_params(sp) and not is_sparse_params(pruned)
    # Projections compressed; embed/unembed/norms stay dense.
    assert isinstance(sp["blocks"]["attn"]["wq"], NMCompressed)
    assert isinstance(sp["blocks"]["mlp"]["down"], NMCompressed)
    assert not isinstance(sp["embed"], NMCompressed)
    assert not isinstance(sp["unembed"], NMCompressed)
    # Exact inverse.
    assert tree_equal(decompress_params(sp), pruned)
    # Mask recovery from indices alone.
    rec = masks_from_params(sp)
    got = np.array(rec["blocks"]["attn"]["wq"])
    want = np.array(masks["blocks"]["attn"]["wq"]).astype(bool)
    np.testing.assert_array_equal(got, want)
    # Footprint: 2:4 f32 + int8 indices -> (2*4 + 2*1)/(4*4) = 0.625.
    acc = sparse_param_bytes(sp)
    assert acc["ratio"] == pytest.approx(0.625)


def test_compressed_leaf_slicing_matches_layers():
    _pruned, _masks, sp = small_sparse_model()
    wq = sp["blocks"]["attn"]["wq"]
    lp = jax.tree.map(lambda a: a[1], sp["blocks"])  # layer 1 slice
    assert isinstance(lp["attn"]["wq"], NMCompressed)
    np.testing.assert_array_equal(
        np.array(lp["attn"]["wq"].decompress()), np.array(wq.decompress()[1])
    )


def test_compress_params_rejects_standard_patterns():
    pruned, masks, _ = small_sparse_model()
    with pytest.raises(ValueError, match="transposable"):
        compress_params(pruned, masks, PatternSpec(2, 4, transposable=False))


def test_compress_params_strict_rejects_uncompressible_masks():
    """A mask on a leaf proj() never dispatches (e.g. the embedding table)
    would be silently dropped — its support would drift under
    mask_mode='compressed' — so strict mode refuses it."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    masks = sparsify_pytree(params, PatternSpec(2, 4),
                            config=SolverConfig(iters=20))  # masks embed too
    with pytest.raises(ValueError, match="embed"):
        compress_params(params, masks, PatternSpec(2, 4))
    relaxed = compress_params(params, masks, PatternSpec(2, 4), strict=False)
    assert not isinstance(relaxed["embed"], NMCompressed)
    assert isinstance(relaxed["blocks"]["attn"]["wq"], NMCompressed)


# ---------------------------------------------------------------------------
# Model forward / train-step bit-identity across mask modes.
# ---------------------------------------------------------------------------


def test_forward_bit_identical_compressed_vs_dense():
    pruned, _masks, sp = small_sparse_model()
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=1)
    toks = jnp.asarray(data.batch(0)["tokens"])
    np.testing.assert_array_equal(
        np.array(lm.forward(pruned, CFG, tokens=toks)),
        np.array(lm.forward(sp, CFG, tokens=toks)),
    )


@pytest.mark.parametrize("seed", [0, 3])
def test_multi_step_bit_identity_fwd_post_compressed(seed):
    """3 optimizer steps in each mask mode: losses and (decompressed) masked
    weights stay bitwise identical — the compressed path IS the dense path."""
    pruned, masks, sp = small_sparse_model(seed=seed)
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=seed)
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)

    st = {
        "fwd": make_train_state(CFG, opt, jax.random.PRNGKey(1),
                                params=jax.tree.map(jnp.copy, pruned)),
        "post": make_train_state(CFG, opt, jax.random.PRNGKey(1),
                                 params=jax.tree.map(jnp.copy, pruned)),
        "compressed": make_train_state(CFG, opt, jax.random.PRNGKey(1),
                                       params=sp),
    }
    steps = {
        mode: build_train_step(
            CFG, opt, masks=None if mode == "compressed" else masks,
            step_cfg=StepConfig(mask_mode=mode), donate=False)
        for mode in st
    }
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        losses = {}
        for mode in st:
            st[mode], metrics = steps[mode](st[mode], batch)
            losses[mode] = float(metrics["loss"])
        assert losses["fwd"] == losses["post"] == losses["compressed"], (i, losses)
    assert tree_equal(st["fwd"].params, st["post"].params)
    assert tree_equal(st["fwd"].params, decompress_params(st["compressed"].params))


def test_compressed_step_with_grad_accumulation():
    _pruned, _masks, sp = small_sparse_model()
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=2)
    opt = AdamW(learning_rate=1e-3)
    state = make_train_state(CFG, opt, jax.random.PRNGKey(0), params=sp)
    step = build_train_step(CFG, opt,
                            step_cfg=StepConfig(accum=2, mask_mode="compressed"),
                            donate=False)
    state, metrics = step(state, {k: jnp.asarray(v)
                                  for k, v in data.batch(0).items()})
    assert np.isfinite(float(metrics["loss"]))
    assert is_sparse_params(state.params)


def test_optimizer_state_lands_on_compressed_shapes():
    _pruned, _masks, sp = small_sparse_model()
    opt = AdamW(learning_rate=1e-3)
    mu = opt.init(sp).mu
    wq = sp["blocks"]["attn"]["wq"]
    assert mu["blocks"]["attn"]["wq"].values.shape == wq.values.shape
    assert mu["blocks"]["attn"]["wq"].indices.shape == (0,)  # no moments
    dense_moment = int(np.prod(wq.dense_shape)) * 4
    comp_moment = int(np.prod(wq.values.shape)) * 4
    assert comp_moment * 2 == dense_moment  # N/M = 1/2 of dense HBM


def test_compressed_mode_rejects_masks():
    opt = AdamW()
    with pytest.raises(ValueError, match="compressed"):
        build_train_step(CFG, opt, masks={"x": jnp.ones(())},
                         step_cfg=StepConfig(mask_mode="compressed"))
    with pytest.raises(ValueError, match="mask_mode"):
        build_train_step(CFG, opt, step_cfg=StepConfig(mask_mode="bogus"))


def test_compressed_mode_rejects_dense_params():
    """Dense params under mask_mode='compressed' would train unmasked with
    no re-projection (silent support drift) — the step must refuse."""
    pruned, _masks, _sp = small_sparse_model()
    opt = AdamW(learning_rate=1e-3)
    state = make_train_state(CFG, opt, jax.random.PRNGKey(0),
                             params=jax.tree.map(jnp.copy, pruned))
    step = build_train_step(CFG, opt,
                            step_cfg=StepConfig(mask_mode="compressed"),
                            donate=False)
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=0)
    with pytest.raises(ValueError, match="SparseParams"):
        step(state, {k: jnp.asarray(v) for k, v in data.batch(0).items()})


# ---------------------------------------------------------------------------
# Pruning runner emit="compressed" and serving.
# ---------------------------------------------------------------------------


def test_prune_transformer_emit_compressed_matches_dense():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=1)
    calib = jnp.asarray(data.batch(0)["tokens"])
    kw = dict(tokens=calib, method="magnitude", pattern=PatternSpec(2, 4),
              solver=SolverConfig(iters=40))
    dense_p, dense_masks = prune_transformer(params, CFG, **kw)
    comp_p, comp_masks = prune_transformer(params, CFG, emit="compressed", **kw)
    assert is_sparse_params(comp_p)
    assert tree_equal(dense_masks, comp_masks)
    assert tree_equal(decompress_params(comp_p), dense_p)


def test_prune_transformer_emit_validation():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(ValueError, match="emit"):
        prune_transformer(params, CFG, tokens=toks, emit="packed")
    with pytest.raises(ValueError, match="transposable"):
        prune_transformer(params, CFG, tokens=toks, emit="compressed",
                          pattern=PatternSpec(2, 4, transposable=False))
    # Non-multiple reduction dims must fail up front, not after the prune:
    # d_model=64 is not a multiple of M=24.
    with pytest.raises(ValueError, match="not a multiple"):
        prune_transformer(params, CFG, tokens=toks, emit="compressed",
                          pattern=PatternSpec(12, 24))


def test_compress_leaf_rejects_partial_groups():
    from repro.sparsity.params import compress_leaf

    w = jnp.ones((48, 64), jnp.float32)
    with pytest.raises(ValueError, match="multiple of M"):
        compress_leaf(w, jnp.ones((48, 64), bool), PatternSpec(16, 32))


def test_serve_from_sparse_params_matches_dense():
    pruned, _masks, sp = small_sparse_model()
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 64)
    out_c = ServeEngine(CFG, sp, max_len=16).generate(prompts, 4)
    out_d = ServeEngine(CFG, pruned, max_len=16).generate(prompts, 4)
    np.testing.assert_array_equal(np.array(out_c), np.array(out_d))


def test_serve_generate_zero_tokens_returns_empty():
    """Regression: max_new_tokens=0 used to sample and return one token."""
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    eng = ServeEngine(CFG, params, max_len=16)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, 64)
    out = eng.generate(prompts, 0)
    assert out.shape == (3, 0)
    assert out.dtype == jnp.int32
    out_one = eng.generate(prompts, 1)
    assert out_one.shape == (3, 1)


# ---------------------------------------------------------------------------
# Checkpointing SparseParams.
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrips_compressed_train_state():
    _pruned, _masks, sp = small_sparse_model()
    opt = AdamW(learning_rate=1e-3)
    state = make_train_state(CFG, opt, jax.random.PRNGKey(0), params=sp)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(5, state)
        restored = mgr.restore(5, state)
    wq = restored.params["blocks"]["attn"]["wq"]
    assert isinstance(wq, NMCompressed)
    assert wq.m == 4 and wq.indices.dtype == jnp.int8
    assert tree_equal(state.params, restored.params)
    assert tree_equal(state.opt_state.mu, restored.opt_state.mu)


def test_checkpointed_compressed_finetune_resumes_bit_identical():
    """Save mid-finetune, restore, continue: same trajectory as uninterrupted."""
    _pruned, _masks, sp = small_sparse_model()
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=4)
    opt = AdamW(learning_rate=1e-3)
    step = build_train_step(CFG, opt, step_cfg=StepConfig(mask_mode="compressed"),
                            donate=False)
    state = make_train_state(CFG, opt, jax.random.PRNGKey(0), params=sp)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(i).items()}
               for i in range(2)]
    state, _ = step(state, batches[0])
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, state)
        resumed = mgr.restore(1, state)
    a, _ = step(state, batches[1])
    b, _ = step(resumed, batches[1])
    assert tree_equal(a.params, b.params)


def test_content_store_prune_lru():
    from repro.checkpoint import ContentStore

    with tempfile.TemporaryDirectory() as d:
        store = ContentStore(d)
        for i, key in enumerate(["aa", "bb", "cc"]):
            store.put(key, data=np.zeros(256, np.uint8))
            os.utime(store.path(key), (1000.0 + i, 1000.0 + i))
        store.get("aa")  # bump: "aa" becomes most recently used
        entry = os.path.getsize(store.path("bb"))
        # An orphaned tmp file from a killed writer is GC'd once stale.
        orphan = store.path("dead") + ".tmp.12345"
        with open(orphan, "wb") as f:
            f.write(b"x" * 64)
        os.utime(orphan, (10.0, 10.0))
        evicted = store.prune(max_bytes=2 * entry)
        assert evicted == ["bb"]  # oldest access goes first
        assert store.has("aa") and store.has("cc") and not store.has("bb")
        assert store.size_bytes() <= 2 * entry
        assert not os.path.exists(orphan)
        assert set(store.prune(max_bytes=0)) == {"aa", "cc"}  # full drain
        assert store.keys() == []


def test_mask_cache_mem_hits_bump_disk_lru():
    """In-memory hits must count as recency for the disk LRU, or the
    hottest keys get evicted first after a restart."""
    from repro.checkpoint import ContentStore
    from repro.service.cache import MaskCache

    with tempfile.TemporaryDirectory() as d:
        cache = MaskCache(ContentStore(d), track_access=True)
        cache.put("hot", np.ones((2, 4, 4), bool))
        cache.put("cold", np.ones((2, 4, 4), bool))
        for key in ("hot", "cold"):
            os.utime(cache.store.path(key), (1000.0, 1000.0))
        assert cache.get_packed("hot") is not None  # mem hit
        assert cache.mem_hits == 1
        evicted = cache.prune(max_bytes=os.path.getsize(cache.store.path("hot")))
        assert evicted == ["cold"]  # "hot" survived because the mem hit touched it
